"""Example: explore scheduler behaviour across accelerator sizes — how much
crossbar capacity does each DNN need before the ARAS overlap stops paying?

    PYTHONPATH=src python examples/schedule_explore.py
"""
import sys

sys.path.insert(0, "src")

import dataclasses

from repro.core.resources import AcceleratorConfig
from repro.models.paper_nets import build_net, synth_layer_codes
from repro.sim.aras import ArasSimConfig, simulate_aras


def main() -> None:
    graph = build_net("resnet50")
    codes = synth_layer_codes(graph, max_samples=100_000)
    print(f"{graph.name}: scaling the PE pool (paper default 96 PEs)")
    print(f"{'PEs':>5} {'capacity':>10} {'baseline':>10} {'ARAS_BRW':>10} "
          f"{'speedup':>8}")
    for pes in (24, 48, 96, 192, 384):
        accel = AcceleratorConfig(num_pes=pes)
        cfgb = dataclasses.replace(ArasSimConfig.variant("baseline"), accel=accel)
        cfgw = dataclasses.replace(ArasSimConfig.variant("BRW"), accel=accel)
        b = simulate_aras(graph, codes, cfgb)
        w = simulate_aras(graph, codes, cfgw)
        print(f"{pes:5d} {accel.weight_capacity/1e6:9.1f}M "
              f"{1/b.makespan_s:9.1f}/s {1/w.makespan_s:9.1f}/s "
              f"{b.makespan_s/w.makespan_s:7.2f}×")
    print("\nthe optimizations matter most exactly when the model does not\n"
          "fit — the adaptability regime the paper targets.")


if __name__ == "__main__":
    main()
