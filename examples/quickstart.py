"""Quickstart: the ARAS pipeline end-to-end in one minute on CPU.

1. Build a DNN layer graph (ResNet-50) and synthetic INT8 weights.
2. Run the offline scheduler (overlap + replication + bank selection +
   partial weight reuse) and inspect the static instruction stream.
3. Compare the four paper configurations on speed/energy/pulses.
4. Run the same scheduling machinery as a TPU weight-streaming plan.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

from repro.core.scheduler import build_schedule, validate_schedule
from repro.models.paper_nets import build_net, synth_layer_codes
from repro.sim.aras import ArasSimConfig, simulate_aras
from repro.streaming.plan import StreamLayer, build_stream_plan


def main() -> None:
    graph = build_net("resnet50")
    codes = synth_layer_codes(graph, max_samples=100_000)
    print(f"{graph.name}: {len(graph.layers)} layers, "
          f"{graph.total_weights/1e6:.1f}M weights")

    # --- offline schedule (paper Fig 6/8) ---
    sched = build_schedule(graph, codes, ArasSimConfig.variant("BRW"))
    errs = validate_schedule(sched)
    assert not errs, errs
    writes, computes = sched.writes(), sched.computes()
    print(f"schedule: {len(writes)} write ops, {len(computes)} compute ops, "
          f"center={sched.reuse_center}, predicted {sched.makespan_s*1e3:.2f} ms")
    print("first events:")
    for ins in sched.instructions[:6]:
        print(f"  {ins.kind:8s} {ins.segment:12s} t=[{ins.t_start_cycles/1e6:8.3f},"
              f"{ins.t_end_cycles/1e6:8.3f}] Mcyc rows={ins.rows} ×{ins.replication}")

    # --- paper configurations ---
    base = simulate_aras(graph, codes, ArasSimConfig.variant("baseline"))
    for v in ("baseline", "B", "BR", "BRW"):
        r = simulate_aras(graph, codes, ArasSimConfig.variant(v))
        print(f"ARAS_{v:4s}: {1/r.makespan_s:6.1f} inf/s  "
              f"energy {r.total_energy_j*1e3:6.2f} mJ "
              f"({r.total_energy_j/base.total_energy_j:5.1%})  "
              f"pulses {r.total_pulses/base.total_pulses:5.1%}")

    # --- the same scheduler as a TPU streaming plan ---
    layers = [StreamLayer(l.name, l.weights, 2.0 * l.weights, windows)
              for l, windows in ((l, l.windows) for l in graph.layers)]
    plan = build_stream_plan(layers,
                             hbm_weight_budget_bytes=graph.total_weights // 3)
    print(f"TPU streaming plan: {plan.n_slots} arena slots, overlap speedup "
          f"{plan.overlap_speedup:.2f}× vs naive")


if __name__ == "__main__":
    main()
