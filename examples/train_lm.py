"""Example: train a reduced LM (any of the 10 assigned architectures) for a
few hundred steps on CPU with checkpoint/resume.

    PYTHONPATH=src python examples/train_lm.py --arch qwen3-32b --steps 50

This drives the same launcher used for the production meshes; on a pod you
would add  --mesh pod  (or --mesh multipod) under a real TPU runtime.
"""
import subprocess
import sys

if __name__ == "__main__":
    args = sys.argv[1:] or ["--arch", "qwen3-32b"]
    cmd = [sys.executable, "-m", "repro.launch.train", "--smoke",
           "--steps", "50", "--batch", "4", "--seq", "64",
           "--ckpt-dir", "/tmp/repro_train_ck", "--ckpt-every", "20", *args]
    print("+", " ".join(cmd))
    raise SystemExit(subprocess.call(cmd, env={"PYTHONPATH": "src",
                                               "PATH": "/usr/bin:/bin"}))
