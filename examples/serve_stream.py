"""Example: ARAS-style serving when weights exceed the device arena.

Part 1 streams a single model through the layer-streaming executor
(delta-encoded INT8 installs overlapped with compute) and checks the result
against the resident full model.

Part 2 serves two tenants — a base model and a fine-tuned variant — through
the continuous-batching `ServingEngine` on a weight arena too small to hold
both, so every tenant switch delta-installs layer codes §V-C-style across
tenants.

Part 3 switches the KV cache to the paged layout: requests sharing a system
prompt share physical KV pages (copy-on-write on divergence), and one
request runs far past the slot layout's per-request `max_seq` ceiling.

    PYTHONPATH=src python examples/serve_stream.py
"""
import sys

sys.path.insert(0, "src")

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.nn.model import forward, init_params
from repro.serving import EngineModel, SchedulerConfig, ServingEngine, format_summary
from repro.serving.variants import perturbed_variant
from repro.streaming.executor import StreamingExecutor


def main() -> None:
    cfg = get_config("gemma-7b", smoke=True)
    cfg = dataclasses.replace(cfg, n_layers=6, scan_layers=False)
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jnp.ones((2, 24), jnp.int32)}

    # --- 1. layer streaming: 6 layers through 3 arena slots -------------
    ex = StreamingExecutor(params, cfg, arena_slots=3, reuse=True,
                           plan_tokens=2 * 24)
    logits, m = ex.forward(batch)
    ref, _, _ = forward(params, batch, cfg)
    err = float(jnp.max(jnp.abs(logits.astype(jnp.float32)
                                - ref.astype(jnp.float32))))
    print(f"streamed forward matches resident model to {err:.4f} (INT8 noise)")
    print(f"installs: {int(m['raw_bytes'])} raw bytes -> "
          f"{int(m['wire_bytes'])} wire bytes "
          f"(skip ratio {m['mean_skip']:.1%}, center={int(m['reuse_center'])})")
    print(f"plan: overlap speedup {m['plan_overlap_speedup']:.2f}× vs naive, "
          f"projected makespan {m['plan_makespan_s']*1e3:.2f} ms on TPU link")

    # --- 2. two tenants through the continuous-batching engine ----------
    rng = np.random.default_rng(0)
    variant = perturbed_variant(params)
    eng = ServingEngine(
        [EngineModel("base", params, cfg, kv_slots=3, max_seq=40),
         EngineModel("variant", variant, cfg, kv_slots=3, max_seq=40)],
        weight_arena_slots=cfg.n_layers + 2,   # < 2 models -> tenant swaps
        sched=SchedulerConfig(model_turn_steps=4))
    for i in range(6):
        prompt = rng.integers(1, cfg.vocab, int(rng.integers(4, 12))).tolist()
        eng.submit("base" if i % 2 == 0 else "variant", prompt,
                   max_new_tokens=6)
    print("\nserving 6 requests across 2 tenants (continuous batching):")
    print(format_summary(eng.run()))

    # --- 3. paged KV: shared prefixes + no per-request max_seq ----------
    peng = ServingEngine(
        [EngineModel("base", params, cfg, kv_slots=4, max_seq=16,
                     kv_layout="paged", page_size=4, n_pages=24)])
    sys_prompt = rng.integers(1, cfg.vocab, 9).tolist()   # 2 full + 1 partial page
    for _ in range(3):   # same system prompt -> shared pages, COW on divergence
        peng.submit("base", sys_prompt, max_new_tokens=5)
    # 3× past the slot layout's max_seq=16 ceiling: just more pages
    long_req = peng.submit("base", rng.integers(1, cfg.vocab, 24).tolist(),
                           max_new_tokens=24)
    # temperature sampling rides along (seeded per-request PRNG)
    sampled = peng.submit("base", sys_prompt, max_new_tokens=5,
                          temperature=0.8, top_k=16, seed=7)
    print("\nserving 5 requests through the paged KV arena "
          "(page_size=4, 24 pages):")
    print(format_summary(peng.run()))
    print(f"long request spanned {long_req.prompt_len + 24} tokens "
          f"(slot arena ceiling was 16); sampled request: "
          f"{sampled.generated}")


if __name__ == "__main__":
    main()
