"""Example: serve a model whose weights exceed the device weight arena,
streaming layers ARAS-style (delta-encoded INT8 installs overlapped with
compute), and compare against the resident full model.

    PYTHONPATH=src python examples/serve_stream.py
"""
import sys

sys.path.insert(0, "src")

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.nn.model import forward, init_params
from repro.streaming.executor import StreamingExecutor


def main() -> None:
    cfg = get_config("gemma-7b", smoke=True)
    cfg = dataclasses.replace(cfg, n_layers=6, scan_layers=False)
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jnp.ones((2, 24), jnp.int32)}

    # 6 layers, 3 arena slots → every slot is overwritten twice per pass.
    ex = StreamingExecutor(params, cfg, arena_slots=3, reuse=True,
                           plan_tokens=2 * 24)
    logits, m = ex.forward(batch)
    ref, _, _ = forward(params, batch, cfg)
    err = float(jnp.max(jnp.abs(logits.astype(jnp.float32)
                                - ref.astype(jnp.float32))))
    print(f"streamed forward matches resident model to {err:.4f} (INT8 noise)")
    print(f"installs: {int(m['raw_bytes'])} raw bytes -> "
          f"{int(m['wire_bytes'])} wire bytes "
          f"(skip ratio {m['mean_skip']:.1%}, center={int(m['reuse_center'])})")
    print(f"plan: overlap speedup {m['plan_overlap_speedup']:.2f}× vs naive, "
          f"projected makespan {m['plan_makespan_s']*1e3:.2f} ms on TPU link")


if __name__ == "__main__":
    main()
