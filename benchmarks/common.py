"""Shared helpers for the paper-figure benchmarks.

Every benchmark prints ``name,us_per_call,derived`` CSV rows (us_per_call is
the simulated inference latency in microseconds; ``derived`` carries the
figure's headline metric) plus a human-readable table.
"""
from __future__ import annotations

import functools
import time
from typing import Dict

from repro.core.resources import AcceleratorConfig
from repro.models.paper_nets import PAPER_NETS, build_net, synth_layer_codes
from repro.sim.aras import ArasSimConfig, SimResult, simulate_aras, upper_bound_cycles
from repro.sim.tpu import TpuResult, simulate_tpu

VARIANTS = ("baseline", "B", "BR", "BRW")
MAX_SAMPLES = 200_000  # per-layer code samples (histograms converge well before)


@functools.lru_cache(maxsize=None)
def net_and_codes(name: str):
    graph = build_net(name)
    codes = tuple(synth_layer_codes(graph, seed=0, max_samples=MAX_SAMPLES))
    return graph, codes


@functools.lru_cache(maxsize=None)
def run_variant(name: str, variant: str) -> SimResult:
    graph, codes = net_and_codes(name)
    return simulate_aras(graph, list(codes), ArasSimConfig.variant(variant))


@functools.lru_cache(maxsize=None)
def run_tpu(name: str) -> TpuResult:
    graph, _ = net_and_codes(name)
    return simulate_tpu(graph)


@functools.lru_cache(maxsize=None)
def run_upper_bound_s(name: str) -> float:
    graph, _ = net_and_codes(name)
    return upper_bound_cycles(graph, AcceleratorConfig()) / AcceleratorConfig().freq_hz


def csv_row(name: str, us: float, derived: str) -> None:
    print(f"{name},{us:.1f},{derived}")


def timed(fn):
    t0 = time.perf_counter()
    out = fn()
    return out, (time.perf_counter() - t0) * 1e6
