"""Bench regression gate: compare a fresh `serving_bench` run against the
committed `BENCH_serving.json` trajectory, per-metric and direction-aware.

Only the virtual-clock parts are gated (overlap, chunked, prefix_cache,
wear) — their numbers are deterministic by construction, so a tolerance
breach is a real behaviour change, not host noise.  The wall-clock parts
(tenants, layout, components) time real host seconds and are reported by
the bench but never gated here.

Each gated metric carries a direction ("lower" = smaller is better,
"higher" = bigger is better) and a relative tolerance; a fresh value past
`base * (1 ± tol)` on the bad side is a regression.  Only metrics present
in BOTH documents are compared, so adding a metric to the bench never
breaks the gate against an older baseline.

    PYTHONPATH=src python -m benchmarks.serving_bench --parts 3,4,5,7 \
        --out fresh-bench.json
    PYTHONPATH=src python -m benchmarks.check_regression \
        --fresh fresh-bench.json

Exit code 0 = no regressions (or --warn-only), 1 = at least one metric
regressed, 2 = bad input (missing file, no comparable metrics).
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List, Tuple

_DEFAULT_BASELINE = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_serving.json")

# part -> metric -> (direction, relative tolerance).  Deterministic step
# counters (stall steps, trace counts) get tolerance 0.0: any change is a
# schedule change and should be looked at.  Virtual-time latencies get
# 10% headroom for workload-constant drift (e.g. a new admission rule
# shifting one request by a step), Gini 15% (a ratio of small counts).
SPECS: Dict[str, Dict[str, Tuple[str, float]]] = {
    "overlap": {
        "stall_steps_overlap": ("lower", 0.0),
        "itl_max_p95_s_overlap": ("lower", 0.10),
        "ttft_p95_s_overlap": ("lower", 0.10),
        "hidden_bytes": ("higher", 0.10),
    },
    "chunked": {
        "itl_max_p95_s_chunked": ("lower", 0.10),
        "ttft_p95_s_chunked": ("lower", 0.10),
        "traces_bucket_on": ("lower", 0.0),
    },
    "prefix_cache": {
        "prefill_tokens_on": ("lower", 0.05),
        "prefix_hit_rate": ("higher", 0.05),
        "ttft_p95_s_on": ("lower", 0.10),
    },
    "wear": {
        "install_energy_j_on": ("lower", 0.10),
        "install_energy_j_off": ("lower", 0.10),
        "kv_write_energy_j": ("lower", 0.10),
        "kv_page_writes": ("lower", 0.10),
        "wear_gini_weight": ("lower", 0.15),
    },
    # part 8: the wear-aware blend must keep flattening the weight
    # plane's write spread, and the seeded 2% fault arm must keep
    # surviving (floor, tolerance 0: fewer survivals means the sweep
    # stopped exercising the degradation path)
    "faults": {
        "wear_gini_weight_on": ("lower", 0.15),
        "faults_survived": ("higher", 0.0),
    },
    # part 9: the three decode arms must stay token-for-token identical
    # (a flag, so any drop is a correctness break) and sampling must
    # never regress back to per-row host syncs; step counts are a
    # deterministic schedule, tolerance 0.  The per-arm component
    # seconds in this part are wall-clock and deliberately ungated.
    "kernel": {
        "tokens_identical_fused": ("higher", 0.0),
        "tokens_identical_pallas": ("higher", 0.0),
        "sample_syncs_max_split": ("lower", 0.0),
        "sample_syncs_max_fused": ("lower", 0.0),
        "sample_syncs_max_pallas": ("lower", 0.0),
        "steps": ("lower", 0.0),
    },
    # part 10: the live telemetry plane must stay token-identical on an
    # identical schedule (a flag and a deterministic step count, both at
    # tolerance 0).  Host overhead is wall-clock: the on/off *ratio* is
    # gated as a generous ceiling (10x the committed baseline, and the
    # ratio is ~1 and never zero, unlike the us/step delta which can
    # clamp to 0 on a noisy host) so a telemetry hook accidentally
    # landing on the decode path still trips, while CI noise does not.
    "telemetry": {
        "tokens_identical": ("higher", 0.0),
        "steps": ("lower", 0.0),
        "host_overhead_ratio": ("lower", 9.0),
    },
}


def _regressed(base: float, fresh: float, direction: str, tol: float) -> bool:
    if direction == "lower":
        return fresh > base * (1.0 + tol) + 1e-12
    if direction == "higher":
        return fresh < base * (1.0 - tol) - 1e-12
    raise ValueError(f"unknown direction {direction!r}")


def compare(baseline_parts: Dict, fresh_parts: Dict) -> List[Dict]:
    """Per-metric comparison rows for every gated metric present in both
    documents; each row carries the verdict in `regressed`."""
    rows: List[Dict] = []
    for part, metrics in SPECS.items():
        base_p = baseline_parts.get(part)
        fresh_p = fresh_parts.get(part)
        if not isinstance(base_p, dict) or not isinstance(fresh_p, dict):
            continue
        for metric, (direction, tol) in metrics.items():
            if metric not in base_p or metric not in fresh_p:
                continue
            base, fresh = float(base_p[metric]), float(fresh_p[metric])
            rows.append({
                "part": part, "metric": metric,
                "base": base, "fresh": fresh,
                "direction": direction, "tol": tol,
                "regressed": _regressed(base, fresh, direction, tol),
            })
    return rows


def _load_parts(path: str) -> Dict:
    with open(path) as f:
        doc = json.load(f)
    parts = doc.get("parts")
    if not isinstance(parts, dict):
        raise ValueError(f"{path}: no 'parts' object "
                         "(not a serving_bench headline dump?)")
    return parts


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        description="direction-aware bench regression gate")
    p.add_argument("--baseline", default=_DEFAULT_BASELINE,
                   help="committed trajectory to gate against "
                        "(default: repo BENCH_serving.json)")
    p.add_argument("--fresh", required=True,
                   help="headline dump of the fresh bench run")
    p.add_argument("--warn-only", action="store_true",
                   help="report regressions but exit 0 anyway")
    args = p.parse_args(argv)

    try:
        baseline = _load_parts(args.baseline)
        fresh = _load_parts(args.fresh)
    except (OSError, ValueError, json.JSONDecodeError) as e:
        print(f"regression gate: cannot load inputs: {e}")
        return 2

    rows = compare(baseline, fresh)
    if not rows:
        print(f"regression gate: no gated metrics shared between "
              f"{args.baseline} and {args.fresh}")
        return 2

    width = max(len(f"{r['part']}/{r['metric']}") for r in rows)
    print(f"{'metric':<{width}}  {'dir':<6} {'tol':>5}  "
          f"{'baseline':>12}  {'fresh':>12}  verdict")
    n_bad = 0
    for r in rows:
        verdict = "REGRESSED" if r["regressed"] else "ok"
        n_bad += r["regressed"]
        print(f"{r['part'] + '/' + r['metric']:<{width}}  "
              f"{r['direction']:<6} {r['tol']:>4.0%}  "
              f"{r['base']:>12.6g}  {r['fresh']:>12.6g}  {verdict}")
    print(f"regression gate: {n_bad}/{len(rows)} gated metrics regressed "
          f"vs {args.baseline}")
    if n_bad and args.warn_only:
        print("--warn-only: reporting without failing")
        return 0
    return 1 if n_bad else 0


if __name__ == "__main__":
    raise SystemExit(main())
