"""Table III proxy: accuracy impact of the §V-C weight shift.

ImageNet/SQuAD evaluation is impossible offline; the paper's claim has two
mechanically checkable parts which we measure exactly:
  1. the shift is losslessly compensated through the zero point (Eq. 6-7) —
     the dot product is bit-identical for non-clipped codes;
  2. the only lossy effect is clipping, whose rate under the chosen Center
     is negligible (the guard used by `encode_network` is 1e-3).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import PAPER_NETS, csv_row, net_and_codes
from repro.core.weight_reuse import encode_network


def main() -> dict:
    out = {}
    print("\n== Table III proxy: clip rate under the chosen Center ==")
    for net in PAPER_NETS:
        _, codes = net_and_codes(net)
        encs, center = encode_network(list(codes), enabled=True)
        worst = max(e.clip_rate for e in encs)
        mean = float(np.mean([e.clip_rate for e in encs]))
        out[net] = (center, worst, mean)
        csv_row(f"tab3/{net}", 0.0,
                f"center={center};worst_clip={worst:.2e};mean_clip={mean:.2e}")
    print("-- all clip rates bounded by the 1e-3 accuracy guard "
          "(paper: <0.12% absolute accuracy loss)")
    return out


if __name__ == "__main__":
    main()
