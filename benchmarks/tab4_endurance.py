"""Table IV: ARAS lifespan in years.

Real-time rates: 30 inf/s (CNNs), 100 inf/s (BERT) at 1e11 endurance;
max-throughput at 1e12 endurance.  Lifespan = endurance / (cell rewrites per
inference × inferences/s).  Cell rewrites per inference = weights written
(incl. replication) / pool weight capacity."""
from __future__ import annotations

import numpy as np

from benchmarks.common import PAPER_NETS, csv_row, run_variant

SECONDS_PER_YEAR = 3600 * 24 * 365


def main() -> dict:
    out = {}
    print("\n== Table IV: lifespan (years) ==")
    for net in PAPER_NETS:
        brw = run_variant(net, "BRW")
        rt_rate = 100.0 if "bert" in net else 30.0
        writes_per_inf = brw.cell_writes_per_inference
        rt_years = 1e11 / (writes_per_inf * rt_rate) / SECONDS_PER_YEAR
        max_rate = 1.0 / brw.makespan_s
        max_years = 1e12 / (writes_per_inf * max_rate) / SECONDS_PER_YEAR
        out[net] = (rt_years, max_years)
        csv_row(f"tab4/{net}", brw.makespan_s * 1e6,
                f"rt_years={rt_years:.0f};max_tp_years={max_years:.0f}")
    rt = float(np.mean([v[0] for v in out.values()]))
    mx = float(np.mean([v[1] for v in out.values()]))
    csv_row("tab4/average", 0.0,
            f"rt_years={rt:.0f};max_tp_years={mx:.0f};paper=12/40")
    print(f"-- average lifespan: real-time {rt:.0f} y (paper 12), "
          f"max-throughput {mx:.0f} y (paper 40)")
    return out


if __name__ == "__main__":
    main()
