"""Fig 14: speedup of ARAS_BRW over the unoptimized baseline, plus the
upper-bound fractions of §VII-B.  Paper: 1.5× average (up to 2.2× ResNet-50,
~1.0× for BERT); baseline at 66% / ARAS at 88% of the write-once bound."""
from __future__ import annotations

import numpy as np

from benchmarks.common import PAPER_NETS, csv_row, run_upper_bound_s, run_variant


def main() -> dict:
    out = {}
    print("\n== Fig 14: ARAS_BRW speedup over baseline ==")
    fracs_base, fracs_brw = [], []
    for net in PAPER_NETS:
        base = run_variant(net, "baseline")
        brw = run_variant(net, "BRW")
        ub = run_upper_bound_s(net)
        speedup = base.makespan_s / brw.makespan_s
        out[net] = speedup
        fracs_base.append(ub / base.makespan_s)
        fracs_brw.append(ub / brw.makespan_s)
        csv_row(f"fig14/{net}", brw.makespan_s * 1e6,
                f"speedup={speedup:.2f};inf_s={1/brw.makespan_s:.0f};"
                f"ub_frac={fracs_brw[-1]:.2f}")
    avg = float(np.mean(list(out.values())))
    csv_row("fig14/average", 0.0,
            f"speedup={avg:.2f};paper=1.5;ub_base={np.mean(fracs_base):.2f}"
            f";ub_brw={np.mean(fracs_brw):.2f};paper_ub=0.66/0.88")
    print(f"-- average speedup {avg:.2f} (paper: 1.5×); bound fractions "
          f"baseline {np.mean(fracs_base):.2f} / ARAS {np.mean(fracs_brw):.2f} "
          f"(paper: 0.66 / 0.88)")
    return out


if __name__ == "__main__":
    main()
