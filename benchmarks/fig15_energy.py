"""Fig 15: normalized energy breakdown of each ARAS configuration.
Paper: bank selection −3%, +replication −14%, +weight reuse −11%; ARAS_BRW
achieves 28% total savings; compute energy negligible; write energy dominates
NLP, static energy high in CNNs."""
from __future__ import annotations

import numpy as np

from benchmarks.common import PAPER_NETS, VARIANTS, csv_row, run_variant


def main() -> dict:
    out = {}
    print("\n== Fig 15: normalized energy breakdown ==")
    for net in PAPER_NETS:
        base = run_variant(net, "baseline").total_energy_j
        parts = {}
        for v in VARIANTS:
            r = run_variant(net, v)
            parts[v] = r.total_energy_j / base
            brk = ";".join(
                f"{k}={val / base:.3f}" for k, val in r.energy.items() if k != "total"
            )
            csv_row(f"fig15/{net}/{v}", r.makespan_s * 1e6,
                    f"norm_total={parts[v]:.3f};{brk}")
        out[net] = parts
    avg = {v: float(np.mean([out[n][v] for n in out])) for v in VARIANTS}
    csv_row("fig15/average", 0.0,
            ";".join(f"{v}={avg[v]:.3f}" for v in VARIANTS) + ";paper_BRW=0.72")
    print(f"-- average normalized energy: "
          + ", ".join(f"{v}={avg[v]:.3f}" for v in VARIANTS)
          + "  (paper: BRW=0.72)")
    return out


if __name__ == "__main__":
    main()
