"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Run as:
    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run --fast     # paper figures only
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    t0 = time.perf_counter()
    from benchmarks import (
        fig13_writes,
        fig14_speedup,
        fig15_energy,
        fig16_17_tpu,
        tab3_accuracy,
        tab4_endurance,
    )

    print("name,us_per_call,derived")
    fig13_writes.main()
    fig14_speedup.main()
    fig15_energy.main()
    fig16_17_tpu.main()
    tab3_accuracy.main()
    tab4_endurance.main()

    if "--fast" not in sys.argv:
        from benchmarks import serving_bench, streaming_bench

        streaming_bench.main()
        serving_bench.main([])   # default parts; don't re-parse our argv

    print(f"\ntotal benchmark wall time: {time.perf_counter() - t0:.1f}s")


if __name__ == "__main__":
    main()
