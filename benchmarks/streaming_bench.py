"""Streaming-executor benchmark (the paper's technique, TPU-native form):
install bytes raw vs delta vs delta+centering, and the planned overlap
speedup vs the naive install→compute schedule (Fig 7 vs Fig 8, DMA edition).

Weights: random inits quantize to already-centered code distributions (the
affine range tracks a symmetric body), which hides §V-C — real checkpoints
have asymmetric outlier tails (paper Fig 11).  The bench injects seeded
asymmetric outliers per tensor to model that regime; the faithful pulse
numbers live in fig13_writes.py.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row
from repro.configs import get_config
from repro.nn.model import init_params
from repro.streaming.executor import StreamingExecutor
from repro.streaming.plan import StreamLayer, TpuLinkModel, build_stream_plan


def _checkpointify(params, seed=0):
    """Inject asymmetric outlier tails (BN-fold / trained-tensor regime).
    The tail sign alternates per *layer* so consecutive layers' code means
    land in different MSB sections — the paper's Fig 11 situation."""
    rng = np.random.default_rng(seed)
    segments = []
    for j, block in enumerate(params["stack"]["segments"]):
        sign = 1.0 if j % 2 == 0 else -1.0
        leaves, treedef = jax.tree_util.tree_flatten(block)
        out = []
        for l in leaves:
            a = np.asarray(l)
            if a.ndim >= 2 and a.size >= 1024:
                a = a.copy()
                idx = rng.choice(a.size, size=max(a.size // 500, 1),
                                 replace=False)
                a.flat[idx] = sign * (6.0 + 2.0 * rng.random(idx.size)) * a.std()
            out.append(a)
        segments.append(jax.tree_util.tree_unflatten(treedef, out))
    return {**params, "stack": {"segments": segments}}


def main() -> dict:
    print("\n== Streaming executor (ARAS on TPU) ==")
    cfg = get_config("minicpm-2b", smoke=True)
    cfg = dataclasses.replace(cfg, n_layers=8, d_model=128, d_ff=256,
                              scan_layers=False)
    params = _checkpointify(init_params(jax.random.PRNGKey(0), cfg))
    batch = {"tokens": jnp.ones((2, 16), jnp.int32)}

    out = {}
    for reuse in (False, True):
        ex = StreamingExecutor(params, cfg, arena_slots=3, reuse=reuse)
        _, m = ex.forward(batch)
        tag = "centered" if reuse else "plain-delta"
        out[tag] = m
        csv_row(f"stream/{tag}", m["wall_s"] * 1e6,
                f"wire_mb={m['wire_bytes']/1e6:.2f};raw_mb={m['raw_bytes']/1e6:.2f};"
                f"skip={m['mean_skip']:.3f};center={int(m['reuse_center'])}")
    saved = 1 - out["centered"]["wire_bytes"] / out["plain-delta"]["wire_bytes"]
    print(f"-- §V-C centering cuts install wire bytes by {saved:.1%} "
          f"(ReRAM pulse analogue: paper −17%)")

    # Planned overlap across arithmetic intensities (gemma-7b class layers).
    full = get_config("gemma-7b")
    per_layer = int(full.param_count() / full.n_layers)
    print("-- overlap speedup vs tokens in flight (install 3.1 ms/layer):")
    for tokens in (64, 256, 1024, 8192, 65536):
        layers = [StreamLayer(f"L{i}", per_layer, 2.0 * per_layer, tokens)
                  for i in range(full.n_layers)]
        plan = build_stream_plan(layers,
                                 hbm_weight_budget_bytes=6 * per_layer,
                                 link=TpuLinkModel(), slot_bytes=per_layer,
                                 replication=False)
        csv_row(f"stream/plan_gemma7b_t{tokens}", plan.makespan_s * 1e6,
                f"overlap_speedup={plan.overlap_speedup:.2f}")
        print(f"   tokens={tokens:6d}: {plan.overlap_speedup:.2f}× "
              f"(compute {per_layer*2*tokens/197e12*1e3:7.2f} ms/layer)")
    out["tokens_sweep"] = True
    return out


if __name__ == "__main__":
    main()
