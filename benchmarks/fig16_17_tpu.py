"""Fig 16/17: ARAS_BRW vs an area/frequency-matched TPU-like accelerator.
Paper: 1.2× average speedup (up to 1.5×) and 33% average energy reduction
(up to 61%)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import PAPER_NETS, csv_row, run_tpu, run_variant


def main() -> dict:
    out = {}
    print("\n== Fig 16/17: ARAS vs TPU-like accelerator ==")
    for net in PAPER_NETS:
        brw = run_variant(net, "BRW")
        tpu = run_tpu(net)
        speedup = tpu.makespan_s / brw.makespan_s
        eratio = brw.total_energy_j / tpu.total_energy_j
        out[net] = (speedup, eratio)
        csv_row(f"fig16_17/{net}", brw.makespan_s * 1e6,
                f"speedup_vs_tpu={speedup:.2f};energy_ratio={eratio:.2f}")
    s = float(np.mean([v[0] for v in out.values()]))
    e = float(np.mean([v[1] for v in out.values()]))
    csv_row("fig16_17/average", 0.0,
            f"speedup_vs_tpu={s:.2f};energy_ratio={e:.2f};paper=1.2/0.67")
    print(f"-- average: speedup {s:.2f}× (paper 1.2×), "
          f"energy ratio {e:.2f} (paper 0.67)")
    return out


if __name__ == "__main__":
    main()
