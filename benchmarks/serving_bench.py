"""Continuous-batching serving benchmark: Poisson-arrival multi-tenant
workload through `repro.serving.ServingEngine`.

Two tenants share one device budget.  Tenant B is a perturbed copy of
tenant A (the fine-tuned-variant regime that multi-tenant weight arenas
actually see), so cross-tenant §V-C delta installs have real structure to
exploit.  The bench reports p50/p95 request latency, tokens/s, queue depth,
and the install wire bytes with cross-tenant reuse on vs off.

    PYTHONPATH=src python -m benchmarks.serving_bench
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import csv_row
from benchmarks.streaming_bench import _checkpointify
from repro.configs import get_config
from repro.nn.model import init_params
from repro.serving import (EngineModel, SchedulerConfig, ServingEngine,
                           format_summary)
from repro.serving.variants import perturbed_variant

N_REQUESTS = 24
ARRIVAL_RATE_HZ = 40.0      # Poisson arrival intensity
PROMPT_RANGE = (6, 20)
GEN_RANGE = (6, 14)
MAX_SEQ = 40
KV_SLOTS = 4
TURN_STEPS = 4


def _workload(seed: int = 0):
    rng = np.random.default_rng(seed)
    inter = rng.exponential(1.0 / ARRIVAL_RATE_HZ, N_REQUESTS)
    arrivals = np.cumsum(inter)
    jobs = []
    for i in range(N_REQUESTS):
        plen = int(rng.integers(*PROMPT_RANGE))
        gen = int(rng.integers(*GEN_RANGE))
        model = "base" if rng.random() < 0.5 else "variant"
        prompt = rng.integers(1, 500, plen).tolist()
        jobs.append((float(arrivals[i]), model, prompt, gen))
    return jobs


def _run_arm(cfg, params_a, params_b, jobs, *, reuse: bool):
    eng = ServingEngine(
        [EngineModel("base", params_a, cfg, kv_slots=KV_SLOTS,
                     max_seq=MAX_SEQ),
         EngineModel("variant", params_b, cfg, kv_slots=KV_SLOTS,
                     max_seq=MAX_SEQ)],
        weight_arena_slots=cfg.n_layers + 1,   # forces tenant swaps
        reuse=reuse,
        sched=SchedulerConfig(max_prefill_per_step=4,
                              model_turn_steps=TURN_STEPS))
    t0 = time.perf_counter()
    pending = sorted(jobs)
    while pending or eng.has_work():
        now = time.perf_counter() - t0
        while pending and pending[0][0] <= now:
            _, model, prompt, gen = pending.pop(0)
            eng.submit(model, prompt, max_new_tokens=gen)
        if eng.has_work():
            eng.step()
        elif pending:
            time.sleep(min(pending[0][0] - now, 1e-3))
    return eng.summary(time.perf_counter() - t0)


def main() -> dict:
    print("\n== Continuous-batching serving engine (Poisson, 2 tenants) ==")
    cfg = get_config("gemma-7b", smoke=True)
    # _checkpointify injects the asymmetric outlier tails real checkpoints
    # have (random inits quantize already-centered, hiding §V-C).
    params_a = _checkpointify(init_params(jax.random.PRNGKey(0), cfg))
    params_b = perturbed_variant(params_a)
    jobs = _workload()

    # Warmup arm over the full workload populates the shared jit caches
    # (every prompt length) so timed arms compare scheduling, not XLA.
    _run_arm(cfg, params_a, params_b, jobs, reuse=True)

    out = {}
    for reuse in (False, True):
        tag = "reuse-on" if reuse else "reuse-off"
        s = _run_arm(cfg, params_a, params_b, jobs, reuse=reuse)
        out[tag] = s
        csv_row(f"serving/{tag}", s["latency_p50_s"] * 1e6,
                f"p95_us={s['latency_p95_s']*1e6:.0f};"
                f"tok_s={s['tokens_per_s']:.1f};"
                f"wire_mb={s['install_wire_bytes']/1e6:.3f};"
                f"installs={int(s['installs'])}")
        print(f"-- {tag}:")
        print(format_summary(s))
    # Install counts are wall-clock dependent (Poisson arrivals vs real
    # turn boundaries), so compare wire bytes per byte of installed
    # weights, not absolute MB across arms.
    saved = out["reuse-on"]["install_savings"]
    print(f"-- cross-tenant §V-C reuse ships {saved:.1%} fewer wire bytes "
          f"per installed weight byte (reuse-off ships raw by definition); "
          f"absolute: {out['reuse-off']['install_wire_bytes']/1e6:.2f} MB "
          f"over {int(out['reuse-off']['installs'])} installs vs "
          f"{out['reuse-on']['install_wire_bytes']/1e6:.2f} MB over "
          f"{int(out['reuse-on']['installs'])}")
    out["wire_saved_frac"] = saved
    return out


if __name__ == "__main__":
    main()
