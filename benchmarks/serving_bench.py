"""Continuous-batching serving benchmark: Poisson-arrival multi-tenant
workload through `repro.serving.ServingEngine`.

Part 1 — two tenants share one device budget.  Tenant B is a perturbed
copy of tenant A (the fine-tuned-variant regime that multi-tenant weight
arenas actually see), so cross-tenant §V-C delta installs have real
structure to exploit.  The bench reports p50/p95 request latency, tokens/s,
queue depth, and the install wire bytes with cross-tenant reuse on vs off.

Part 2 — paged vs slot KV layout under mixed short/long Poisson traffic on
one tenant, at the SAME device KV budget.  The slot arm must size every
slot for the longest request, so short requests strand most of their slot;
the paged arm packs the same budget block by block, admits more requests
concurrently, and shares the pages of the common system-prompt prefix.

Part 3 — overlapped vs synchronous cross-tenant weight installs on a
deterministic virtual clock (simulated install ticks, so the numbers are
exactly reproducible).  Synchronous installs stall every tenant switch for
the whole install stream; the overlap arm pipelines the incoming tenant's
installs under the outgoing tenant's final decode steps (ARAS §IV applied
at the tenant scale) and must show strictly fewer install-stall steps and a
lower worst inter-token gap at the turn boundary — token-for-token
identical output.

Part 4 — chunked prefill with prompt-length bucketing on mixed 16–2048
token prompts, again on the virtual clock with a per-step cost model that
charges steps for the prompt tokens they prefill.  A monolithic prefill
burns a whole prompt in one step, so every concurrent decoder eats a
prompt-length inter-token gap; the chunked arm spreads the same tokens
across budgeted steps and must show a strictly lower worst decode ITL p95,
token-for-token identical.  The bucketing sub-arm counts distinct prefill
jit traces over randomized prompt lengths: bounded by the bucket ladder
with bucketing on, growing with every new tail length with it off.

Part 5 — radix-tree prefix cache on a shared-system-prompt multi-turn
workload (virtual clock, prefill-token cost model).  Every conversation
carries the same system prompt, and each turn's prompt is the previous
turn's prompt + generated reply + a fresh user message.  The cache-off arm
re-prefills that growing history from scratch every turn; the cache-on arm
retains finished requests' pages in the radix tree and skips every chunk
the cached prefix covers, so it must show strictly fewer computed prefill
tokens and a strictly lower TTFT p95 — token-for-token identical output.

Part 6 — per-step component breakdown through the structured tracer, on
the part-3 overlap workload.  A wall-clock `Tracer` on a virtual-clock
engine keeps the schedule deterministic while the component spans
(schedule / install / prefill / decode / sample / bookkeep) measure real
host seconds, printed as an overlap-on vs overlap-off table.  With
`--trace-out` it also re-runs the overlap arm with engine AND tracer on
one `VirtualClock` and writes the byte-identical Chrome-trace artifact.

Part 7 — wear & write energy: the part-1 reuse-on/off comparison re-run on
a virtual clock with paged KV + prefix cache on both tenants, priced in
joules through the ARAS energy model.  The schedule is identical across
arms (instant installs; reuse only changes install accounting), so the
reuse-on arm must spend strictly less install write energy — the §V-C
equal-skip pulses — while the prefix cache's avoided page writes and the
per-slot/per-page wear Gini are reported off the engine's WearMap.

Part 9 — kernel backend & fused sampling: the decode hot path run three
ways on one deterministic virtual-clock schedule with mixed greedy and
temperature/top-k requests.  The legacy arm decodes through the XLA
gather path and samples on the host; the fused arm keeps the XLA kernel
but samples inside the jitted step; the Pallas arm routes paged GQA
decode through the `kernels/paged_attention` kernel (interpret mode off
TPU) with fused sampling.  All three must be token-for-token identical,
every arm must spend at most one sampling host sync per decoded step
(the PR 9 per-row `int(argmax)` bug), and the tracer's component table
shows where the host seconds went.

Every run writes the per-part headline numbers to `BENCH_serving.json`
at the repo root (override with `--out`, disable with `--out ''`), so
the perf trajectory persists commit over commit.  `--parts` selects a
subset, e.g. the CI artifact job runs only the virtual-clock parts:

    PYTHONPATH=src python -m benchmarks.serving_bench
    PYTHONPATH=src python -m benchmarks.serving_bench --parts 3,6 \
        --trace-out trace.json
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from benchmarks.common import csv_row
from benchmarks.streaming_bench import _checkpointify
from repro.configs import get_config
from repro.nn.model import init_params
from repro.serving import (EngineModel, FlightRecorder, InstallCostModel,
                           SchedulerConfig, ServingEngine, SLOConfig,
                           TelemetryConfig, Tracer, VirtualClock,
                           WeightResidencyManager, drive_simulated,
                           format_summary, prometheus_text,
                           validate_events_jsonl, validate_prometheus_text)
from repro.serving.tracing import TRACE_COMPONENTS
from repro.serving.variants import perturbed_variant

N_REQUESTS = 24
ARRIVAL_RATE_HZ = 40.0      # Poisson arrival intensity
PROMPT_RANGE = (6, 20)
GEN_RANGE = (6, 14)
MAX_SEQ = 40
KV_SLOTS = 4
TURN_STEPS = 4


def _workload(seed: int = 0):
    rng = np.random.default_rng(seed)
    inter = rng.exponential(1.0 / ARRIVAL_RATE_HZ, N_REQUESTS)
    arrivals = np.cumsum(inter)
    jobs = []
    for i in range(N_REQUESTS):
        plen = int(rng.integers(*PROMPT_RANGE))
        gen = int(rng.integers(*GEN_RANGE))
        model = "base" if rng.random() < 0.5 else "variant"
        prompt = rng.integers(1, 500, plen).tolist()
        jobs.append((float(arrivals[i]), model, prompt, gen))
    return jobs


def _drive(eng, jobs):
    """Arrival-clocked driver: submit each job at its Poisson timestamp,
    stepping the engine whenever it has work."""
    t0 = time.perf_counter()
    pending = sorted(jobs)
    while pending or eng.has_work():
        now = time.perf_counter() - t0
        while pending and pending[0][0] <= now:
            _, model, prompt, gen = pending.pop(0)
            eng.submit(model, prompt, max_new_tokens=gen)
        if eng.has_work():
            eng.step()
        elif pending:
            time.sleep(min(pending[0][0] - now, 1e-3))
    return eng.summary(time.perf_counter() - t0)


def _run_arm(cfg, params_a, params_b, jobs, *, reuse: bool):
    eng = ServingEngine(
        [EngineModel("base", params_a, cfg, kv_slots=KV_SLOTS,
                     max_seq=MAX_SEQ),
         EngineModel("variant", params_b, cfg, kv_slots=KV_SLOTS,
                     max_seq=MAX_SEQ)],
        weight_arena_slots=cfg.n_layers + 1,   # forces tenant swaps
        reuse=reuse,
        sched=SchedulerConfig(max_prefill_per_step=4,
                              model_turn_steps=TURN_STEPS))
    return _drive(eng, jobs)


# ---------------------------------------------------- paged vs slot layout
PAGE_SIZE = 8
LONG_MAX_SEQ = 96          # slot arm: every slot sized for the longest
SLOT_ARM_SLOTS = 4         # 4 × 96 tokens of KV budget
PAGED_ROWS = 8             # paged arm: same budget, finer admission
SYS_PREFIX_LEN = 16        # shared system prompt (2 full pages)
BURST_RATE_HZ = 400.0      # near-simultaneous arrivals: admission-bound


def _mixed_workload(seed: int = 1, n: int = 20):
    """Mostly-short burst traffic with a long tail, all behind one shared
    system prompt — the regime whole-sequence slots handle worst."""
    rng = np.random.default_rng(seed)
    sys_prefix = rng.integers(1, 500, SYS_PREFIX_LEN).tolist()
    arrivals = np.cumsum(rng.exponential(1.0 / BURST_RATE_HZ, n))
    jobs = []
    for i in range(n):
        if rng.random() < 0.25:        # long: ~2/3 of the slot ceiling
            plen, gen = int(rng.integers(40, 56)), int(rng.integers(12, 24))
        else:                          # short: strands a 96-token slot
            plen, gen = int(rng.integers(4, 12)), int(rng.integers(4, 10))
        prompt = sys_prefix + rng.integers(1, 500, plen).tolist()
        jobs.append((float(arrivals[i]), "base", prompt, gen))
    return jobs


def _run_layout_arm(cfg, params, jobs, *, layout: str):
    if layout == "paged":
        kv = dict(kv_slots=PAGED_ROWS, max_seq=LONG_MAX_SEQ,
                  kv_layout="paged", page_size=PAGE_SIZE,
                  n_pages=SLOT_ARM_SLOTS * LONG_MAX_SEQ // PAGE_SIZE)
    else:
        kv = dict(kv_slots=SLOT_ARM_SLOTS, max_seq=LONG_MAX_SEQ)
    eng = ServingEngine([EngineModel("base", params, cfg, **kv)],
                        sched=SchedulerConfig(max_prefill_per_step=4))
    return _drive(eng, jobs)


def paged_vs_slot() -> dict:
    print("\n== Paged vs slot KV layout (mixed short/long Poisson) ==")
    cfg = get_config("gemma-7b", smoke=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    jobs = _mixed_workload()
    out = {}
    for layout in ("slot", "paged"):
        _run_layout_arm(cfg, params, jobs, layout=layout)   # jit warmup
        s = _run_layout_arm(cfg, params, jobs, layout=layout)
        out[layout] = s
        csv_row(f"serving/kv-{layout}", s["latency_p50_s"] * 1e6,
                f"p95_us={s['latency_p95_s']*1e6:.0f};"
                f"tok_s={s['tokens_per_s']:.1f};"
                f"max_conc={int(s['max_concurrent'])}")
        print(f"-- {layout} (KV budget "
              f"{SLOT_ARM_SLOTS * LONG_MAX_SEQ} tokens):")
        print(format_summary(s))
    sl, pg = out["slot"], out["paged"]
    print(f"-- same {SLOT_ARM_SLOTS * LONG_MAX_SEQ}-token KV budget: paged "
          f"admits {int(pg['max_concurrent'])} concurrent requests vs "
          f"{int(sl['max_concurrent'])} slots (queue depth max "
          f"{int(sl['queue_depth_max'])} -> {int(pg['queue_depth_max'])}), "
          f"saves {int(pg['kv_pages_saved'])} pages "
          f"({int(pg['kv_pages_saved']) * PAGE_SIZE} KV tokens) via shared "
          f"prefixes; p50 latency {sl['latency_p50_s']*1e3:.0f} vs "
          f"{pg['latency_p50_s']*1e3:.0f} ms "
          f"(smoke-scale CPU decode cost grows with the gather length — "
          f"the structural win is admission, occupancy, and sharing)")
    return out


# ------------------------------------------- overlapped installs (part 3)
OVERLAP_TURN_STEPS = 4
OVERLAP_STEP_DT = 1e-3      # one simulated engine step = 1 ms


def _overlap_workload(cfg, seed: int = 2, n: int = 16):
    """Two-tenant Poisson arrivals in *virtual* time (units of engine
    steps), long enough generations that turn rotations happen mid-flight."""
    rng = np.random.default_rng(seed)
    t, jobs = 0.0, []
    for i in range(n):
        t += float(rng.exponential(2.0)) * OVERLAP_STEP_DT
        plen = int(rng.integers(4, 12))
        jobs.append((t, "base" if i % 2 == 0 else "variant",
                     rng.integers(1, cfg.vocab, plen).tolist(),
                     int(rng.integers(8, 14))))
    return jobs


def _install_tick_bytes(cfg, params_a, params_b) -> int:
    """Size one install tick at half the biggest layer's raw stream so a
    cold tenant install spans several steps — the regime where hiding it
    matters.  (Sizing needs the quantized store, not a whole engine.)"""
    probe = WeightResidencyManager(
        {"base": (params_a, cfg), "variant": (params_b, cfg)}, cfg.n_layers)
    return max(max(lw.codes.size for lw in probe.store.layers) // 2, 1)


def _run_overlap_arm(cfg, params_a, params_b, jobs, *, overlap: bool,
                     bytes_per_tick: int, tracer=None, clock=None):
    clock = clock or VirtualClock()
    eng = ServingEngine(
        [EngineModel("base", params_a, cfg, kv_slots=KV_SLOTS,
                     max_seq=MAX_SEQ),
         EngineModel("variant", params_b, cfg, kv_slots=KV_SLOTS,
                     max_seq=MAX_SEQ)],
        weight_arena_slots=cfg.n_layers + 1,   # forces tenant swaps
        sched=SchedulerConfig(max_prefill_per_step=4,
                              model_turn_steps=OVERLAP_TURN_STEPS),
        clock=clock, tracer=tracer,
        install_ticks_per_step=1, overlap_installs=overlap,
        install_cost=InstallCostModel(bytes_per_tick=bytes_per_tick))
    summary = drive_simulated(eng, clock, jobs, dt=OVERLAP_STEP_DT)
    summary["_generated"] = {r.rid: list(r.generated)
                             for r in eng.requests.values()}
    return summary


def overlap_vs_sync() -> dict:
    print("\n== Overlapped vs synchronous weight installs "
          "(virtual clock, 2 tenants) ==")
    cfg = get_config("gemma-7b", smoke=True)
    params_a = _checkpointify(init_params(jax.random.PRNGKey(0), cfg))
    params_b = perturbed_variant(params_a)
    jobs = _overlap_workload(cfg)
    bpt = _install_tick_bytes(cfg, params_a, params_b)

    out = {}
    for overlap in (False, True):
        tag = "overlap-on" if overlap else "overlap-off"
        s = _run_overlap_arm(cfg, params_a, params_b, jobs, overlap=overlap,
                             bytes_per_tick=bpt)
        out[tag] = s
        csv_row(f"serving/install-{tag}", s["install_stall_steps"],
                f"hidden_mb={s['overlap_hidden_bytes']/1e6:.3f};"
                f"itl_p95_ms={s['itl_max_p95_s']*1e3:.1f};"
                f"steps={int(s['steps'])}")
        print(f"-- {tag}:")
        print(format_summary(s))
    sync, over = out["overlap-off"], out["overlap-on"]
    assert over["_generated"] == sync["_generated"], \
        "overlap changed decoded tokens"
    print(f"-- overlap hides {over['overlap_hidden_bytes']/1e6:.2f} MB of "
          f"install stream under decode: install stall steps "
          f"{int(sync['install_stall_steps'])} -> "
          f"{int(over['install_stall_steps'])}, worst inter-token gap p95 "
          f"{sync['itl_max_p95_s']*1e3:.1f} -> "
          f"{over['itl_max_p95_s']*1e3:.1f} ms, total steps "
          f"{int(sync['steps'])} -> {int(over['steps'])} "
          f"(token-for-token identical)")
    for s in out.values():
        s.pop("_generated")
    return out


# --------------------------------------------- chunked prefill (part 4)
CHUNK_STEP_DT = 1e-3        # one simulated engine step = 1 ms
CHUNK_TOKEN_COST = 2e-5     # + 20 µs of virtual step time per prefilled token
CHUNK_PROMPT_LENS = (16, 48, 2048, 24, 512, 96, 1024, 32)
CHUNK_SIZE = 128


def _chunk_workload(cfg, seed: int = 4):
    """Poisson arrivals of mixed short/long prompts on one tenant — the
    regime where one monolithic 2048-token prefill freezes every concurrent
    decode for two thousand token-times."""
    rng = np.random.default_rng(seed)
    t, jobs = 0.0, []
    for plen in CHUNK_PROMPT_LENS:
        t += float(rng.exponential(4.0)) * CHUNK_STEP_DT
        jobs.append((t, "base", rng.integers(1, cfg.vocab, plen).tolist(),
                     int(rng.integers(8, 14))))
    return jobs


def _run_chunk_arm(cfg, params, jobs, *, chunk: int, budget, growth=2.0,
                   max_seq: int = 2048 + 16):
    clock = VirtualClock()
    eng = ServingEngine(
        [EngineModel("base", params, cfg, kv_slots=4, max_seq=max_seq)],
        sched=SchedulerConfig(max_prefill_per_step=2,
                              prefill_token_budget=budget),
        clock=clock, prefill_chunk=chunk, bucket_growth=growth)
    summary = drive_simulated(
        eng, clock, jobs, dt=CHUNK_STEP_DT,
        step_dt=lambda rec: (CHUNK_STEP_DT
                             + CHUNK_TOKEN_COST * rec.prefill_tokens))
    summary["_generated"] = {r.rid: list(r.generated)
                             for r in eng.requests.values()}
    return summary


def chunked_prefill_bench() -> dict:
    print("\n== Chunked prefill + prompt-length bucketing "
          "(virtual clock, 16-2048 token prompts) ==")
    from repro.launch.steps import prefill_cache_info
    cfg = get_config("gemma-7b", smoke=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    jobs = _chunk_workload(cfg)

    out = {}
    arms = {"chunk-off": dict(chunk=0, budget=None),
            "chunk-on": dict(chunk=CHUNK_SIZE, budget=CHUNK_SIZE)}
    for tag, kw in arms.items():
        s = _run_chunk_arm(cfg, params, jobs, **kw)
        out[tag] = s
        csv_row(f"serving/prefill-{tag}", s["itl_max_p95_s"] * 1e3,
                f"ttft_p95_ms={s['ttft_p95_s']*1e3:.1f};"
                f"chunks={int(s['prefill_chunks'])};"
                f"steps={int(s['steps'])}")
        print(f"-- {tag}:")
        print(format_summary(s))
    mono, chunked = out["chunk-off"], out["chunk-on"]
    assert mono["_generated"] == chunked["_generated"], \
        "chunking changed decoded tokens"
    print(f"-- budget {CHUNK_SIZE} tokens/step: worst decode inter-token "
          f"gap p95 {mono['itl_max_p95_s']*1e3:.1f} -> "
          f"{chunked['itl_max_p95_s']*1e3:.1f} ms "
          f"(token-for-token identical; "
          f"{int(chunked['prefill_chunks'])} chunks over "
          f"{int(chunked['steps'])} steps vs {int(mono['steps'])})")

    # -- trace counts: bucketing on vs off over randomized prompt lengths
    rng = np.random.default_rng(7)
    lens = rng.integers(1, 65, 40)
    for tag, growth in (("bucket-on", 2.0), ("bucket-off", 0.0)):
        before = prefill_cache_info()["chunk_misses"]
        jobs_b = [(i * CHUNK_STEP_DT, "base",
                   rng.integers(1, cfg.vocab, int(n)).tolist(), 2)
                  for i, n in enumerate(lens)]
        _run_chunk_arm(cfg, params, jobs_b, chunk=64, budget=None,
                       growth=growth, max_seq=96)
        traces = prefill_cache_info()["chunk_misses"] - before
        out[f"{tag}_traces"] = traces
        csv_row(f"serving/prefill-{tag}", traces,
                f"prompt_lens={len(set(lens.tolist()))}")
    print(f"-- {len(set(lens.tolist()))} distinct prompt lengths: "
          f"{out['bucket-on_traces']} distinct prefill traces with the "
          f"bucket ladder vs {out['bucket-off_traces']} without "
          f"(one per tail length)")
    for s in (mono, chunked):
        s.pop("_generated")
    return out


# ----------------------------------------- prefix cache (part 5)
PC_STEP_DT = 1e-3           # one simulated engine step = 1 ms
PC_TOKEN_COST = 2e-5        # + 20 µs of virtual step time per prefilled token
PC_SYS_LEN = 64             # shared system prompt (8 full pages)
PC_CONVS = 3
PC_TURNS = 3
PC_CHUNK = 16
PC_PAGE = 8


def _run_prefix_cache_arm(cfg, params, *, cache: bool):
    """Drive PC_TURNS turns of PC_CONVS conversations over one shared
    system prompt: turn k+1's prompt is turn k's prompt + generated reply
    + a fresh user message, submitted as one drive_simulated episode per
    turn on a persistent engine (the cache lives across episodes).  The
    user messages and arrival jitter come from a fixed seed, so both arms
    see the identical workload; the replies are whatever the engine
    generates — asserted identical across arms by the caller."""
    clock = VirtualClock()
    eng = ServingEngine(
        [EngineModel("base", params, cfg, kv_slots=PC_CONVS + 1,
                     max_seq=64, kv_layout="paged", page_size=PC_PAGE,
                     n_pages=128, prefix_cache=cache)],
        sched=SchedulerConfig(max_prefill_per_step=2,
                              prefill_token_budget=PC_CHUNK),
        clock=clock, prefill_chunk=PC_CHUNK)
    rng = np.random.default_rng(11)
    sys_prefix = rng.integers(1, cfg.vocab, PC_SYS_LEN).tolist()
    hist = {c: list(sys_prefix) for c in range(PC_CONVS)}
    for turn in range(PC_TURNS):
        jobs = []
        for c in range(PC_CONVS):
            arrival = clock.t + float(rng.exponential(2.0)) * PC_STEP_DT
            gen = int(rng.integers(6, 10))
            jobs.append((arrival, "base", list(hist[c]), gen))
        rid_start = eng._next_rid
        drive_simulated(
            eng, clock, jobs, dt=PC_STEP_DT,
            step_dt=lambda rec: (PC_STEP_DT
                                 + PC_TOKEN_COST * rec.prefill_tokens))
        # rids are handed out in submission (= sorted arrival) order; map
        # them back to conversations through that order — prompts alone
        # cannot disambiguate turn 0, where every conversation submits the
        # bare system prompt
        order = sorted(range(PC_CONVS), key=lambda c: jobs[c][0])
        for i, c in enumerate(order):
            req = eng.requests[rid_start + i]
            assert list(req.prompt) == hist[c], "conversation map slipped"
            hist[c] = hist[c] + list(req.generated) + rng.integers(
                1, cfg.vocab, int(rng.integers(8, 17))).tolist()
    summary = eng.summary(clock.t)
    summary["_generated"] = {rid: list(r.generated)
                            for rid, r in eng.requests.items()}
    return summary


def prefix_cache_bench() -> dict:
    print("\n== Radix-tree prefix cache "
          "(shared system prompt, multi-turn, virtual clock) ==")
    cfg = get_config("gemma-7b", smoke=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    out = {}
    for cache in (False, True):
        tag = "cache-on" if cache else "cache-off"
        s = _run_prefix_cache_arm(cfg, params, cache=cache)
        out[tag] = s
        csv_row(f"serving/prefix-{tag}", s["prefill_tokens"],
                f"hit_tokens={int(s['prefix_hit_tokens'])};"
                f"ttft_p95_ms={s['ttft_p95_s']*1e3:.1f};"
                f"steps={int(s['steps'])}")
        print(f"-- {tag}:")
        print(format_summary(s))
    off, on = out["cache-off"], out["cache-on"]
    assert on["_generated"] == off["_generated"], \
        "prefix cache changed decoded tokens"
    assert on["prefill_tokens"] < off["prefill_tokens"], \
        "cache-on arm must compute strictly fewer prefill tokens"
    assert on["ttft_p95_s"] < off["ttft_p95_s"], \
        "cache-on arm must strictly drop TTFT p95"
    print(f"-- shared {PC_SYS_LEN}-token system prompt, {PC_CONVS} "
          f"conversations × {PC_TURNS} turns: computed prefill tokens "
          f"{int(off['prefill_tokens'])} -> {int(on['prefill_tokens'])} "
          f"({int(on['prefix_hit_tokens'])} served from cache, "
          f"{on['prefix_hit_rate']:.0%} hit rate); ttft p95 "
          f"{off['ttft_p95_s']*1e3:.1f} -> {on['ttft_p95_s']*1e3:.1f} ms; "
          f"{int(on['kv_prefix_cached_pages'])} cached pages resident, "
          f"{int(on['kv_prefix_evictions'])} LRU evictions "
          f"(token-for-token identical)")
    for s in out.values():
        s.pop("_generated")
    return out


# ------------------------------------- component breakdown (part 6)
def component_breakdown(trace_out: str = "") -> dict:
    """Per-step component breakdown via the structured tracer, overlap on
    vs off on the part-3 workload.  Engine on a VirtualClock (identical,
    deterministic schedules across arms), tracer on the wall clock (real
    host seconds per component)."""
    print("\n== Per-step component breakdown "
          "(structured tracer, overlap on vs off) ==")
    cfg = get_config("gemma-7b", smoke=True)
    params_a = _checkpointify(init_params(jax.random.PRNGKey(0), cfg))
    params_b = perturbed_variant(params_a)
    jobs = _overlap_workload(cfg)
    bpt = _install_tick_bytes(cfg, params_a, params_b)

    # Warmup arm populates the shared jit caches so the component tables
    # compare scheduling overhead, not XLA compile time.
    _run_overlap_arm(cfg, params_a, params_b, jobs, overlap=False,
                     bytes_per_tick=bpt)

    arms = ("overlap-off", "overlap-on")
    out = {}
    for tag in arms:
        s = _run_overlap_arm(cfg, params_a, params_b, jobs,
                             overlap=(tag == "overlap-on"),
                             bytes_per_tick=bpt, tracer=Tracer())
        s.pop("_generated")
        out[tag] = s
        total = sum(v for k, v in s.items() if k.startswith("component_"))
        csv_row(f"serving/components-{tag}", total / max(s["steps"], 1) * 1e6,
                f"total_ms={total*1e3:.1f};steps={int(s['steps'])}")

    steps = {t: max(int(out[t]["steps"]), 1) for t in arms}
    print(f"{'component':<10}" + "".join(f"{t:>24}" for t in arms))
    print(f"{'':<10}" + f"{'total ms':>14} {'us/step':>9}" * len(arms))
    for comp in TRACE_COMPONENTS:
        vals = [out[t].get(f"component_{comp}_s", 0.0) for t in arms]
        if not any(vals):
            continue
        print(f"{comp:<10}" + "".join(
            f"{v*1e3:>14.2f} {v*1e6/steps[t]:>9.1f}"
            for t, v in zip(arms, vals)))
    print(f"-- host seconds per component (wall-clock tracer, identical "
          f"virtual-clock schedule per arm): overlap turns "
          f"{int(out['overlap-off']['install_stall_steps'])} token-less "
          f"install stall steps into "
          f"{int(out['overlap-on']['install_stall_steps'])}, finishing in "
          f"{int(out['overlap-on']['steps'])} vs "
          f"{int(out['overlap-off']['steps'])} steps")

    if trace_out:
        # Deterministic artifact: same workload, engine AND tracer on one
        # VirtualClock — byte-identical across runs, Perfetto-loadable.
        clock = VirtualClock()
        tracer = Tracer(clock=clock)
        _run_overlap_arm(cfg, params_a, params_b, jobs, overlap=True,
                         bytes_per_tick=bpt, tracer=tracer, clock=clock)
        tracer.export_chrome_trace(trace_out)
        out["trace_events"] = len(tracer.events)
        print(f"-- wrote deterministic Chrome trace "
              f"({len(tracer.events)} events) to {trace_out} — load in "
              "chrome://tracing or https://ui.perfetto.dev")
    return out


# -------------------------------------- wear & write energy (part 7)
WEAR_STEP_DT = 1e-3         # one simulated engine step = 1 ms
WEAR_N_PAGES = 48
WEAR_SYS_LEN = 16           # shared system prompt (2 full pages)


def _wear_workload(cfg, seed: int = 9, n: int = 14):
    """Two-tenant Poisson arrivals in virtual time behind one shared
    system prompt: tenant switches produce weight installs (the flip
    plane), prefix-cache hits produce avoided page writes (the KV
    plane)."""
    rng = np.random.default_rng(seed)
    sys_prefix = rng.integers(1, cfg.vocab, WEAR_SYS_LEN).tolist()
    t, jobs = 0.0, []
    for i in range(n):
        t += float(rng.exponential(2.0)) * WEAR_STEP_DT
        plen = int(rng.integers(3, 10))
        prompt = sys_prefix + rng.integers(1, cfg.vocab, plen).tolist()
        jobs.append((t, "base" if i % 2 == 0 else "variant", prompt,
                     int(rng.integers(6, 12))))
    return jobs


def _run_wear_arm(cfg, params_a, params_b, jobs, *, reuse: bool):
    """One wear arm: paged KV + prefix cache on both tenants, instant
    installs on a virtual clock — the schedule is identical across reuse
    arms (reuse only changes install accounting, decode runs on the
    full-precision params), so the energy comparison is apples to
    apples.  Returns (engine, summary) — the caller reads the wear map
    off the engine."""
    clock = VirtualClock()
    kv = dict(kv_slots=4, max_seq=64, kv_layout="paged",
              page_size=PAGE_SIZE, n_pages=WEAR_N_PAGES, prefix_cache=True)
    eng = ServingEngine(
        [EngineModel("base", params_a, cfg, **kv),
         EngineModel("variant", params_b, cfg, **kv)],
        weight_arena_slots=cfg.n_layers + 1,   # forces tenant swaps
        reuse=reuse,
        sched=SchedulerConfig(max_prefill_per_step=4,
                              model_turn_steps=TURN_STEPS),
        clock=clock)
    summary = drive_simulated(eng, clock, jobs, dt=WEAR_STEP_DT)
    summary["_generated"] = {r.rid: list(r.generated)
                             for r in eng.requests.values()}
    return eng, summary


def wear_energy_bench(wear_json: str = "") -> dict:
    print("\n== Wear & write energy "
          "(reuse on vs off, virtual clock, 2 tenants, paged KV) ==")
    cfg = get_config("gemma-7b", smoke=True)
    params_a = _checkpointify(init_params(jax.random.PRNGKey(0), cfg))
    params_b = perturbed_variant(params_a)
    jobs = _wear_workload(cfg)

    out = {}
    engines = {}
    for reuse in (False, True):
        tag = "reuse-on" if reuse else "reuse-off"
        eng, s = _run_wear_arm(cfg, params_a, params_b, jobs, reuse=reuse)
        engines[tag] = eng
        out[tag] = s
        csv_row(f"serving/wear-{tag}", s["install_energy_j"] * 1e6,
                f"flips={int(s['install_cell_flips'])};"
                f"pulses={int(s['install_write_pulses'])};"
                f"kv_writes={int(s['kv_page_writes'])}")
        print(f"-- {tag}:")
        print(format_summary(s))
    off, on = out["reuse-off"], out["reuse-on"]
    assert on["_generated"] == off["_generated"], \
        "reuse changed decoded tokens"
    assert on["steps"] == off["steps"], "reuse changed the schedule"
    assert on["install_energy_j"] < off["install_energy_j"], \
        "§V-C equal-skip install must spend strictly less write energy"
    print(f"-- same schedule ({int(on['steps'])} steps, token-for-token "
          f"identical): install write energy "
          f"{off['install_energy_j']*1e3:.3f} -> "
          f"{on['install_energy_j']*1e3:.3f} mJ "
          f"({1 - on['install_energy_j']/off['install_energy_j']:.1%} "
          f"saved by §V-C equal-skip), KV page writes "
          f"{int(on['kv_page_writes'])} "
          f"({int(on['kv_page_writes_avoided'])} avoided via shared "
          f"prefixes, {on['kv_write_energy_j']*1e3:.3f} mJ); wear gini "
          f"weight {on['wear_gini_weight']:.3f}, kv {on['wear_gini_kv']:.3f}")
    if wear_json:
        with open(wear_json, "w") as f:
            json.dump(engines["reuse-on"].wear.as_json(), f, indent=2,
                      sort_keys=True)
            f.write("\n")
        print(f"-- wrote reuse-on wear map to {wear_json}")
    for s in out.values():
        s.pop("_generated")
    return out


# --------------------- wear-aware placement & fault sweep (part 8)
FAULT_SWEEP_RATES = (0.005, 0.01, 0.02)
# seed chosen so the 2% arm faults at least one weight slot and one KV
# page on this workload (the 0/0.5/1% arms may legitimately stay clean)
FAULT_SEED = 100


def _run_fault_arm(cfg, params_a, params_b, jobs, *, wear_aware=0.0,
                   fault_rate=0.0, fault_seed=0, spare_slots=1):
    """One placement/fault arm over the part-7 workload shape: paged KV
    + prefix cache, instant installs on a virtual clock.  Neither knob
    may move the decoded tokens — wear-aware placement only re-ranks
    eviction victims and free pages (installs are bookkeeping; decode
    runs on full-precision params), and a surviving fault remaps the
    write to a healthy unit with identical contents.

    `spare_slots=1` forces tenant swaps (the wear arms need traffic to
    steer); the fault arms run with 3 — room for both tenants to decode
    concurrently plus one retirement — so a weight-slot fault remaps to
    a healthy slot instead of exhausting the arena.  Endurance headroom
    is a provisioning decision: a stuck-at slot is capacity permanently
    gone."""
    clock = VirtualClock()
    kv = dict(kv_slots=4, max_seq=64, kv_layout="paged",
              page_size=PAGE_SIZE, n_pages=WEAR_N_PAGES, prefix_cache=True)
    eng = ServingEngine(
        [EngineModel("base", params_a, cfg, **kv),
         EngineModel("variant", params_b, cfg, **kv)],
        weight_arena_slots=cfg.n_layers + spare_slots,
        reuse=True,
        sched=SchedulerConfig(max_prefill_per_step=4,
                              model_turn_steps=TURN_STEPS),
        clock=clock, wear_aware=wear_aware,
        fault_rate=fault_rate, fault_seed=fault_seed)
    summary = drive_simulated(eng, clock, jobs, dt=WEAR_STEP_DT)
    summary["_generated"] = {r.rid: list(r.generated)
                             for r in eng.requests.values()}
    return eng, summary


def fault_wear_bench() -> dict:
    print("\n== Wear-aware placement & stuck-at fault sweep "
          "(virtual clock, 2 tenants, paged KV) ==")
    cfg = get_config("gemma-7b", smoke=True)
    params_a = _checkpointify(init_params(jax.random.PRNGKey(0), cfg))
    params_b = perturbed_variant(params_a)
    jobs = _wear_workload(cfg)

    out = {}
    # -- wear-aware placement: identical schedule, flatter write spread
    for weight in (0.0, 1.0):
        tag = "wear-on" if weight else "wear-off"
        _, s = _run_fault_arm(cfg, params_a, params_b, jobs,
                              wear_aware=weight)
        out[tag] = s
        csv_row(f"serving/faults-{tag}", s["wear_gini_weight"],
                f"gini_kv={s['wear_gini_kv']:.3f};"
                f"flips={int(s['install_cell_flips'])};"
                f"installs={int(s['installs'])}")
        print(f"-- {tag}:")
        print(format_summary(s))
    off, on = out["wear-off"], out["wear-on"]
    assert on["_generated"] == off["_generated"], \
        "wear-aware placement changed decoded tokens"
    assert on["steps"] == off["steps"], \
        "wear-aware placement changed the schedule"
    assert on["wear_gini_weight"] < off["wear_gini_weight"], \
        "wear blend must strictly flatten the weight plane's write spread"
    print(f"-- same schedule ({int(on['steps'])} steps, token-for-token "
          f"identical): weight-plane wear gini "
          f"{off['wear_gini_weight']:.3f} -> {on['wear_gini_weight']:.3f} "
          f"with the wear-aware victim/free-page blend on")

    # -- fault sweep 0 -> 2%: token-equivalent with survivals logged.
    # The sweep's own rate-0 arm is the baseline (same arena shape).
    for rate in (0.0,) + FAULT_SWEEP_RATES:
        tag = f"fault-{rate:g}"
        _, s = _run_fault_arm(cfg, params_a, params_b, jobs,
                              fault_rate=rate, fault_seed=FAULT_SEED,
                              spare_slots=3)
        out[tag] = s
        assert s["requests_finished"] == len(jobs), \
            f"rate {rate:g}: a request never finished"
        assert s["_generated"] == out["fault-0"]["_generated"], \
            f"rate {rate:g}: a surviving fault changed decoded tokens"
        assert s["faults_survived"] == \
            s["slots_retired"] + s["pages_retired"]
        csv_row(f"serving/{tag}", s["faults_survived"],
                f"slots_retired={int(s['slots_retired'])};"
                f"pages_retired={int(s['pages_retired'])}")
    assert out["fault-0"]["faults_survived"] == 0
    top = out[f"fault-{FAULT_SWEEP_RATES[-1]:g}"]
    assert top["faults_survived"] > 0, \
        "sweep never injected a fault — seed/rate too conservative"
    print(f"-- fault sweep 0 -> {FAULT_SWEEP_RATES[-1]:.1%} token-"
          f"equivalent: " + ", ".join(
              f"{r:.1%}: {int(out[f'fault-{r:g}']['faults_survived'])} "
              f"survived ({int(out[f'fault-{r:g}']['slots_retired'])} "
              f"slots, {int(out[f'fault-{r:g}']['pages_retired'])} pages "
              f"retired)" for r in FAULT_SWEEP_RATES))
    for s in out.values():
        s.pop("_generated")
    return out


# ------------------- kernel backend & fused sampling (part 9)
KB_STEP_DT = 1e-3           # one simulated engine step = 1 ms
KB_PAGE = 4
KB_N_PAGES = 64
KB_SYS_LEN = 8              # shared system prompt (2 full pages)


def _kernel_workload(cfg, seed: int = 13, n: int = 12):
    """One-tenant Poisson arrivals behind a shared system prompt, a third
    of them sampled (fixed seed or rid-derived key) so the fused sampler
    sees greedy, top-k, and plain-temperature rows in the same batch.
    Jobs carry per-request sampling kwargs, so this part drives its own
    arrival loop instead of `drive_simulated`."""
    rng = np.random.default_rng(seed)
    sys_prefix = rng.integers(1, cfg.vocab, KB_SYS_LEN).tolist()
    t, jobs = 0.0, []
    for i in range(n):
        t += float(rng.exponential(2.0)) * KB_STEP_DT
        plen = int(rng.integers(3, 10))
        prompt = sys_prefix + rng.integers(1, cfg.vocab, plen).tolist()
        if i % 3 == 1:
            kw = dict(temperature=0.8, top_k=9, seed=100 + i)
        elif i % 3 == 2:
            kw = dict(temperature=1.1)       # key derives from the rid
        else:
            kw = {}
        jobs.append((t, "base", prompt, int(rng.integers(6, 12)), kw))
    return jobs


def _run_kernel_arm(cfg, params, jobs, *, backend: str, fuse: bool):
    clock = VirtualClock()
    eng = ServingEngine(
        [EngineModel("base", params, cfg, kv_slots=4, max_seq=48,
                     kv_layout="paged", page_size=KB_PAGE,
                     n_pages=KB_N_PAGES, prefix_cache=True,
                     kernel_backend=backend)],
        sched=SchedulerConfig(max_prefill_per_step=2),
        clock=clock, tracer=Tracer(),
        fuse_sampling=fuse, kernel_interpret=True)
    pending = sorted((t, i) for i, (t, *_rest) in enumerate(jobs))
    for _ in range(100_000):
        if not pending and not eng.has_work():
            break
        while pending and pending[0][0] <= clock.t:
            _, i = pending.pop(0)
            _, model, prompt, gen, kw = jobs[i]
            eng.submit(model, prompt, max_new_tokens=gen, **kw)
        if eng.has_work():
            eng.step()
        clock.advance(KB_STEP_DT)
    else:
        raise RuntimeError("part-9 arm did not drain — engine livelock?")
    summary = eng.summary(clock.t)
    summary["_generated"] = {r.rid: list(r.generated)
                             for r in eng.requests.values()}
    summary["sample_syncs_max"] = max(
        (rec.sample_syncs for rec in eng.metrics.steps if rec.n_decoded),
        default=0)
    return summary


def kernel_backend_bench() -> dict:
    print("\n== Kernel backend & fused sampling "
          "(virtual clock, XLA vs Pallas-interpret, split vs fused) ==")
    cfg = get_config("gemma-7b", smoke=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    jobs = _kernel_workload(cfg)

    arms = {"xla-split": ("xla", False),
            "xla-fused": ("xla", True),
            "pallas-fused": ("pallas", True)}
    out = {}
    for tag, (backend, fuse) in arms.items():
        _run_kernel_arm(cfg, params, jobs, backend=backend,
                        fuse=fuse)                          # jit warmup
        s = _run_kernel_arm(cfg, params, jobs, backend=backend, fuse=fuse)
        out[tag] = s
        csv_row(f"serving/kernel-{tag}",
                s.get("component_decode_s", 0.0) * 1e3,
                f"sample_ms={s.get('component_sample_s', 0.0)*1e3:.2f};"
                f"syncs_max={int(s['sample_syncs_max'])};"
                f"steps={int(s['steps'])}")
        print(f"-- {tag}:")
        print(format_summary(s))

    base = out["xla-split"]
    assert out["xla-fused"]["_generated"] == base["_generated"], \
        "fused sampling changed decoded tokens"
    assert out["pallas-fused"]["_generated"] == base["_generated"], \
        "pallas kernel backend changed decoded tokens"
    for tag, s in out.items():
        assert s["steps"] == base["steps"], f"{tag} changed the schedule"
        assert s["sample_syncs_max"] <= 1, \
            f"{tag}: sampling cost more than one host sync per step"
        assert s.get("component_sample_s", 0.0) > 0.0, \
            f"{tag}: tracer recorded no sample spans"
    out["tokens_identical_fused"] = 1
    out["tokens_identical_pallas"] = 1

    tags = list(arms)
    steps = {t: max(int(out[t]["steps"]), 1) for t in tags}
    print(f"{'component':<10}" + "".join(f"{t:>24}" for t in tags))
    print(f"{'':<10}" + f"{'total ms':>14} {'us/step':>9}" * len(tags))
    for comp in TRACE_COMPONENTS:
        vals = [out[t].get(f"component_{comp}_s", 0.0) for t in tags]
        if not any(vals):
            continue
        print(f"{comp:<10}" + "".join(
            f"{v*1e3:>14.2f} {v*1e6/steps[t]:>9.1f}"
            for t, v in zip(tags, vals)))
    print(f"-- token-for-token identical across all three arms over "
          f"{int(base['steps'])} steps; sampling host syncs per decoded "
          f"step: " + ", ".join(
              f"{t}={int(out[t]['sample_syncs_max'])}" for t in tags) +
          " (the legacy path paid one sync per row)")
    for tag in arms:
        out[tag].pop("_generated")
    return out


# ----------------------- live telemetry plane overhead (part 10)
def _run_telemetry_arm(cfg, params_a, params_b, jobs, *,
                       telemetry: bool, out_dir: str):
    """One telemetry arm over the part-7 workload shape.  The telemetry
    arm turns EVERYTHING on — windowed percentiles, an (intentionally
    breaching) SLO tracker, the JSONL event stream, the flight recorder,
    and the step watchdog — the off arm is the stock engine.  Same
    virtual-clock schedule both ways, so the decoded tokens must match
    bit for bit; the wall `time.perf_counter` around the drive is the
    honest host cost (the engine's own wall_s is virtual here)."""
    clock = VirtualClock()
    kv = dict(kv_slots=4, max_seq=64, kv_layout="paged",
              page_size=PAGE_SIZE, n_pages=WEAR_N_PAGES, prefix_cache=True)
    kwargs = {}
    if telemetry:
        # ITL target of half a step: guaranteed to burn, so the bench
        # exercises breach -> trace instant -> flight dump every run
        kwargs = dict(
            telemetry=TelemetryConfig(
                window=64,
                slo=SLOConfig(itl_p95_s=WEAR_STEP_DT / 2),
                events_path=os.path.join(out_dir, "events.jsonl")),
            recorder=FlightRecorder(64, out_dir=out_dir),
            stall_timeout_s=300.0)
    eng = ServingEngine(
        [EngineModel("base", params_a, cfg, **kv),
         EngineModel("variant", params_b, cfg, **kv)],
        weight_arena_slots=cfg.n_layers + 1,
        sched=SchedulerConfig(max_prefill_per_step=4,
                              model_turn_steps=TURN_STEPS),
        clock=clock, **kwargs)
    t0 = time.perf_counter()
    summary = drive_simulated(eng, clock, jobs, dt=WEAR_STEP_DT)
    host_s = time.perf_counter() - t0
    summary["_generated"] = {r.rid: list(r.generated)
                             for r in eng.requests.values()}
    return eng, summary, host_s


def telemetry_bench(telemetry_dir: str = "") -> dict:
    print("\n== Live telemetry plane "
          "(off vs windows+SLO+recorder+watchdog, identical schedule) ==")
    import tempfile

    cfg = get_config("gemma-7b", smoke=True)
    params_a = _checkpointify(init_params(jax.random.PRNGKey(0), cfg))
    params_b = perturbed_variant(params_a)
    jobs = _wear_workload(cfg)
    out_dir = telemetry_dir or tempfile.mkdtemp(prefix="telemetry-bench-")
    os.makedirs(out_dir, exist_ok=True)

    # warmup arm: pay the jit compiles outside the timed comparison
    _run_telemetry_arm(cfg, params_a, params_b, jobs, telemetry=False,
                       out_dir=out_dir)
    eng_off, off, host_off = _run_telemetry_arm(
        cfg, params_a, params_b, jobs, telemetry=False, out_dir=out_dir)
    eng_on, on, host_on = _run_telemetry_arm(
        cfg, params_a, params_b, jobs, telemetry=True, out_dir=out_dir)

    assert on["_generated"] == off["_generated"], \
        "telemetry changed decoded tokens"
    assert on["steps"] == off["steps"], "telemetry changed the schedule"
    steps = int(on["steps"])
    overhead_us = max(host_on - host_off, 0.0) / max(steps, 1) * 1e6
    # the ratio is what the regression gate watches: on this class of
    # host the absolute delta is noise-dominated (and can clamp to 0,
    # which would make a relative-tolerance gate a zero ceiling), while
    # on/off is always positive and ~1 unless a hook lands on the
    # decode path
    overhead_ratio = host_on / max(host_off, 1e-9)

    # the artifacts the on arm produced, validated in-process
    prom = prometheus_text(eng_on.metrics.registry, eng_on.telemetry)
    prom_errors = validate_prometheus_text(prom)
    assert not prom_errors, f"invalid Prometheus exposition: {prom_errors}"
    events_path = os.path.join(out_dir, "events.jsonl")
    eng_on.telemetry.close()
    with open(events_path, encoding="utf-8") as f:
        events_text = f.read()
    events_errors = validate_events_jsonl(events_text)
    assert not events_errors, f"invalid events JSONL: {events_errors}"
    events_lines = len(events_text.splitlines())
    health = eng_on.health()
    assert health["ok"] is False, \
        "the intentionally-tight ITL SLO must be breached"
    assert eng_on.recorder.dumps, "SLO breach must leave a flight dump"

    for tag, s, host_s in (("telemetry-off", off, host_off),
                           ("telemetry-on", on, host_on)):
        csv_row(f"serving/{tag}", host_s / max(steps, 1) * 1e6,
                f"steps={steps}")
        print(f"-- {tag}: host {host_s*1e3:.1f} ms over {steps} steps "
              f"({host_s/max(steps,1)*1e6:.0f} us/step)")
    print(format_summary(on))
    print(f"-- token-for-token identical over {steps} steps; telemetry "
          f"host overhead {overhead_us:.0f} us/step; "
          f"{events_lines} JSONL events, "
          f"{len(eng_on.recorder.dumps)} flight dump(s) "
          f"({', '.join(os.path.basename(p) for p in eng_on.recorder.dumps)}), "
          f"prom exposition {len(prom.splitlines())} lines (valid)")
    if telemetry_dir:
        with open(os.path.join(out_dir, "prom.txt"), "w") as f:
            f.write(prom)
        with open(os.path.join(out_dir, "health.json"), "w") as f:
            json.dump(_json_safe(health), f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"-- wrote prom.txt / health.json / events.jsonl / flight "
              f"dumps to {out_dir}")
    for s in (off, on):
        s.pop("_generated")
    return {
        "telemetry-off": off, "telemetry-on": on,
        "host_s_off": host_off, "host_s_on": host_on,
        "overhead_us_per_step": overhead_us,
        "host_overhead_ratio": overhead_ratio,
        "tokens_identical": 1.0,
        "events_lines": float(events_lines),
        "flight_dumps": float(len(eng_on.recorder.dumps)),
    }


# ------------------------------------------------- headline persistence
_DEFAULT_OUT = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_serving.json")


def _json_safe(obj):
    """NaN/inf -> None recursively, so the dump is strict JSON."""
    if isinstance(obj, dict):
        return {k: _json_safe(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_json_safe(v) for v in obj]
    if isinstance(obj, float) and not np.isfinite(obj):
        return None
    return obj


def _headlines(results: dict) -> dict:
    """Compress each part's summaries to its headline numbers."""
    h = {}
    t = results.get("tenants")
    if t:
        h["tenants"] = {
            "latency_p50_s": t["reuse-on"]["latency_p50_s"],
            "latency_p95_s": t["reuse-on"]["latency_p95_s"],
            "tokens_per_s": t["reuse-on"]["tokens_per_s"],
            "wire_saved_frac": t["wire_saved_frac"],
        }
    lay = results.get("layout")
    if lay:
        h["layout"] = {
            "slot_max_concurrent": lay["slot"]["max_concurrent"],
            "paged_max_concurrent": lay["paged"]["max_concurrent"],
            "paged_pages_saved": lay["paged"]["kv_pages_saved"],
            "slot_latency_p50_s": lay["slot"]["latency_p50_s"],
            "paged_latency_p50_s": lay["paged"]["latency_p50_s"],
        }
    ov = results.get("overlap")
    if ov:
        h["overlap"] = {
            "stall_steps_sync": ov["overlap-off"]["install_stall_steps"],
            "stall_steps_overlap": ov["overlap-on"]["install_stall_steps"],
            "itl_max_p95_s_sync": ov["overlap-off"]["itl_max_p95_s"],
            "itl_max_p95_s_overlap": ov["overlap-on"]["itl_max_p95_s"],
            "ttft_p95_s_overlap": ov["overlap-on"]["ttft_p95_s"],
            "hidden_bytes": ov["overlap-on"]["overlap_hidden_bytes"],
        }
    ch = results.get("chunked")
    if ch:
        h["chunked"] = {
            "itl_max_p95_s_mono": ch["chunk-off"]["itl_max_p95_s"],
            "itl_max_p95_s_chunked": ch["chunk-on"]["itl_max_p95_s"],
            "ttft_p95_s_chunked": ch["chunk-on"]["ttft_p95_s"],
            "traces_bucket_on": ch["bucket-on_traces"],
            "traces_bucket_off": ch["bucket-off_traces"],
        }
    pc = results.get("prefix_cache")
    if pc:
        h["prefix_cache"] = {
            "prefill_tokens_off": pc["cache-off"]["prefill_tokens"],
            "prefill_tokens_on": pc["cache-on"]["prefill_tokens"],
            "prefix_hit_rate": pc["cache-on"]["prefix_hit_rate"],
            "ttft_p95_s_off": pc["cache-off"]["ttft_p95_s"],
            "ttft_p95_s_on": pc["cache-on"]["ttft_p95_s"],
        }
    w = results.get("wear")
    if w:
        h["wear"] = {
            "install_energy_j_off": w["reuse-off"]["install_energy_j"],
            "install_energy_j_on": w["reuse-on"]["install_energy_j"],
            "install_cell_flips_on": w["reuse-on"]["install_cell_flips"],
            "kv_write_energy_j": w["reuse-on"]["kv_write_energy_j"],
            "kv_page_writes": w["reuse-on"]["kv_page_writes"],
            "kv_page_writes_avoided":
                w["reuse-on"]["kv_page_writes_avoided"],
            "wear_gini_weight": w["reuse-on"]["wear_gini_weight"],
            "wear_gini_kv": w["reuse-on"]["wear_gini_kv"],
        }
    fl = results.get("faults")
    if fl:
        top = fl[f"fault-{FAULT_SWEEP_RATES[-1]:g}"]
        h["faults"] = {
            "wear_gini_weight_off": fl["wear-off"]["wear_gini_weight"],
            "wear_gini_weight_on": fl["wear-on"]["wear_gini_weight"],
            "faults_survived": top["faults_survived"],
            "slots_retired": top["slots_retired"],
            "pages_retired": top["pages_retired"],
            "steps": fl["wear-on"]["steps"],
        }
    kb = results.get("kernel")
    if kb:
        h["kernel"] = {
            "tokens_identical_fused": kb["tokens_identical_fused"],
            "tokens_identical_pallas": kb["tokens_identical_pallas"],
            "sample_syncs_max_split": kb["xla-split"]["sample_syncs_max"],
            "sample_syncs_max_fused": kb["xla-fused"]["sample_syncs_max"],
            "sample_syncs_max_pallas": kb["pallas-fused"]["sample_syncs_max"],
            "steps": kb["pallas-fused"]["steps"],
        }
        # wall-clock component seconds per arm: reported, never gated
        for tag in ("xla-split", "xla-fused", "pallas-fused"):
            h["kernel"][f"decode_s_{tag}"] = \
                kb[tag].get("component_decode_s", 0.0)
            h["kernel"][f"sample_s_{tag}"] = \
                kb[tag].get("component_sample_s", 0.0)
    tel = results.get("telemetry")
    if tel:
        h["telemetry"] = {
            # the identity bit and schedule length are deterministic and
            # gated at tolerance 0; the host overhead ratio is wall-clock
            # and gated only as a generous ceiling (us/step is reported
            # but ungated: the delta is noise on shared CI hosts)
            "tokens_identical": tel["tokens_identical"],
            "steps": tel["telemetry-on"]["steps"],
            "overhead_us_per_step": tel["overhead_us_per_step"],
            "host_overhead_ratio": tel["host_overhead_ratio"],
            "events_lines": tel["events_lines"],
            "flight_dumps": tel["flight_dumps"],
            "ttft_p95_s": tel["telemetry-on"]["ttft_p95_s"],
            "itl_max_p95_s": tel["telemetry-on"]["itl_max_p95_s"],
        }
    comp = results.get("components")
    if comp:
        h["components"] = {
            tag: {k: v for k, v in comp[tag].items()
                  if k.startswith("component_")}
            for tag in ("overlap-off", "overlap-on") if tag in comp}
        if "trace_events" in comp:
            h["components"]["trace_events"] = comp["trace_events"]
    return h


def _write_bench_json(path: str, headlines: dict) -> None:
    doc = {"bench": "serving", "arch": "gemma-7b(smoke)",
           "parts": headlines}
    with open(path, "w") as f:
        json.dump(_json_safe(doc), f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"\nwrote per-part headline numbers to {path}")


def tenant_reuse_bench() -> dict:
    print("\n== Continuous-batching serving engine (Poisson, 2 tenants) ==")
    cfg = get_config("gemma-7b", smoke=True)
    # _checkpointify injects the asymmetric outlier tails real checkpoints
    # have (random inits quantize already-centered, hiding §V-C).
    params_a = _checkpointify(init_params(jax.random.PRNGKey(0), cfg))
    params_b = perturbed_variant(params_a)
    jobs = _workload()

    # Warmup arm over the full workload populates the shared jit caches
    # (every prompt length) so timed arms compare scheduling, not XLA.
    _run_arm(cfg, params_a, params_b, jobs, reuse=True)

    out = {}
    for reuse in (False, True):
        tag = "reuse-on" if reuse else "reuse-off"
        s = _run_arm(cfg, params_a, params_b, jobs, reuse=reuse)
        out[tag] = s
        csv_row(f"serving/{tag}", s["latency_p50_s"] * 1e6,
                f"p95_us={s['latency_p95_s']*1e6:.0f};"
                f"tok_s={s['tokens_per_s']:.1f};"
                f"wire_mb={s['install_wire_bytes']/1e6:.3f};"
                f"installs={int(s['installs'])}")
        print(f"-- {tag}:")
        print(format_summary(s))
    # Install counts are wall-clock dependent (Poisson arrivals vs real
    # turn boundaries), so compare wire bytes per byte of installed
    # weights, not absolute MB across arms.
    saved = out["reuse-on"]["install_savings"]
    print(f"-- cross-tenant §V-C reuse ships {saved:.1%} fewer wire bytes "
          f"per installed weight byte (reuse-off ships raw by definition); "
          f"absolute: {out['reuse-off']['install_wire_bytes']/1e6:.2f} MB "
          f"over {int(out['reuse-off']['installs'])} installs vs "
          f"{out['reuse-on']['install_wire_bytes']/1e6:.2f} MB over "
          f"{int(out['reuse-on']['installs'])}")
    out["wire_saved_frac"] = saved
    return out


def main(argv=None) -> dict:
    p = argparse.ArgumentParser(description="serving-engine benchmarks")
    p.add_argument("--parts", default="1,2,3,4,5,6,7,8,9,10",
                   help="comma-separated parts to run: 1 tenant reuse, "
                        "2 paged-vs-slot, 3 install overlap, 4 chunked "
                        "prefill, 5 prefix cache, 6 component breakdown, "
                        "7 wear & write energy, 8 wear-aware placement "
                        "& fault sweep, 9 kernel backend & fused "
                        "sampling, 10 live telemetry plane overhead")
    p.add_argument("--out", default=_DEFAULT_OUT,
                   help="path for the BENCH_serving.json headline dump "
                        "('' disables)")
    p.add_argument("--trace-out", default="",
                   help="part 6: also write the deterministic virtual-clock "
                        "Chrome trace to this path")
    p.add_argument("--wear-json", default="",
                   help="part 7: also write the reuse-on arm's per-plane "
                        "wear map (writes/flips/pulses per slot and page) "
                        "to this path")
    p.add_argument("--telemetry-dir", default="",
                   help="part 10: keep the telemetry-on arm's artifacts "
                        "(events.jsonl, prom.txt, health.json, flight "
                        "dumps) in this directory instead of a tempdir")
    args = p.parse_args(argv)
    parts = sorted({int(x) for x in args.parts.split(",") if x.strip()})

    results = {}
    if 1 in parts:
        results["tenants"] = tenant_reuse_bench()
    if 2 in parts:
        results["layout"] = paged_vs_slot()
    if 3 in parts:
        results["overlap"] = overlap_vs_sync()
    if 4 in parts:
        results["chunked"] = chunked_prefill_bench()
    if 5 in parts:
        results["prefix_cache"] = prefix_cache_bench()
    if 6 in parts:
        results["components"] = component_breakdown(args.trace_out)
    if 7 in parts:
        results["wear"] = wear_energy_bench(args.wear_json)
    if 8 in parts:
        results["faults"] = fault_wear_bench()
    if 9 in parts:
        results["kernel"] = kernel_backend_bench()
    if 10 in parts:
        results["telemetry"] = telemetry_bench(args.telemetry_dir)
    if args.out:
        _write_bench_json(args.out, _headlines(results))
    return results


if __name__ == "__main__":
    main()
