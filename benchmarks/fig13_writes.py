"""Fig 13: normalized ReRAM writing activity (total programming pulses) of
ARAS_BRW vs the unoptimized baseline.  Paper: −17% on average."""
from __future__ import annotations

import numpy as np

from benchmarks.common import PAPER_NETS, csv_row, run_variant


def main() -> dict:
    ratios = {}
    print("\n== Fig 13: normalized ReRAM writing activity (pulses) ==")
    for net in PAPER_NETS:
        base = run_variant(net, "baseline")
        brw = run_variant(net, "BRW")
        ratio = brw.total_pulses / base.total_pulses
        ratios[net] = ratio
        csv_row(f"fig13/{net}", brw.makespan_s * 1e6,
                f"pulse_ratio={ratio:.3f};center={brw.reuse_center}")
    avg = float(np.mean(list(ratios.values())))
    csv_row("fig13/average", 0.0, f"pulse_ratio={avg:.3f};paper=0.83")
    print(f"-- average pulse ratio {avg:.3f} (paper: 0.83 → −17%)")
    return ratios


if __name__ == "__main__":
    main()
