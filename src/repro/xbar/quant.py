"""INT8 uniform quantization with zero-point compensation (paper Eq. 6-7).

The paper stores weights as unsigned INT8 codes ``w_q = round(q_w * w_f) - zp_w``
laid out over four 2-bit ReRAM cells.  The §V-C re-encoding adds an ``Offset``
to every code of a layer so the code distribution is centered on a common
``Center``; the *same* offset is subtracted from the zero point used at
de-quantization, so the floating-point dot product is bit-exact unchanged
(up to clipping of codes that leave [0, 255]).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

UINT_MAX = 255


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class QuantParams:
    """Uniform affine quantization parameters for one tensor.

    code = clip(round(w / scale) + zero_point, 0, 255)
    w̃   = (code - zero_point) * scale
    """

    scale: jax.Array      # f32 scalar (or per-channel vector)
    zero_point: jax.Array  # f32, same shape as scale

    def shifted(self, offset: jax.Array) -> "QuantParams":
        """Compensate a code-domain shift by ``offset`` (Eq. 7's zp_w - Offset)."""
        return QuantParams(self.scale, self.zero_point + offset)


def quantize_tensor(w: jax.Array, axis=None) -> Tuple[jax.Array, QuantParams]:
    """Symmetric-range uniform quantization of ``w`` to uint8 codes.

    ``axis``: None for per-tensor, or an int/tuple for per-channel params
    (reduction is performed over the *other* axes).
    """
    if axis is None:
        lo = jnp.min(w)
        hi = jnp.max(w)
    else:
        axes = tuple(i for i in range(w.ndim) if i != axis)
        lo = jnp.min(w, axis=axes, keepdims=True)
        hi = jnp.max(w, axis=axes, keepdims=True)
    # Guard degenerate range.
    scale = jnp.maximum(hi - lo, 1e-8) / UINT_MAX
    zero_point = -lo / scale  # code for w == 0.0 ... (affine: code = w/scale + zp)
    code = jnp.clip(jnp.round(w / scale + zero_point), 0, UINT_MAX).astype(jnp.uint8)
    return code, QuantParams(scale=scale, zero_point=zero_point)


def quantize(w: jax.Array, params: QuantParams) -> jax.Array:
    return jnp.clip(
        jnp.round(w / params.scale + params.zero_point), 0, UINT_MAX
    ).astype(jnp.uint8)


def dequantize(code: jax.Array, params: QuantParams) -> jax.Array:
    return (code.astype(jnp.float32) - params.zero_point) * params.scale


def shift_weights(code: jax.Array, center: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Paper Eq. 4-5: shift all codes of a layer so their mean lands on ``center``.

    Returns (new_code, offset).  Codes are clipped to [0, 255]; the caller is
    responsible for compensating ``offset`` in the zero point (Eq. 7) and for
    checking the clip rate (accuracy proxy).
    """
    offset = jnp.round(center - jnp.mean(code.astype(jnp.float32)))
    new_code = jnp.clip(code.astype(jnp.int32) + offset.astype(jnp.int32), 0, UINT_MAX)
    return new_code.astype(jnp.uint8), offset


def clip_rate(code: jax.Array, offset: jax.Array) -> jax.Array:
    """Fraction of codes that saturate when shifted by ``offset`` (accuracy proxy)."""
    shifted = code.astype(jnp.int32) + offset.astype(jnp.int32)
    return jnp.mean(((shifted < 0) | (shifted > UINT_MAX)).astype(jnp.float32))


def dot_int8(
    x_code: jax.Array,
    w_code: jax.Array,
    x_params: QuantParams,
    w_params: QuantParams,
    bias: jax.Array | None = None,
) -> jax.Array:
    """De-quantized dot product (paper Eq. 7), pure-jnp reference.

    ``x_code``: (..., K) uint8 activations; ``w_code``: (K, N) uint8 weights.
    Computes yf = sum_k (x - zp_x)*sx * (w - zp_w)*sw + b using integer
    accumulation plus the standard zero-point correction terms — exactly the
    arithmetic a TPU-native INT8 path performs, and the identity under which
    the §V-C weight shift is free (Offset folded into zp_w).
    """
    xi = x_code.astype(jnp.int32)
    wi = w_code.astype(jnp.int32)
    acc = jnp.matmul(xi, wi, preferred_element_type=jnp.int32)
    k = x_code.shape[-1]
    # Zero-point corrections: (x - zpx)·(w - zpw) = xw - zpw·Σx - zpx·Σw + K·zpx·zpw
    sum_x = jnp.sum(xi, axis=-1, keepdims=True).astype(jnp.float32)
    sum_w = jnp.sum(wi, axis=0, keepdims=True).astype(jnp.float32)
    zpx = x_params.zero_point
    zpw = w_params.zero_point
    y = (
        acc.astype(jnp.float32)
        - zpw * sum_x
        - zpx * sum_w
        + k * zpx * zpw
    ) * (x_params.scale * w_params.scale)
    if bias is not None:
        y = y + bias
    return y
