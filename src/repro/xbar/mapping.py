"""Mapping DNN layers onto ReRAM crossbars (paper §II-C, Fig 3).

Each CONV kernel is unrolled into one crossbar *column group*: a kernel of
volume V = R·S·C occupies ceil(V / xbar_rows) vertically-stacked crossbars;
each INT8 weight spans CELLS_PER_WEIGHT adjacent cell columns, so a
``xbar_cols``-wide crossbar holds ``xbar_cols // CELLS_PER_WEIGHT`` kernels
side by side.  FC layers are the V = C_in, K = C_out special case.
"""
from __future__ import annotations

import dataclasses
import math

from repro.xbar.cells import CELLS_PER_WEIGHT


@dataclasses.dataclass(frozen=True)
class CrossbarSpec:
    rows: int = 128
    cols: int = 128
    cell_bits: int = 2

    @property
    def weights_per_row(self) -> int:
        return self.cols // CELLS_PER_WEIGHT  # 32 for 128 cols / 4 cells

    @property
    def weight_capacity(self) -> int:
        return self.rows * self.weights_per_row  # 4096 INT8 weights


@dataclasses.dataclass(frozen=True)
class LayerMapping:
    """Resource footprint of one layer replica on the crossbar pool."""

    xbars_tall: int      # ceil(kernel_volume / rows): vertical partitions
    xbars_wide: int      # ceil(num_kernels / weights_per_row)
    windows: int         # activation windows to stream (OH*OW, or tokens)
    kernel_volume: int   # weights per kernel (= occupied rows in last xbar)
    num_kernels: int

    @property
    def apus(self) -> int:
        """Crossbars (== APUs; one crossbar per APU) per replica."""
        return self.xbars_tall * self.xbars_wide

    @property
    def weights(self) -> int:
        return self.kernel_volume * self.num_kernels

    def occupied_rows(self, spec: CrossbarSpec) -> int:
        """Total crossbar rows actually written for one replica."""
        full, rem = divmod(self.kernel_volume, spec.rows)
        rows = full * spec.rows + rem  # == kernel_volume
        return rows * self.xbars_wide


def map_layer(
    kernel_volume: int,
    num_kernels: int,
    windows: int,
    spec: CrossbarSpec = CrossbarSpec(),
) -> LayerMapping:
    if kernel_volume <= 0 or num_kernels <= 0:
        raise ValueError("layer must have positive kernel volume and count")
    return LayerMapping(
        xbars_tall=math.ceil(kernel_volume / spec.rows),
        xbars_wide=math.ceil(num_kernels / spec.weights_per_row),
        windows=max(windows, 1),
        kernel_volume=kernel_volume,
        num_kernels=num_kernels,
    )
