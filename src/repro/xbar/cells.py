"""2-bit multi-level ReRAM cell arithmetic (paper §II-B, §V-C).

Each INT8 weight code occupies ``CELLS_PER_WEIGHT = 4`` cells of
``CELL_BITS = 2`` bits (levels 0..3).  Cell 0 holds the least-significant
pair, cell 3 the most-significant pair (the paper's "4th cell").

Updating a cell from level a to level b costs ``|a - b|`` programming pulses
(incremental SET/RESET pulse trains); equal levels are *skipped* entirely.
The write latency of a row-phase is set by the slowest cell in the row
(max |Δ| over the row for that polarity).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

CELL_BITS = 2
CELLS_PER_WEIGHT = 8 // CELL_BITS  # = 4
LEVELS = 1 << CELL_BITS            # = 4


def pack_cells(code: jax.Array) -> jax.Array:
    """uint8 codes (...,) -> cell levels (..., 4), cell 0 = LSBs."""
    c = code.astype(jnp.int32)
    shifts = jnp.arange(CELLS_PER_WEIGHT) * CELL_BITS  # [0, 2, 4, 6]
    return (c[..., None] >> shifts) & (LEVELS - 1)


def unpack_cells(cells: jax.Array) -> jax.Array:
    """Cell levels (..., 4) -> uint8 codes (...,)."""
    shifts = jnp.arange(CELLS_PER_WEIGHT) * CELL_BITS
    return jnp.sum(cells.astype(jnp.int32) << shifts, axis=-1).astype(jnp.uint8)


def cell_deltas(old_code: jax.Array, new_code: jax.Array) -> jax.Array:
    """Signed per-cell level deltas (..., 4) when overwriting old with new."""
    return pack_cells(new_code) - pack_cells(old_code)


def pulse_count(old_code: jax.Array, new_code: jax.Array) -> jax.Array:
    """Total programming pulses to overwrite ``old_code`` with ``new_code``.

    This is the paper's "ReRAM writing activity" metric (Fig 13).
    """
    return jnp.sum(jnp.abs(cell_deltas(old_code, new_code)))


def pulse_count_per_cell(old_code: jax.Array, new_code: jax.Array) -> jax.Array:
    """Per-cell-index pulse totals, shape (4,) — MSB cells are index 2, 3."""
    d = jnp.abs(cell_deltas(old_code, new_code))
    return jnp.sum(d.reshape(-1, CELLS_PER_WEIGHT), axis=0)


def skip_ratio(old_code: jax.Array, new_code: jax.Array) -> jax.Array:
    """Fraction of cells whose level is unchanged (skippable writes)."""
    d = cell_deltas(old_code, new_code)
    return jnp.mean((d == 0).astype(jnp.float32))


def skip_ratio_per_cell(old_code: jax.Array, new_code: jax.Array) -> jax.Array:
    d = cell_deltas(old_code, new_code)
    return jnp.mean((d == 0).astype(jnp.float32).reshape(-1, CELLS_PER_WEIGHT), axis=0)


def cell_value_histogram(code: jax.Array, cell: int) -> jax.Array:
    """P_i(k) of the paper's Eq. 3: distribution of levels in cell ``cell``."""
    levels = pack_cells(code)[..., cell].reshape(-1)
    counts = jnp.sum(
        (levels[:, None] == jnp.arange(LEVELS)[None, :]).astype(jnp.float32), axis=0
    )
    return counts / levels.shape[0]


def cell_similarity(code_x: jax.Array, code_y: jax.Array, cell: int) -> jax.Array:
    """Paper Eq. 3: Sim(X, Y, i) = Σ_k P_i_X(k) · P_i_Y(k).

    Probability that cell ``cell`` keeps its value when a random weight of
    layer Y overwrites a random weight of layer X in the same crossbar cell.
    """
    px = cell_value_histogram(code_x, cell)
    py = cell_value_histogram(code_y, cell)
    return jnp.sum(px * py)


def row_phase_pulses(old_code: jax.Array, new_code: jax.Array) -> jax.Array:
    """Max pulses per polarity for a crossbar *row* of weights.

    ``old_code``/``new_code``: (row_weights,) uint8.  Row write latency is
    2 phases; each phase is bounded by the slowest cell needing that polarity
    (increase phase: max positive Δ; decrease phase: max negative Δ).
    Returns (inc_pulses, dec_pulses).
    """
    d = cell_deltas(old_code, new_code)
    inc = jnp.max(jnp.maximum(d, 0))
    dec = jnp.max(jnp.maximum(-d, 0))
    return jnp.stack([inc, dec])
