"""Crossbar numerics: INT8 quantization, 2-bit ReRAM cell packing, mapping.

This package is the numerical substrate shared by the faithful simulator
(`repro.sim`), the weight-reuse optimization (`repro.core.weight_reuse`) and
the TPU-native streaming path (`repro.streaming`).
"""
from repro.xbar.quant import (
    QuantParams,
    quantize,
    dequantize,
    quantize_tensor,
    shift_weights,
    dot_int8,
)
from repro.xbar.cells import (
    CELL_BITS,
    CELLS_PER_WEIGHT,
    LEVELS,
    pack_cells,
    unpack_cells,
    pulse_count,
    skip_ratio,
    cell_similarity,
)
from repro.xbar.mapping import CrossbarSpec, LayerMapping, map_layer

__all__ = [
    "QuantParams", "quantize", "dequantize", "quantize_tensor",
    "shift_weights", "dot_int8",
    "CELL_BITS", "CELLS_PER_WEIGHT", "LEVELS",
    "pack_cells", "unpack_cells", "pulse_count", "skip_ratio",
    "cell_similarity",
    "CrossbarSpec", "LayerMapping", "map_layer",
]
