"""Roofline terms from a compiled dry-run artifact (no real hardware).

    compute term    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory term     = HLO_bytes / (chips × HBM_bw)
    collective term = collective_bytes / (chips × link_bw)

``compiled.cost_analysis()`` is per-device under SPMD, as is the post-SPMD
HLO text, so per-device quantities are divided by per-chip peak directly
(algebraically identical to the global/(chips×·) form in the spec).

collective_bytes sums *operand* sizes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute (and their -start async
variants) in the optimized HLO.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

#: TPU v5e-class hardware constants (per chip).
HW = {
    "peak_flops": 197e12,   # bf16
    "hbm_bw": 819e9,        # bytes/s
    "link_bw": 50e9,        # bytes/s per ICI link
    "hbm_bytes": 16 * 1024**3,
}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
# `%name = TYPE[dims]{layout} kind(...)` — modern HLO omits operand types,
# so transfer sizes derive from the RESULT shape with per-kind wire factors.
_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z]+\d*\[[\d,]*\]\S*)\s+("
    + "|".join(_COLL_KINDS)
    + r")(-start)?\(")
_SHAPE_RE = re.compile(r"([a-z]+\d*)\[([\d,]*)\]")
_GROUP_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _wire_bytes(kind: str, result_bytes: int, group: int) -> float:
    """Per-device bytes crossing links on a ring/bidirectional schedule."""
    g = max(group, 2)
    if kind == "all-reduce":
        return 2.0 * (g - 1) / g * result_bytes   # reduce-scatter + all-gather
    if kind == "all-gather":
        return (g - 1) / g * result_bytes         # result = gathered size
    if kind == "reduce-scatter":
        return (g - 1) * result_bytes             # result = scattered shard
    if kind == "all-to-all":
        return (g - 1) / g * result_bytes
    return float(result_bytes)                    # collective-permute


_GROUP_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def collective_bytes_from_hlo(hlo_text: str, default_group: int = 2
                              ) -> Dict[str, float]:
    """Per-device wire bytes of collective ops, by kind.

    ``default_group`` is used when replica_groups={} (all devices).
    NOTE: ops inside `while` bodies (lax.scan) appear once in the text; the
    dry-run corrects loop multiplicity via depth-probe extrapolation
    (launch/dryrun.py)."""
    out = {k: 0.0 for k in _COLL_KINDS}
    counts = {k: 0 for k in _COLL_KINDS}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        result_sig, kind = m.group(1), m.group(2)
        shapes = _SHAPE_RE.findall(result_sig)
        rbytes = sum(_shape_bytes(d, s) for d, s in shapes)
        gm = _GROUP_RE.search(line)
        if gm:
            group = int(gm.group(2))
        else:
            gl = _GROUP_LIST_RE.search(line)
            group = (gl.group(1).count(",") + 1) if gl else default_group
        out[kind] += _wire_bytes(kind, rbytes, group)
        counts[kind] += 1
    out["total"] = sum(out[k] for k in _COLL_KINDS)
    out.update({f"n_{k}": counts[k] for k in _COLL_KINDS})
    return out


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    model_flops_global: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    useful_flops_ratio: float
    collectives: Dict[str, float]

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)


def roofline_terms(
    *,
    arch: str,
    shape: str,
    mesh_name: str,
    chips: int,
    cost_analysis: Optional[Dict[str, float]],
    hlo_text: str,
    model_flops_global: float,
) -> RooflineReport:
    cost = cost_analysis or {}
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    coll = collective_bytes_from_hlo(hlo_text)
    compute_s = flops / HW["peak_flops"]
    memory_s = nbytes / HW["hbm_bw"]
    collective_s = coll["total"] / HW["link_bw"]
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    useful = model_flops_global / max(flops * chips, 1.0)
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops_per_device=flops, bytes_per_device=nbytes,
        collective_bytes_per_device=coll["total"],
        model_flops_global=model_flops_global,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant, useful_flops_ratio=useful,
        collectives=coll,
    )
