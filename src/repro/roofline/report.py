"""Aggregate results/dryrun/*.json into the EXPERIMENTS.md roofline table.

    PYTHONPATH=src python -m repro.roofline.report [--dir results/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List


def load_cells(directory: str) -> List[Dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(directory, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def fmt_s(x) -> str:
    if x is None:
        return "—"
    if x >= 1.0:
        return f"{x:.2f}s"
    return f"{x*1e3:.1f}ms"


def markdown_table(cells: List[Dict]) -> str:
    rows = ["| arch | shape | mesh | GB/dev | fits | compute | memory | "
            "collective | dominant | useful-FLOPs |",
            "|---|---|---|---|---|---|---|---|---|---|"]
    for c in cells:
        r = c.get("roofline")
        if r:
            rows.append(
                f"| {c['arch']} | {c['shape']} | {c['mesh']} | "
                f"{c['per_device_gb']:.1f} | {'✓' if c['fits_hbm'] else '✗'} | "
                f"{fmt_s(r['compute_s'])} | {fmt_s(r['memory_s'])} | "
                f"{fmt_s(r['collective_s'])} | {r['dominant']} | "
                f"{r['useful_flops_ratio']:.2f} |")
        else:
            rows.append(
                f"| {c['arch']} | {c['shape']} | {c['mesh']} | "
                f"{c['per_device_gb']:.1f} | {'✓' if c['fits_hbm'] else '✗'} | "
                f"—  | — | — | (sharding-proof run) | — |")
    return "\n".join(rows)


def summary(cells: List[Dict]) -> str:
    single = [c for c in cells if c["mesh"] == "pod16x16" and c.get("roofline")]
    lines = [f"cells: {len(cells)} total, {len(single)} with roofline"]
    worst = sorted(single, key=lambda c: c["roofline"]["useful_flops_ratio"])
    coll = sorted(single, key=lambda c: -c["roofline"]["collective_s"])
    if worst:
        w = worst[0]
        lines.append(f"worst useful-FLOPs: {w['arch']}×{w['shape']} "
                     f"({w['roofline']['useful_flops_ratio']:.2f})")
        c0 = coll[0]
        lines.append(f"most collective-bound: {c0['arch']}×{c0['shape']} "
                     f"({c0['roofline']['collective_s']:.2f}s)")
    misfit = [c for c in cells if not c["fits_hbm"]]
    lines.append("over-HBM cells: " + (", ".join(
        f"{c['arch']}×{c['shape']}×{c['mesh']}" for c in misfit) or "none"))
    return "\n".join(lines)


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--dir", default=os.path.join("results", "dryrun"))
    args = p.parse_args()
    cells = load_cells(args.dir)
    print(markdown_table(cells))
    print()
    print(summary(cells))


if __name__ == "__main__":
    main()
