"""Sharded, resharding-tolerant checkpointing.

Layout: <dir>/step_<N>/
  manifest.json        — tree structure, shapes, dtypes, mesh at save time
  arr_<i>.npy          — one file per leaf (host-gathered)

Design points for the 1000+-node setting (documented trade-offs; the
single-process container exercises the same code paths):

  * save is atomic: written to step_<N>.tmp then renamed, so a preemption
    mid-save never corrupts the latest checkpoint;
  * async: the host-side serialization runs on a background thread; training
    continues (`save_checkpoint(..., block=False)`);
  * restore reshards: arrays are loaded host-side and `jax.device_put` with
    the *target* sharding, so a checkpoint written on a (16,16) mesh restores
    onto (2,16,16) or a single device unchanged — this is the elastic-scaling
    path;
  * per-leaf files keep restore memory bounded and allow lazy/partial reads
    (the streaming executor reads single layers).

A production deployment would write per-shard files from each host (ocdbt
style); host-gather is the honest equivalent for a one-host container and
keeps the format trivially inspectable.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

_SAVE_LOCK = threading.Lock()
_PENDING: list = []


def _leaf_paths(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save_checkpoint(directory: str, step: int, tree: Any,
                    block: bool = True) -> None:
    leaves, treedef = _leaf_paths(tree)
    host = [np.asarray(jax.device_get(l)) for l in leaves]
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "leaves": [{"shape": list(a.shape), "dtype": str(a.dtype)} for a in host],
    }
    # .npy cannot roundtrip ml_dtypes (bf16 loads back as void) — store the
    # raw bits as uint16; the manifest records the logical dtype.
    host = [a.view(np.uint16) if a.dtype.itemsize == 2 and a.dtype.kind == "V"
            or str(a.dtype) == "bfloat16" else a for a in host]

    def _write():
        with _SAVE_LOCK:
            tmp = os.path.join(directory, f"step_{step}.tmp")
            final = os.path.join(directory, f"step_{step}")
            os.makedirs(tmp, exist_ok=True)
            for i, a in enumerate(host):
                np.save(os.path.join(tmp, f"arr_{i}.npy"), a)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)

    if block:
        _write()
    else:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        _PENDING.append(t)


def wait_for_saves() -> None:
    for t in _PENDING:
        t.join()
    _PENDING.clear()


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for d in os.listdir(directory)
             if (m := re.fullmatch(r"step_(\d+)", d))]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, target: Any,
                       shardings: Any = None) -> Any:
    """Restore into the structure (and shardings) of ``target``.

    ``target`` supplies the pytree structure and dtypes;  ``shardings`` (same
    structure, jax.sharding.Sharding leaves or None) controls placement —
    pass the *current* mesh's shardings to reshard an old checkpoint.
    """
    path = os.path.join(directory, f"step_{step}")
    leaves, treedef = _leaf_paths(target)
    if shardings is None:
        shard_leaves = [None] * len(leaves)
    else:
        shard_leaves = treedef.flatten_up_to(shardings)
    out = []
    for i, (ref, sh) in enumerate(zip(leaves, shard_leaves)):
        a = np.load(os.path.join(path, f"arr_{i}.npy"))
        if a.dtype.kind == "V" and a.dtype.itemsize == 2 or (
                a.dtype == np.uint16 and str(ref.dtype) == "bfloat16"):
            import ml_dtypes
            a = a.view(ml_dtypes.bfloat16)
        if list(a.shape) != list(ref.shape):
            raise ValueError(
                f"leaf {i}: checkpoint shape {a.shape} != target {ref.shape}")
        if a.dtype != ref.dtype:
            # numpy lacks cast kernels for ml_dtypes (bf16) — cast in jax.
            import jax.numpy as jnp
            a = np.asarray(jnp.asarray(a).astype(ref.dtype))
        out.append(jax.device_put(a, sh) if sh is not None else jax.device_put(a))
    return treedef.unflatten(out)
