"""Event-driven simulator of the ARAS accelerator (paper §VI).

Models the paper's machine: a pool of 96 PEs × 6×4 APUs with 128×128 2-bit
crossbars, an LPDDR4 main memory (19.2 GB/s, serialized DMA), a heterogeneous
multi-banked Global Buffer, and the ARAS offline scheduler that overlaps the
compute of layer L with the weight writing of layers L+1…L+K (Fig 8),
including Algorithm-1 replication and §V-C partial weight reuse.

The same simulation doubles as the *offline scheduler*: with
``record_instructions=True`` it emits the static instruction stream
(write/compute ops with resources, replication factors and timestamps) that
the paper's Fig 6 flow would hand to the hardware.
"""
from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.bank_selection import Bank, BankSelection, make_banks, select_banks
from repro.core.layer_graph import LayerGraph, LayerNode
from repro.core.replication import LayerCost, plan_writes
from repro.core.resources import AcceleratorConfig
from repro.core.weight_reuse import (
    ERASED_HIST,
    LayerEncoding,
    encode_network,
    expected_pulses_per_weight,
)
from repro.sim.energy import EnergyModel
from repro.xbar.cells import CELLS_PER_WEIGHT

BASELINE_BANKS_BYTES = tuple([256 * 1024] * 15)
HETERO_BANKS_BYTES = (
    1024, 1024, 2 * 1024, 4 * 1024, 64 * 1024, 128 * 1024,
    256 * 1024, 512 * 1024, 1024 * 1024, 2 * 1024 * 1024,
)


@dataclasses.dataclass(frozen=True)
class ArasSimConfig:
    accel: AcceleratorConfig = AcceleratorConfig()
    energy: EnergyModel = EnergyModel()
    overlap: bool = True          # ARAS scheduler (Fig 8) vs naive (Fig 7)
    replication: bool = False     # §V-B
    hetero_banks: bool = False    # §V-A
    weight_reuse: bool = False    # §V-C
    max_replication: int = 64
    record_instructions: bool = False

    @staticmethod
    def variant(name: str, **kw) -> "ArasSimConfig":
        """Paper configurations: naive | baseline | B | BR | BRW."""
        presets = {
            "naive": dict(overlap=False),
            "baseline": dict(overlap=True),
            "B": dict(overlap=True, hetero_banks=True),
            "BR": dict(overlap=True, hetero_banks=True, replication=True),
            "BRW": dict(overlap=True, hetero_banks=True, replication=True,
                        weight_reuse=True),
        }
        return ArasSimConfig(**{**presets[name], **kw})


@dataclasses.dataclass(frozen=True)
class Segment:
    """A schedulable unit: a layer, or a column-wise slice of a large layer."""

    layer_idx: int
    seg_idx: int
    name: str
    kernel_volume: int
    num_kernels: int
    windows: int
    apus: int           # APUs for one replica
    base_rows: int      # PE rows for one replica
    weights: int

    @property
    def compute_cycles_unreplicated(self) -> int:
        return self.windows  # multiplied by xbar_compute_cycles by the engine


@dataclasses.dataclass
class Instruction:
    kind: str            # 'write' | 'compute'
    segment: str
    t_start_cycles: float
    t_end_cycles: float
    rows: int
    replication: int
    fraction: float = 1.0


@dataclasses.dataclass
class SimResult:
    name: str
    makespan_s: float
    energy: Dict[str, float]
    total_pulses: float
    weights_written: float
    cell_writes_per_inference: float
    upper_bound_s: Optional[float]
    instructions: List[Instruction]
    reuse_center: Optional[int]
    per_layer_compute_s: Dict[str, float]

    @property
    def throughput_inf_s(self) -> float:
        return 1.0 / self.makespan_s

    @property
    def total_energy_j(self) -> float:
        return self.energy["total"]


def segment_graph(graph: LayerGraph, accel: AcceleratorConfig) -> List[Segment]:
    """Split layers that exceed the crossbar pool into column-slices (§IV-A:
    'In the event that a layer exceeds the capacity of the accelerator, it is
    divided into smaller segments, each of which is executed sequentially')."""
    segs: List[Segment] = []
    spec = accel.xbar
    for li, layer in enumerate(graph.layers):
        m = layer.mapping(spec)
        total_rows = accel.rows_for_apus(m.apus)
        if total_rows <= accel.total_rows:
            segs.append(Segment(li, 0, layer.name, layer.kernel_volume,
                                layer.num_kernels, layer.windows, m.apus,
                                total_rows, layer.weights))
            continue
        # Split along kernels (output channels) in groups that fit the pool.
        kernels_per_colgroup = spec.weights_per_row
        apus_per_colgroup = m.xbars_tall
        rows_per_colgroup = accel.rows_for_apus(apus_per_colgroup)
        groups_per_seg = max(accel.total_rows // rows_per_colgroup, 1)
        kernels_per_seg = groups_per_seg * kernels_per_colgroup
        n_segs = math.ceil(layer.num_kernels / kernels_per_seg)
        done = 0
        for si in range(n_segs):
            k = min(kernels_per_seg, layer.num_kernels - done)
            done += k
            mm = math.ceil(k / kernels_per_colgroup) * apus_per_colgroup
            segs.append(Segment(li, si, f"{layer.name}.s{si}",
                                layer.kernel_volume, k, layer.windows, mm,
                                accel.rows_for_apus(mm),
                                layer.kernel_volume * k))
    return segs


class _Dram:
    """Serialized DMA channel (single LPDDR4 channel, paper §VI)."""

    def __init__(self, bytes_per_cycle: float):
        self.bpc = bytes_per_cycle
        self.free_at = 0.0
        self.bytes_moved = 0.0

    def transfer(self, t: float, nbytes: float) -> float:
        start = max(t, self.free_at)
        end = start + nbytes / self.bpc
        self.free_at = end
        self.bytes_moved += nbytes
        return end


class _Occupancy:
    """FIFO of (rows, hist) chunks tracking which layer's codes currently sit
    in each crossbar row — determines overwrite pulse costs."""

    def __init__(self, total_rows: int):
        self.chunks = deque([(total_rows, None)])  # None = erased

    def consume(self, rows: int) -> List[Tuple[int, Optional[np.ndarray]]]:
        out: List[Tuple[int, Optional[np.ndarray]]] = []
        need = rows
        while need > 0:
            r, h = self.chunks.popleft()
            take = min(r, need)
            out.append((take, h))
            if r > take:
                self.chunks.appendleft((r - take, h))
            need -= take
        return out

    def release(self, rows: int, hist: np.ndarray) -> None:
        self.chunks.append((rows, hist))


def _bank_plans(
    graph: LayerGraph, hetero: bool, energy: EnergyModel
) -> Tuple[List[Bank], Dict[int, BankSelection], Dict[int, float]]:
    sizes = HETERO_BANKS_BYTES if hetero else BASELINE_BANKS_BYTES
    banks = make_banks(sizes, energy.sram_leak_w_per_kb, energy.sram_bank_overhead_w)
    sel: Dict[int, BankSelection] = {}
    in_leak: Dict[int, float] = {}
    for li, layer in enumerate(graph.layers):
        sel[li] = select_banks(banks, layer.in_act_bytes, layer.out_act_bytes)
        # Leakage of just holding the layer's input (gaps between computes).
        hold = select_banks(banks, layer.in_act_bytes, 0)
        in_leak[li] = hold.leakage_w
    return banks, sel, in_leak


def simulate_aras(
    graph: LayerGraph,
    layer_codes: Sequence[Tuple[str, np.ndarray]],
    config: ArasSimConfig = ArasSimConfig(),
) -> SimResult:
    accel, em = config.accel, config.energy
    segs = segment_graph(graph, accel)
    n = len(segs)
    bpc = accel.dram_bw_effective / accel.freq_hz  # bytes per cycle

    encodings, center = encode_network(layer_codes, enabled=config.weight_reuse)
    hist_of_layer = [e.hist for e in encodings]

    banks, bank_sel, bank_in_leak = _bank_plans(graph, config.hetero_banks, em)

    segmented_layers = {s.layer_idx for s in segs if s.seg_idx > 0}
    costs = [
        LayerCost(
            base_rows=s.base_rows,
            compute_cycles=s.windows * accel.xbar_compute_cycles,
            max_replication=(
                1 if s.layer_idx in segmented_layers
                else min(s.windows, config.max_replication)
            ),
            write_dma_cycles=s.weights / bpc,
        )
        for s in segs
    ]

    def wl_cycles(idx: int) -> float:
        if idx >= n:
            return float("inf")
        dram_cycles = segs[idx].weights / bpc
        return max(accel.xbar_write_cycles, dram_cycles)

    dram = _Dram(bpc)
    occ = _Occupancy(accel.total_rows)
    free_rows = accel.total_rows

    ready: Dict[int, float] = {}       # seg -> fully-written time
    rows_of: Dict[int, int] = {}
    repl_of: Dict[int, int] = {i: 1 for i in range(n)}
    frac_written: Dict[int, float] = {i: 0.0 for i in range(n)}
    part_rows: Dict[int, int] = {i: 0 for i in range(n)}

    total_pulses = 0.0
    weights_written = 0.0
    instructions: List[Instruction] = []

    def _write_chunk(t: float, seg: Segment, rows: int, frac: float, repl: int) -> float:
        nonlocal total_pulses, weights_written, free_rows
        nbytes = seg.weights * frac * repl
        dram_end = dram.transfer(t, nbytes)
        end = max(t + accel.xbar_write_cycles, dram_end)
        free_rows -= rows
        new_hist = hist_of_layer[seg.layer_idx]
        for r, old_hist in occ.consume(rows):
            share = (r / rows) * seg.weights * frac * repl
            old = ERASED_HIST if old_hist is None else old_hist
            total_pulses += share * expected_pulses_per_weight(old, new_hist)
        weights_written += seg.weights * frac * repl
        if config.record_instructions:
            instructions.append(Instruction("write", seg.name, t, end, rows, repl, frac))
        return end

    w = 0  # next segment index to plan writes for

    def plan_and_issue(t: float, max_seg: Optional[int] = None) -> None:
        """Weight Writing Scheduling Procedure (Fig 9b).  ``max_seg`` bounds
        the write frontier — the naive Fig-7 scheduler only ever writes the
        segment it is about to compute."""
        nonlocal w, free_rows
        while w < n and free_rows > 0:
            if max_seg is not None and w > max_seg:
                return
            eff = list(costs)
            if frac_written[w] > 0.0:
                rem = 1.0 - frac_written[w]
                eff[w] = LayerCost(
                    base_rows=max(segs[w].base_rows - part_rows[w], 1),
                    compute_cycles=costs[w].compute_cycles,
                    max_replication=1,
                )
            items = plan_writes(free_rows, w, eff, wl_cycles,
                                replication_enabled=config.replication)
            if max_seg is not None:
                items = [it for it in items if it.layer_idx <= max_seg]
            if not items:
                return
            for it in items:
                s = segs[it.layer_idx]
                if it.fraction >= 1.0 and frac_written[it.layer_idx] == 0.0:
                    end = _write_chunk(t, s, it.rows, 1.0, it.replication)
                    ready[it.layer_idx] = end
                    rows_of[it.layer_idx] = it.rows
                    repl_of[it.layer_idx] = it.replication
                    frac_written[it.layer_idx] = 1.0
                    w = it.layer_idx + 1
                else:
                    # Partial (continuation) write of segment ``it.layer_idx``.
                    idx = it.layer_idx
                    frac = min(it.fraction * (1.0 - frac_written[idx])
                               if frac_written[idx] > 0.0 else it.fraction,
                               1.0 - frac_written[idx])
                    end = _write_chunk(t, s, it.rows, frac, 1)
                    frac_written[idx] += frac
                    part_rows[idx] += it.rows
                    rows_of[idx] = part_rows[idx]
                    if frac_written[idx] >= 1.0 - 1e-9:
                        ready[idx] = end
                        w = idx + 1
                    else:
                        ready[idx] = float("inf")
            if any(it.fraction < 1.0 for it in items):
                return  # pool exhausted on a partial chunk

    # --- initial input DMA (initialization state, Fig 9a) ---
    input_dma_end = dram.transfer(0.0, graph.layers[0].in_act_bytes)

    gbuffer_j = 0.0
    compute_j = 0.0
    sram_j = 0.0
    per_layer_compute_s: Dict[str, float] = {}

    comp_end_prev = 0.0
    if config.overlap:
        plan_and_issue(0.0)
    for c in range(n):
        seg = segs[c]
        max_seg = None if config.overlap else c
        if not config.overlap:
            # Naive Fig 7: write strictly before this segment's compute, and
            # never write ahead.
            plan_and_issue(comp_end_prev, max_seg)
        guard = 0
        while frac_written[c] < 1.0 - 1e-9:
            plan_and_issue(max(comp_end_prev, ready.get(c, 0.0)
                               if ready.get(c, 0.0) != float("inf") else comp_end_prev),
                           max_seg)
            guard += 1
            if guard > 10000:
                raise RuntimeError(f"scheduler stuck on segment {seg.name}")
        start = max(ready[c], comp_end_prev)
        if c == 0:
            start = max(start, input_dma_end)
        dur = math.ceil(seg.windows / repl_of[c]) * accel.xbar_compute_cycles
        end = start + dur
        li = seg.layer_idx
        gap = start - comp_end_prev
        gbuffer_j += bank_in_leak[li] * accel.cycles_to_seconds(gap)
        gbuffer_j += bank_sel[li].leakage_w * accel.cycles_to_seconds(dur)
        compute_j += seg.windows * seg.apus * em.xbar_op_j
        sram_j += seg.windows * (seg.kernel_volume + seg.num_kernels) * em.sram_access_j_per_byte
        per_layer_compute_s[seg.name] = accel.cycles_to_seconds(dur)
        if config.record_instructions:
            instructions.append(Instruction("compute", seg.name, start, end,
                                            rows_of[c], repl_of[c]))
        # Release state: free this segment's rows and immediately bind writes.
        free_rows += rows_of[c]
        occ.release(rows_of[c], hist_of_layer[li])
        comp_end_prev = end
        if config.overlap:
            plan_and_issue(end)

    makespan_cycles = dram.transfer(comp_end_prev, graph.layers[-1].out_act_bytes)
    makespan_s = accel.cycles_to_seconds(makespan_cycles)

    write_j = total_pulses * em.write_pulse_j
    dram_j = dram.bytes_moved * em.dram_j_per_byte
    static_other_w = em.chip_other_leak_w + accel.total_apus * em.apu_leak_w
    static_other_j = static_other_w * makespan_s
    energy = {
        "write": write_j,
        "static_gbuffer": gbuffer_j,
        "static_other": static_other_j,
        "compute": compute_j,
        "sram": sram_j,
        "dram": dram_j,
    }
    energy["total"] = sum(energy.values())

    return SimResult(
        name=graph.name,
        makespan_s=makespan_s,
        energy=energy,
        total_pulses=total_pulses,
        weights_written=weights_written,
        cell_writes_per_inference=weights_written / accel.weight_capacity,
        upper_bound_s=None,
        instructions=instructions,
        reuse_center=center,
        per_layer_compute_s=per_layer_compute_s,
    )


def upper_bound_cycles(graph: LayerGraph, accel: AcceleratorConfig) -> float:
    """Performance upper bound (§VII-B): the time to write every layer's
    weights exactly once given the pool and DRAM constraints, with compute
    taken as free (rows release instantly)."""
    segs = segment_graph(graph, accel)
    bpc = accel.dram_bw_effective / accel.freq_hz
    dram = _Dram(bpc)
    t = 0.0
    free_rows = accel.total_rows
    pending: deque = deque()  # (end_time, rows)
    for s in segs:
        rows_left = s.base_rows
        while rows_left > 0:
            while free_rows == 0:
                end, r = pending.popleft()
                t = max(t, end)
                free_rows += r
            take = min(rows_left, free_rows)
            frac = take / s.base_rows
            end = max(t + accel.xbar_write_cycles, dram.transfer(t, s.weights * frac))
            free_rows -= take
            pending.append((end, take))
            rows_left -= take
    return max(e for e, _ in pending) if pending else t
