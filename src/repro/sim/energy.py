"""Energy model constants (32 nm class, paper §VI methodology analogues).

The paper characterizes crossbars with NeuroSim, SRAM with CACTI-P, logic
with Synopsys DC (32 nm) and DRAM with DRAMSim3 — none of which publish the
resulting joule constants in the paper, and none of which are runnable in
this offline container.  The constants below are set to NeuroSim/CACTI-class
values from the public literature and are the declared free parameters of
this reproduction (see DESIGN.md §4): absolute joules are approximate, the
*relative* behaviours (write-dominated NLP, static-heavy CNNs, negligible
compute) are the reproduction targets.

All values are joules / watts at 1 GHz, 32 nm.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class EnergyModel:
    # --- ReRAM crossbar ---
    # One incremental SET/RESET programming pulse on a 2-bit 1T1R cell.
    write_pulse_j: float = 20e-12
    # One crossbar × one activation-window dot-product: 128 SLs × 8 bit-serial
    # iterations sampled by the shared 6-bit ADC pool (~0.4 pJ/conv) plus
    # DAC/WL-driver and S&H overheads.
    xbar_op_j: float = 0.35e-9
    # Leakage of one APU's periphery (ADC pool dominates).
    apu_leak_w: float = 65e-6

    # --- SRAM (CACTI-P class, 32 nm, low-standby-power cells) ---
    sram_leak_w_per_kb: float = 60e-6
    sram_bank_overhead_w: float = 0.2e-3
    sram_access_j_per_byte: float = 1.2e-12

    # --- Logic / rest-of-chip static (controllers, NoC, SFU, ACC) ---
    chip_other_leak_w: float = 0.05

    # --- Main memory (LPDDR4, ~5 pJ/bit incl. PHY) ---
    dram_j_per_byte: float = 25e-12

    # --- TPU-like accelerator (same 32 nm node, area-matched, Table I) ---
    tpu_mac_j: float = 0.55e-12          # INT8 MAC incl. local register movement
    tpu_sram_j_per_byte: float = 2.4e-12  # 4.5 MB unified buffer access
    tpu_leak_w: float = 0.42             # buffers + 64×64 MAC array + logic

    # Expected incremental pulses to program one 2-bit cell whose target
    # level is uniform in {0..3} from an erased (level-0) cell: E|Δ| = 1.5.
    # The KV plane has no per-cell delta tracking (pages are programmed
    # whole), so byte traffic converts to pulses through this expectation.
    kv_pulses_per_cell: float = 1.5

    def aras_static_w(self, num_apus: int, gbuffer_leak_w: float) -> float:
        """Chip static power given the currently-active Gbuffer bank set."""
        return self.chip_other_leak_w + num_apus * self.apu_leak_w + gbuffer_leak_w

    def weight_write_j(self, pulses: float) -> float:
        """Energy of `pulses` incremental SET/RESET programming pulses —
        the serving engine's §V-C install accounting priced in joules."""
        return float(pulses) * self.write_pulse_j

    def kv_write_j(self, n_bytes: float) -> float:
        """Energy to program `n_bytes` of KV-page traffic into 2-bit cells
        (4 cells per byte — `repro.xbar.cells.CELLS_PER_WEIGHT`) at the
        expected erased-cell programming cost per cell."""
        return float(n_bytes) * 4 * self.kv_pulses_per_cell * self.write_pulse_j
