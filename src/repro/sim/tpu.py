"""TPU-like systolic-array baseline (paper §VI, Table I; ScaleSim-style).

64×64 INT8 MAC array @ 1 GHz, 4.5 MB unified data buffer, weight-stationary
dataflow: each K×N weight tile (64×64) is loaded into the array (64 cycles)
and M activation rows are streamed through (M cycles + 64 drain).  DRAM
traffic: weights once; activations refetched once per weight-buffer pass when
a layer's weights exceed half the buffer (double buffering).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict

from repro.core.layer_graph import LayerGraph
from repro.sim.energy import EnergyModel


@dataclasses.dataclass(frozen=True)
class TpuConfig:
    array_rows: int = 64
    array_cols: int = 64
    freq_hz: float = 1e9
    buffer_bytes: int = int(4.5 * 1024 * 1024)
    energy: EnergyModel = EnergyModel()


@dataclasses.dataclass
class TpuResult:
    name: str
    makespan_s: float
    energy: Dict[str, float]

    @property
    def total_energy_j(self) -> float:
        return self.energy["total"]


def simulate_tpu(graph: LayerGraph, config: TpuConfig = TpuConfig(),
                 dram_bw_bytes_per_s: float = 19.2e9 * 0.65) -> TpuResult:
    em = config.energy
    cycles = 0.0
    macs_total = 0.0
    dram_bytes = 0.0
    sram_bytes = 0.0
    for layer in graph.layers:
        m, k, nn = layer.windows, layer.kernel_volume, layer.num_kernels
        k_tiles = math.ceil(k / config.array_rows)
        n_tiles = math.ceil(nn / config.array_cols)
        # Weight-stationary: per tile, load (rows) + stream (M) + drain (cols).
        compute_cycles = k_tiles * n_tiles * (m + config.array_rows + config.array_cols)
        weight_bytes = k * nn  # INT8
        act_bytes = m * k
        out_bytes = m * nn
        # Activation refetch once per weight-buffer pass (double buffered).
        passes = max(1, math.ceil(weight_bytes / (config.buffer_bytes / 2)))
        layer_dram = weight_bytes + act_bytes * passes + out_bytes
        dram_cycles = layer_dram / (dram_bw_bytes_per_s / config.freq_hz)
        cycles += max(compute_cycles, dram_cycles)  # double-buffered overlap
        macs_total += layer.macs
        dram_bytes += layer_dram
        # On-chip traffic: weights into the array once per tile pass,
        # activations read per K-tile, outputs written once per N pass.
        sram_bytes += weight_bytes + act_bytes * n_tiles + out_bytes * k_tiles

    makespan_s = cycles / config.freq_hz
    energy = {
        "compute": macs_total * em.tpu_mac_j,
        "sram": sram_bytes * em.tpu_sram_j_per_byte,
        "dram": dram_bytes * em.dram_j_per_byte,
        "static": em.tpu_leak_w * makespan_s,
    }
    energy["total"] = sum(energy.values())
    return TpuResult(name=graph.name, makespan_s=makespan_s, energy=energy)
