"""Event-driven simulation of the ARAS accelerator and a TPU-like baseline."""
from repro.sim.energy import EnergyModel
from repro.sim.aras import ArasSimConfig, SimResult, simulate_aras, upper_bound_cycles
from repro.sim.tpu import TpuConfig, simulate_tpu

__all__ = [
    "EnergyModel",
    "ArasSimConfig",
    "SimResult",
    "simulate_aras",
    "upper_bound_cycles",
    "TpuConfig",
    "simulate_tpu",
]
