"""Straggler detection: per-step timing statistics with outlier flagging.

On a multi-host deployment each host feeds its local step wall time; the
report flags hosts whose EWMA exceeds the fleet median by `threshold`.
Mitigation hooks (the launcher wires these): emit a warning, exclude the
host from the next elastic re-mesh, or trigger an emergency checkpoint.
The single-host container exercises the same statistics on one stream.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class StragglerReport:
    step: int
    median_s: float
    slowest: Dict[str, float]     # host -> ewma seconds (only flagged hosts)
    flagged: bool


class StepTimer:
    def __init__(self, ewma_alpha: float = 0.2, threshold: float = 1.5):
        self.alpha = ewma_alpha
        self.threshold = threshold
        self.ewma: Dict[str, float] = {}
        self._t0: Optional[float] = None
        self.history: List[float] = []

    def start(self) -> None:
        self._t0 = time.perf_counter()

    def stop(self, host: str = "host0") -> float:
        dt = time.perf_counter() - self._t0
        prev = self.ewma.get(host, dt)
        self.ewma[host] = (1 - self.alpha) * prev + self.alpha * dt
        self.history.append(dt)
        return dt

    def observe(self, host_times: Dict[str, float]) -> None:
        """Feed one step's wall time per host (from an all-gather of times)."""
        for h, dt in host_times.items():
            prev = self.ewma.get(h, dt)
            self.ewma[h] = (1 - self.alpha) * prev + self.alpha * dt

    def report(self, step: int) -> StragglerReport:
        if not self.ewma:
            return StragglerReport(step, 0.0, {}, False)
        med = float(np.median(list(self.ewma.values())))
        slow = {h: t for h, t in self.ewma.items()
                if med > 0 and t > self.threshold * med}
        return StragglerReport(step, med, slow, bool(slow))
