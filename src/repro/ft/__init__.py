from repro.ft.straggler import StepTimer, StragglerReport
from repro.ft.watchdog import Watchdog

__all__ = ["StepTimer", "StragglerReport", "Watchdog"]
