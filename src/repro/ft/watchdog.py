"""Step watchdog: detects a hung step (dead collective / lost host) and runs
an emergency action (checkpoint + abort) so the job can be rescheduled
instead of burning the reservation.

Usage:
    wd = Watchdog(timeout_s=600, on_timeout=emergency_checkpoint)
    for step in ...:
        with wd.armed(step):
            run_step()

The serving engine arms the same watchdog as a per-step heartbeat
(`ServingEngine(stall_timeout_s=...)`): a step that overruns the deadline
fires `stall_suspected` telemetry + a flight-recorder dump while the step
keeps running — on the serving side the watchdog observes, never kills.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Callable, Optional


class Watchdog:
    def __init__(self, timeout_s: float,
                 on_timeout: Optional[Callable[[int], None]] = None):
        self.timeout_s = timeout_s
        self.on_timeout = on_timeout
        self.fired = False
        self.fires = 0                       # lifetime deadline misses
        self.fired_step: Optional[int] = None   # most recent missed step
        self._timer: Optional[threading.Timer] = None

    def _fire(self, step: int) -> None:
        self.fired = True
        self.fires += 1
        self.fired_step = step
        if self.on_timeout is not None:
            self.on_timeout(step)

    @contextlib.contextmanager
    def armed(self, step: int):
        self._timer = threading.Timer(self.timeout_s, self._fire, args=(step,))
        self._timer.daemon = True
        self._timer.start()
        try:
            yield
        finally:
            self._timer.cancel()
