"""Layer-graph IR consumed by the ARAS scheduler and the simulators.

The paper's offline flow (Fig 6) extracts a Data-Flow Graph from the PyTorch
model; here the equivalent is a linearized (topologically ordered) list of
weighted layers plus their activation footprints.  Only weight-bearing layers
(CONV / FC / projections) occupy crossbars; SFU ops (pooling, activations,
norms) ride along and are folded into the producing layer's output.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, List, Optional

from repro.xbar.mapping import CrossbarSpec, LayerMapping, map_layer


@dataclasses.dataclass(frozen=True)
class LayerNode:
    """One weight-bearing layer.

    kernel_volume : weights per output unit (R*S*C for CONV, C_in for FC)
    num_kernels   : output units with distinct weight columns (K / C_out)
    windows       : activation windows streamed per inference
                    (OH*OW for CONV, #tokens for transformer FC, 1 for MLP head)
    """

    name: str
    kind: str                 # 'conv' | 'fc'
    kernel_volume: int
    num_kernels: int
    windows: int
    in_act_bytes: int
    out_act_bytes: int

    @property
    def weights(self) -> int:
        return self.kernel_volume * self.num_kernels

    @property
    def weight_bytes(self) -> int:
        return self.weights  # INT8: 1 byte per weight

    @property
    def macs(self) -> int:
        return self.weights * self.windows

    def mapping(self, spec: CrossbarSpec = CrossbarSpec()) -> LayerMapping:
        return map_layer(self.kernel_volume, self.num_kernels, self.windows, spec)


@dataclasses.dataclass(frozen=True)
class LayerGraph:
    name: str
    layers: List[LayerNode]

    @property
    def total_weights(self) -> int:
        return sum(l.weights for l in self.layers)

    @property
    def total_macs(self) -> int:
        return sum(l.macs for l in self.layers)

    @property
    def max_act_bytes(self) -> int:
        return max(l.in_act_bytes + l.out_act_bytes for l in self.layers)

    def __iter__(self) -> Iterable[LayerNode]:
        return iter(self.layers)

    def __len__(self) -> int:
        return len(self.layers)


def conv(
    name: str,
    cin: int,
    cout: int,
    k: int,
    oh: int,
    ow: Optional[int] = None,
    act_bytes: int = 1,
    stride: int = 1,
    ih: Optional[int] = None,
    iw: Optional[int] = None,
) -> LayerNode:
    """Helper for square CONV layers (INT8 activations by default)."""
    ow = ow if ow is not None else oh
    ih = ih if ih is not None else oh * stride
    iw = iw if iw is not None else ow * stride
    return LayerNode(
        name=name,
        kind="conv",
        kernel_volume=cin * k * k,
        num_kernels=cout,
        windows=oh * ow,
        in_act_bytes=cin * ih * iw * act_bytes,
        out_act_bytes=cout * oh * ow * act_bytes,
    )


def fc(name: str, cin: int, cout: int, tokens: int = 1, act_bytes: int = 1) -> LayerNode:
    return LayerNode(
        name=name,
        kind="fc",
        kernel_volume=cin,
        num_kernels=cout,
        windows=tokens,
        in_act_bytes=cin * tokens * act_bytes,
        out_act_bytes=cout * tokens * act_bytes,
    )
