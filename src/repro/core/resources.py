"""Accelerator resource accounting (paper Table II).

The allocation granularity is a *PE row of APUs*: all APUs within a PE row
share the broadcast activations, so a row is exclusively owned by one layer
(§IV-C).  96 PEs × 6 rows × 4 APUs/row = 576 allocatable rows = 2304 APUs.
"""
from __future__ import annotations

import dataclasses
import math

from repro.xbar.mapping import CrossbarSpec


@dataclasses.dataclass(frozen=True)
class AcceleratorConfig:
    """Hardware parameters of ARAS (paper Table II defaults)."""

    num_pes: int = 96
    apu_rows_per_pe: int = 6
    apus_per_row: int = 4
    xbar: CrossbarSpec = CrossbarSpec()
    freq_hz: float = 1e9
    xbar_compute_cycles: int = 96          # per activation window per crossbar
    xbar_write_cycles: int = 768_000       # per crossbar (128 rows × 2 phases)
    dram_bw_bytes_per_s: float = 19.2e9    # LPDDR4, single channel (peak)
    dram_efficiency: float = 0.65          # sustained/peak (DRAMSim3-class)
    num_adcs_per_apu: int = 16
    adc_bits: int = 6
    pe_buffer_bytes: int = 1536            # 1.5 KB
    activation_bits: int = 8

    @property
    def dram_bw_effective(self) -> float:
        return self.dram_bw_bytes_per_s * self.dram_efficiency

    @property
    def total_rows(self) -> int:
        return self.num_pes * self.apu_rows_per_pe

    @property
    def total_apus(self) -> int:
        return self.total_rows * self.apus_per_row

    @property
    def weight_capacity(self) -> int:
        """INT8 weights the whole pool can hold at once."""
        return self.total_apus * self.xbar.weight_capacity

    def rows_for_apus(self, apus: int) -> int:
        return math.ceil(apus / self.apus_per_row)

    def cycles_to_seconds(self, cycles: float) -> float:
        return cycles / self.freq_hz


class RowPool:
    """Free-list of PE rows.  Fragmentation-free by construction: rows are
    fungible (the NoC routes any layer's activations to any PE)."""

    def __init__(self, config: AcceleratorConfig):
        self.config = config
        self.free_rows = config.total_rows

    def can_allocate(self, rows: int) -> bool:
        return rows <= self.free_rows

    def allocate(self, rows: int) -> None:
        if rows > self.free_rows:
            raise RuntimeError(
                f"allocating {rows} rows but only {self.free_rows} free"
            )
        self.free_rows -= rows

    def release(self, rows: int) -> None:
        self.free_rows += rows
        if self.free_rows > self.config.total_rows:
            raise RuntimeError("released more rows than the pool owns")
