"""ARAS offline scheduler (paper §IV, Fig 6/9).

The scheduler statically fixes resource allocation, replication factors,
bank sets and the interleaving of write/compute tasks — DNN inference is
deterministic, so all decisions are made offline and reused across
inferences.  The decision logic lives in `repro.core.replication`,
`repro.core.bank_selection` and `repro.core.weight_reuse`; the timing engine
is the event-driven simulator (`repro.sim.aras`), run once with instruction
recording to produce the static instruction stream (Fig 6's output).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.bank_selection import BankSelection, make_banks, select_banks
from repro.core.layer_graph import LayerGraph
from repro.core.weight_reuse import LayerEncoding, encode_network
from repro.sim.aras import (
    ArasSimConfig,
    HETERO_BANKS_BYTES,
    Instruction,
    SimResult,
    simulate_aras,
)


@dataclasses.dataclass
class Schedule:
    """Output of the offline flow (Fig 6): the static execution plan."""

    graph: LayerGraph
    instructions: List[Instruction]
    encodings: List[LayerEncoding]
    reuse_center: Optional[int]
    bank_plan: Dict[int, BankSelection]
    predicted: SimResult

    @property
    def makespan_s(self) -> float:
        return self.predicted.makespan_s

    def writes(self) -> List[Instruction]:
        return [i for i in self.instructions if i.kind == "write"]

    def computes(self) -> List[Instruction]:
        return [i for i in self.instructions if i.kind == "compute"]


def build_schedule(
    graph: LayerGraph,
    layer_codes: Sequence[Tuple[str, np.ndarray]],
    config: ArasSimConfig = ArasSimConfig.variant("BRW"),
) -> Schedule:
    config = dataclasses.replace(config, record_instructions=True)
    result = simulate_aras(graph, layer_codes, config)
    encodings, center = encode_network(layer_codes, enabled=config.weight_reuse)
    banks = make_banks(
        HETERO_BANKS_BYTES if config.hetero_banks else (256 * 1024,) * 15,
        config.energy.sram_leak_w_per_kb,
        config.energy.sram_bank_overhead_w,
    )
    bank_plan = {
        li: select_banks(banks, l.in_act_bytes, l.out_act_bytes)
        for li, l in enumerate(graph.layers)
    }
    return Schedule(
        graph=graph,
        instructions=result.instructions,
        encodings=encodings,
        reuse_center=center,
        bank_plan=bank_plan,
        predicted=result,
    )


def validate_schedule(schedule: Schedule) -> List[str]:
    """Structural invariants of a legal ARAS schedule (used by tests and as a
    launch-time safety check):

    1. computes are in layer order and non-overlapping (layer-by-layer, §IV);
    2. every segment's weights are fully written before its compute starts;
    3. at no time do allocated rows exceed the pool.
    """
    errors: List[str] = []
    computes = schedule.computes()
    for a, b in zip(computes[:-1], computes[1:]):
        if b.t_start_cycles < a.t_end_cycles - 1e-6:
            errors.append(f"compute overlap: {a.segment} vs {b.segment}")
    write_end: Dict[str, float] = {}
    write_frac: Dict[str, float] = {}
    for w in schedule.writes():
        write_end[w.segment] = max(write_end.get(w.segment, 0.0), w.t_end_cycles)
        write_frac[w.segment] = write_frac.get(w.segment, 0.0) + w.fraction
    for c in computes:
        if c.segment not in write_end:
            errors.append(f"{c.segment} computed but never written")
            continue
        if write_frac[c.segment] < 1.0 - 1e-6:
            errors.append(f"{c.segment} only {write_frac[c.segment]:.2%} written")
        if write_end[c.segment] > c.t_start_cycles + 1e-6:
            errors.append(
                f"{c.segment} compute starts at {c.t_start_cycles:.0f} before "
                f"write completes at {write_end[c.segment]:.0f}"
            )
    return errors
