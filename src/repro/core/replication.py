"""Adaptive Weight Replication (paper §V-B, Algorithm 1).

Given the rows freed after a layer's compute, decide which upcoming layers to
write next and with what replication factors.  Replicating a layer r× lets r
activation windows be processed concurrently (compute latency / r) at the
cost of r× the weight writes and rows.

The iterative branch (plenty of rows free) mirrors Algorithm 1: start from
the largest window of K consecutive layers that fits unreplicated, then
repeatedly *drop the last layer of the window* and hand its rows (plus any
spare) to the currently-slowest layers, until the window's interior compute
latency no longer exceeds the write latency WL of the following wave — the
inflection point beyond which more replication cannot help (computation would
finish before the next weights are ready anyway).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class WriteItem:
    layer_idx: int
    replication: int     # ≥ 1; rows consumed = replication * base_rows
    rows: int            # total rows to allocate for this item
    fraction: float      # fraction of the layer's weights written (1.0 = full)


@dataclasses.dataclass(frozen=True)
class LayerCost:
    """Static per-layer quantities the planner needs."""

    base_rows: int            # rows for one replica
    compute_cycles: int       # unreplicated compute latency (windows * 96)
    max_replication: int      # typically min(windows, cap)
    write_dma_cycles: float = 0.0  # DMA cycles to write one replica


def _replicate_longest(
    candidates: List[int],
    costs: Sequence[LayerCost],
    factors: Dict[int, int],
    spare_rows: int,
    wl_gate: float,
) -> int:
    """Greedy: +1 replica to the slowest candidate while rows remain.

    ``wl_gate`` is the write latency WL of the wave that follows: once a
    layer's replicated compute latency drops to WL, further replication
    cannot improve the makespan (the machine will be waiting on writes
    anyway, §V-B) — so such layers stop being candidates.  This is also why
    FC-dominated DNNs (BERT) see zero replication: token counts are tiny, so
    compute is already far below WL (paper Fig 14).
    Returns leftover spare rows.
    """
    pool = list(candidates)

    def window_cycles() -> float:
        return sum(costs[i].compute_cycles / factors[i] for i in candidates)

    while pool:
        if wl_gate > 0 and window_cycles() <= wl_gate:
            break  # the wave already hides the next wave's writes
        # Current latency of each candidate given its factor.
        slowest = max(pool, key=lambda i: costs[i].compute_cycles / factors[i])
        cost = costs[slowest]
        f = factors[slowest]
        if wl_gate > 0:
            worthwhile = True
        else:
            # Tail wave: no following writes to hide behind — replicate while
            # the marginal compute saving beats the replica's own DMA cost.
            saving = cost.compute_cycles / f - cost.compute_cycles / (f + 1)
            worthwhile = saving > cost.write_dma_cycles
        if (
            not worthwhile
            or f >= cost.max_replication
            or cost.base_rows > spare_rows
        ):
            pool.remove(slowest)
            continue
        factors[slowest] += 1
        spare_rows -= cost.base_rows
    return spare_rows


def plan_writes(
    free_rows: int,
    next_idx: int,
    costs: Sequence[LayerCost],
    wl_cycles: Callable[[int], float],
    replication_enabled: bool = True,
) -> List[WriteItem]:
    """Algorithm 1: decide the next write wave.

    ``costs`` covers all layers; indices ≥ ``next_idx`` are unwritten.
    ``wl_cycles(idx)`` estimates the write latency of the wave that will
    follow a window ending at ``idx`` (the paper's WL threshold).
    """
    n = len(costs)
    if next_idx >= n or free_rows <= 0:
        return []

    L = next_idx
    need_l = costs[L].base_rows

    if free_rows < need_l:
        # Lines 2-3: partial write of L, never replicated.
        frac = free_rows / need_l
        return [WriteItem(L, 1, free_rows, frac)]

    next_need = costs[L + 1].base_rows if L + 1 < n else None
    if not replication_enabled:
        # Fit as many consecutive layers as possible, no replication.
        items, rows = [], free_rows
        i = L
        while i < n and rows >= costs[i].base_rows:
            items.append(WriteItem(i, 1, costs[i].base_rows, 1.0))
            rows -= costs[i].base_rows
            i += 1
        if i < n and rows > 0:
            items.append(WriteItem(i, 1, rows, rows / costs[i].base_rows))
        return items

    if next_need is None:
        # Final layer: replicate only while the marginal compute saving
        # beats the replica's own DMA cost (tail-wave gate).
        factors = {L: 1}
        _replicate_longest([L], costs, factors, free_rows - need_l,
                           wl_gate=0.0)
        return [WriteItem(L, factors[L], factors[L] * need_l, 1.0)]
    if free_rows < need_l + next_need:
        # Lines 4-5: only L fits entirely → replicate L into the free rows,
        # gated by the WL of the following wave.
        factors = {L: 1}
        _replicate_longest([L], costs, factors, free_rows - need_l,
                           wl_gate=wl_cycles(L + 1))
        return [WriteItem(L, factors[L], factors[L] * need_l, 1.0)]

    # Lines 6-17: iterative window shrinking.
    # K = number of consecutive layers that fit without replication.
    K, acc = 0, 0
    while L + K < n and acc + costs[L + K].base_rows <= free_rows:
        acc += costs[L + K].base_rows
        K += 1

    while True:
        window = list(range(L, L + K))
        factors = {i: 1 for i in window}
        spare = free_rows - sum(costs[i].base_rows for i in window)
        # WL of the wave following this window.  When nothing follows, any
        # compute reduction shows directly in the makespan → gate at 0.
        wl = wl_cycles(L + K) if L + K < n else 0.0
        _replicate_longest(window, costs, factors, spare, wl_gate=wl)
        interior = window[1:-1] if K > 2 else []
        interior_cycles = sum(
            costs[i].compute_cycles / factors[i] for i in interior
        )
        if K <= 2 or L + K >= n or interior_cycles <= wl:
            return [
                WriteItem(i, factors[i], factors[i] * costs[i].base_rows, 1.0)
                for i in window
            ]
        K -= 1
