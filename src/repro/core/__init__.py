"""ARAS core: the paper's primary contribution.

- `layer_graph`     — DNN layer IR (Fig 6's Data Flow Graph)
- `resources`       — PE/APU/crossbar pool accounting (Table II)
- `scheduler`       — offline scheduler producing the static instruction
                      stream (Fig 6/8/9); decisions reused by the TPU-native
                      streaming executor in `repro.streaming`
- `replication`     — Adaptive Weight Replication, Algorithm 1 (§V-B)
- `bank_selection`  — Adaptive Bank Selection ILP (§V-A)
- `weight_reuse`    — Adaptive Partial Weight Reuse (§V-C)
"""
from repro.core.layer_graph import LayerGraph, LayerNode, conv, fc
from repro.core.resources import AcceleratorConfig, RowPool
from repro.core.scheduler import Schedule, build_schedule, validate_schedule
from repro.core.replication import LayerCost, WriteItem, plan_writes
from repro.core.bank_selection import Bank, BankSelection, make_banks, select_banks
from repro.core.weight_reuse import (
    CENTERS,
    LayerEncoding,
    encode_network,
    cell_hist,
    expected_pulses_per_weight,
    expected_skip_per_cell,
)

__all__ = [
    "LayerGraph", "LayerNode", "conv", "fc",
    "AcceleratorConfig", "RowPool",
    "Schedule", "build_schedule", "validate_schedule",
    "LayerCost", "WriteItem", "plan_writes",
    "Bank", "BankSelection", "make_banks", "select_banks",
    "CENTERS", "LayerEncoding", "encode_network", "cell_hist",
    "expected_pulses_per_weight", "expected_skip_per_cell",
]
