"""Adaptive Bank Selection (paper §V-A, Eq. 1-2).

Choose, per layer, the minimal-leakage subset of heterogeneous Gbuffer banks
that covers the input activations and (disjointly) the output activations;
every unselected bank is power-gated during that layer's execution.

The paper formulates this as an ILP.  With ≤ 12 heterogeneous banks the
*exact* optimum is found by enumerating the 3^K {unused, input, output}
assignments with branch-and-bound pruning; for the homogeneous baseline the
optimum has a closed form (banks are fungible).  Both are exact solutions of
the ILP, requiring no external solver.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class Bank:
    size_bytes: int
    leakage_w: float


def make_banks(sizes_bytes: Sequence[int], leak_per_kb_w: float, overhead_w: float) -> List[Bank]:
    """CACTI-style leakage model: linear in capacity plus a fixed periphery term."""
    return [
        Bank(size_bytes=s, leakage_w=leak_per_kb_w * (s / 1024.0) + overhead_w)
        for s in sizes_bytes
    ]


@dataclasses.dataclass(frozen=True)
class BankSelection:
    input_banks: Tuple[int, ...]
    output_banks: Tuple[int, ...]
    leakage_w: float
    feasible: bool


def _homogeneous(banks: Sequence[Bank], in_bytes: int, out_bytes: int) -> BankSelection:
    size = banks[0].size_bytes
    leak = banks[0].leakage_w
    n_in = math.ceil(in_bytes / size) if in_bytes else 0
    n_out = math.ceil(out_bytes / size) if out_bytes else 0
    if n_in + n_out > len(banks):
        # Infeasible: activations must be processed in multiple passes; the
        # caller partitions the layer.  Report all banks active.
        return BankSelection(tuple(range(len(banks))), (), leak * len(banks), False)
    return BankSelection(
        tuple(range(n_in)),
        tuple(range(n_in, n_in + n_out)),
        leak * (n_in + n_out),
        True,
    )


def select_banks(banks: Sequence[Bank], in_bytes: int, out_bytes: int) -> BankSelection:
    """Exact minimal-leakage disjoint double cover (the paper's ILP)."""
    if len(set((b.size_bytes, b.leakage_w) for b in banks)) == 1:
        return _homogeneous(banks, in_bytes, out_bytes)

    # Order banks by descending size for stronger bound pruning.
    order = sorted(range(len(banks)), key=lambda i: -banks[i].size_bytes)
    best = {"leak": float("inf"), "in": (), "out": ()}
    suffix_size = [0] * (len(order) + 1)
    for i in range(len(order) - 1, -1, -1):
        suffix_size[i] = suffix_size[i + 1] + banks[order[i]].size_bytes

    def rec(i: int, in_cov: int, out_cov: int, leak: float, ins: tuple, outs: tuple):
        if leak >= best["leak"]:
            return
        if in_cov >= in_bytes and out_cov >= out_bytes:
            best.update({"leak": leak, "in": ins, "out": outs})
            return
        if i == len(order):
            return
        remaining = suffix_size[i]
        if in_cov + out_cov + remaining < in_bytes + out_bytes:
            return  # cannot cover even using every remaining bank
        b = order[i]
        bank = banks[b]
        # Branch: unused / input / output.  Try "used" branches first so the
        # incumbent tightens quickly.
        if in_cov < in_bytes:
            rec(i + 1, in_cov + bank.size_bytes, out_cov, leak + bank.leakage_w,
                ins + (b,), outs)
        if out_cov < out_bytes:
            rec(i + 1, in_cov, out_cov + bank.size_bytes, leak + bank.leakage_w,
                ins, outs + (b,))
        rec(i + 1, in_cov, out_cov, leak, ins, outs)

    rec(0, 0, 0, 0.0, (), ())
    if best["leak"] is float("inf") or best["leak"] == float("inf"):
        return BankSelection(tuple(range(len(banks))), (),
                             sum(b.leakage_w for b in banks), False)
    return BankSelection(tuple(best["in"]), tuple(best["out"]), best["leak"], True)


def total_leakage(banks: Sequence[Bank]) -> float:
    return sum(b.leakage_w for b in banks)
