"""Adaptive Partial Weight Reuse (paper §V-C).

Re-encode each layer's INT8 weight codes by shifting the layer mean to a
common *Center* so that consecutive layers overwriting the same ReRAM cells
agree on the most-significant 2-bit cells.  Equal cells are skipped; smaller
deltas take fewer programming pulses.  The shift is exactly compensated in
the zero point at de-quantization (see `repro.xbar.quant`), so it is free.

Distribution-level machinery: the simulator needs, per ordered layer pair
(old occupant → new occupant), the expected pulses/weight and skip ratios.
Pairing of individual weights inside a crossbar is effectively random across
layers, so the exact expectation follows from the per-cell level histograms
(the paper's P_i(k) of Eq. 3) — no elementwise pass over 100M-weight tensors
is needed inside the event loop.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.xbar.cells import CELLS_PER_WEIGHT, LEVELS

# The six viable centers of §V-C (mid-points of MSB-cell sections away from
# the clipping extremes).
CENTERS: Tuple[int, ...] = (88, 104, 96, 160, 152, 168)

# |a - b| matrix over the 4 levels of a 2-bit cell.
_ABS_DELTA = np.abs(np.arange(LEVELS)[:, None] - np.arange(LEVELS)[None, :]).astype(np.float64)
_EQ = np.eye(LEVELS, dtype=np.float64)


def cell_hist(codes: np.ndarray) -> np.ndarray:
    """Per-cell level histograms, shape (CELLS_PER_WEIGHT, LEVELS)."""
    c = codes.astype(np.int64).reshape(-1)
    hists = np.empty((CELLS_PER_WEIGHT, LEVELS), dtype=np.float64)
    for i in range(CELLS_PER_WEIGHT):
        levels = (c >> (2 * i)) & (LEVELS - 1)
        hists[i] = np.bincount(levels, minlength=LEVELS) / max(c.size, 1)
    return hists


#: Histogram of pristine (erased) cells — all at level 0.
ERASED_HIST: np.ndarray = np.tile(
    np.eye(LEVELS, dtype=np.float64)[0], (CELLS_PER_WEIGHT, 1)
)


def expected_pulses_per_weight(hist_old: np.ndarray, hist_new: np.ndarray) -> float:
    """E[Σ_cells |Δ level|] when hist_new overwrites hist_old (random pairing)."""
    total = 0.0
    for i in range(CELLS_PER_WEIGHT):
        total += float(hist_old[i] @ _ABS_DELTA @ hist_new[i])
    return total


def expected_skip_per_cell(hist_old: np.ndarray, hist_new: np.ndarray) -> np.ndarray:
    """Paper Eq. 3 per cell: Σ_k P_old(k)·P_new(k), shape (4,)."""
    return np.array(
        [float(hist_old[i] @ _EQ @ hist_new[i]) for i in range(CELLS_PER_WEIGHT)]
    )


@dataclasses.dataclass(frozen=True)
class LayerEncoding:
    """Re-encoding decision for one layer."""

    name: str
    offset: int                # code-domain shift (0 for first layer / reuse off)
    clip_rate: float           # fraction of codes clipped by the shift
    hist: np.ndarray           # (4, 4) per-cell level histograms after shift


def _shift_codes(codes: np.ndarray, offset: int) -> Tuple[np.ndarray, float]:
    shifted = codes.astype(np.int64) + offset
    clipped = np.count_nonzero((shifted < 0) | (shifted > 255)) / max(codes.size, 1)
    return np.clip(shifted, 0, 255).astype(np.uint8), clipped


def encode_network(
    layer_codes: Sequence[Tuple[str, np.ndarray]],
    enabled: bool = True,
    max_clip_rate: float = 1e-3,
    centers: Sequence[int] = CENTERS,
    shift_first_layer: bool = False,
) -> Tuple[List[LayerEncoding], Optional[int]]:
    """Pick the best common Center for a network and re-encode every layer.

    Follows §V-C: evaluates every candidate center, discards centers whose
    worst-layer clip rate exceeds ``max_clip_rate`` (the accuracy guard), and
    keeps the one maximizing the average expected MSB-cell skip ratio between
    consecutive layers.  The first layer is never shifted (paper: first-layer
    perturbations are disproportionately harmful).

    Returns (encodings, chosen_center).  ``chosen_center`` is None when reuse
    is disabled or no center passes the clip guard.
    """
    names = [n for n, _ in layer_codes]
    raw = [c for _, c in layer_codes]
    if not enabled or len(raw) == 0:
        encs = [
            LayerEncoding(n, 0, 0.0, cell_hist(c)) for n, c in zip(names, raw)
        ]
        return encs, None

    best_center, best_score, best_encs = None, -np.inf, None
    for center in centers:
        encs: List[LayerEncoding] = []
        worst_clip = 0.0
        for li, codes in enumerate(raw):
            if li == 0 and not shift_first_layer:
                shifted, clip, off = codes, 0.0, 0
            else:
                off = int(round(center - float(np.mean(codes.astype(np.float64)))))
                shifted, clip = _shift_codes(codes, off)
            worst_clip = max(worst_clip, clip)
            encs.append(LayerEncoding(names[li], off, clip, cell_hist(shifted)))
        if worst_clip > max_clip_rate:
            continue
        # Score: mean MSB-cell (cells 2, 3) skip ratio over consecutive pairs.
        if len(encs) > 1:
            score = float(
                np.mean(
                    [
                        expected_skip_per_cell(a.hist, b.hist)[2:].sum()
                        for a, b in zip(encs[:-1], encs[1:])
                    ]
                )
            )
        else:
            score = 0.0
        if score > best_score:
            best_center, best_score, best_encs = center, score, encs

    if best_encs is None:  # no center met the accuracy guard → reuse disabled
        encs = [LayerEncoding(n, 0, 0.0, cell_hist(c)) for n, c in zip(names, raw)]
        return encs, None
    return best_encs, best_center


def pulse_matrix(encodings: Sequence[LayerEncoding]) -> np.ndarray:
    """(L+1, L) expected pulses/weight; row 0 is the erased state."""
    hists = [ERASED_HIST] + [e.hist for e in encodings]
    out = np.zeros((len(hists), len(encodings)))
    for i, ho in enumerate(hists):
        for j, e in enumerate(encodings):
            out[i, j] = expected_pulses_per_weight(ho, e.hist)
    return out
