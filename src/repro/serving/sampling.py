"""Token sampling for the serving engine: greedy by default, temperature /
top-k with a seeded per-request PRNG key otherwise.

Determinism contract: a request's n-th generated token depends only on
(logits, seed, n) — the key is `fold_in(PRNGKey(seed), n)` — so identical
requests through any engine schedule (continuous batch, preemption and
re-prefill, paged vs slot layout) sample identical tokens.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def request_key(seed: Optional[int], rid: int) -> jax.Array:
    """Per-request PRNG root: the explicit seed, else the rid (stable across
    re-admissions — the rid never changes)."""
    return jax.random.PRNGKey(rid if seed is None else seed)


def sample_token(logits: jax.Array, vocab: int, *, temperature: float = 0.0,
                 top_k: int = 0, key: Optional[jax.Array] = None,
                 step: int = 0) -> int:
    """One token from a single row of next-token logits (≥ vocab wide;
    padded tail ignored).  temperature <= 0 is greedy argmax — the engine's
    default, token-for-token identical to the pre-sampling behavior."""
    logits = logits[:vocab]
    if temperature <= 0.0:
        return int(jnp.argmax(logits))
    if key is None:
        raise ValueError("temperature sampling needs a PRNG key")
    scaled = logits.astype(jnp.float32) / temperature
    if 0 < top_k < vocab:
        kth = jax.lax.top_k(scaled, top_k)[0][-1]
        scaled = jnp.where(scaled < kth, -jnp.inf, scaled)
    return int(jax.random.categorical(jax.random.fold_in(key, step), scaled))


def sample_tokens(logits: jax.Array, vocab: int, *, temperatures: jax.Array,
                  top_ks: jax.Array, keys: jax.Array,
                  steps: jax.Array) -> jax.Array:
    """Batched `sample_token`: one device call for a whole decode batch.

    logits (B, V≥vocab) — the padded vocab tail is masked off; temperatures
    (B,) f32 (<= 0 → greedy argmax for that row); top_ks (B,) int32 (0 or
    ≥ vocab → disabled); keys (B, 2) raw uint32 per-request PRNG roots;
    steps (B,) int32 fold_in indices (= n tokens already generated).
    Returns (B,) int32 token ids, row-for-row identical to per-row
    `sample_token` calls — same kth-value top-k cut, same
    `fold_in(key, step)` stream — so the determinism contract survives
    batching.  Jit-safe; rows the caller doesn't care about can carry
    temperature 0 / zero keys and be discarded."""
    logits = logits[:, :vocab]
    temperatures = jnp.asarray(temperatures, jnp.float32)
    top_ks = jnp.asarray(top_ks, jnp.int32)
    steps = jnp.asarray(steps, jnp.int32)
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    safe_t = jnp.where(temperatures > 0.0, temperatures, 1.0)
    scaled = logits.astype(jnp.float32) / safe_t[:, None]
    # per-row k-th largest value (== lax.top_k(row, k)[0][-1]): one
    # descending sort, then pick column k-1
    sorted_desc = -jnp.sort(-scaled, axis=-1)
    kth = jnp.take_along_axis(
        sorted_desc, jnp.clip(top_ks - 1, 0, vocab - 1)[:, None], axis=-1)
    use_topk = ((top_ks > 0) & (top_ks < vocab))[:, None]
    scaled = jnp.where(use_topk & (scaled < kth), -jnp.inf, scaled)

    def draw(key, step, row):
        return jax.random.categorical(jax.random.fold_in(key, step), row)

    sampled = jax.vmap(draw)(keys, steps, scaled).astype(jnp.int32)
    return jnp.where(temperatures <= 0.0, greedy, sampled)
