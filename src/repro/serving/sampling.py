"""Token sampling for the serving engine: greedy by default, temperature /
top-k with a seeded per-request PRNG key otherwise.

Determinism contract: a request's n-th generated token depends only on
(logits, seed, n) — the key is `fold_in(PRNGKey(seed), n)` — so identical
requests through any engine schedule (continuous batch, preemption and
re-prefill, paged vs slot layout) sample identical tokens.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def request_key(seed: Optional[int], rid: int) -> jax.Array:
    """Per-request PRNG root: the explicit seed, else the rid (stable across
    re-admissions — the rid never changes)."""
    return jax.random.PRNGKey(rid if seed is None else seed)


def sample_token(logits: jax.Array, vocab: int, *, temperature: float = 0.0,
                 top_k: int = 0, key: Optional[jax.Array] = None,
                 step: int = 0) -> int:
    """One token from a single row of next-token logits (≥ vocab wide;
    padded tail ignored).  temperature <= 0 is greedy argmax — the engine's
    default, token-for-token identical to the pre-sampling behavior."""
    logits = logits[:vocab]
    if temperature <= 0.0:
        return int(jnp.argmax(logits))
    if key is None:
        raise ValueError("temperature sampling needs a PRNG key")
    scaled = logits.astype(jnp.float32) / temperature
    if 0 < top_k < vocab:
        kth = jax.lax.top_k(scaled, top_k)[0][-1]
        scaled = jnp.where(scaled < kth, -jnp.inf, scaled)
    return int(jax.random.categorical(jax.random.fold_in(key, step), scaled))
