"""Wear telemetry: per-slot / per-page write, cell-flip, and pulse counts.

ARAS §V-C minimizes HOW MUCH each install writes (equal 2-bit cells are
skipped, pulses track |Δ level|); Hamun-style endurance management needs to
know WHERE those writes land before any wear-aware policy can steer them.
`WearPlane` is one physical write plane tracked id by id — the weight
arena's slots, or a paged tenant's KV page pool — and `WearMap` is the
engine-owned registry of planes.  Leaf modules record into an injected
plane exactly like they emit into the injected tracer
(`WeightResidencyManager._install` for weight-slot flips/pulses,
`PagedKVArena` for page programs); the spread summaries (Gini, hottest-N,
write-count histogram) and the deterministic JSON export live here so the
victim picker and page allocator have observables to steer by.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

_METRICS = ("writes", "flips", "pulses")


def gini_coefficient(counts) -> float:
    """Gini coefficient of a non-negative count vector: 0 = perfectly even
    wear, → 1 = one location takes every write.  Degenerate inputs (empty,
    single slot, all-zero) are 0.0 by convention — no spread to speak of."""
    x = np.sort(np.asarray(counts, np.float64))
    n = x.size
    total = float(x.sum())
    if n <= 1 or total <= 0.0:
        return 0.0
    idx = np.arange(1, n + 1, dtype=np.float64)
    return float(2.0 * float((idx * x).sum()) / (n * total) - (n + 1) / n)


class WearPlane:
    """Write accounting over ids `first .. first + n - 1` of one plane.

    `first` shifts the id space so reserved ids stay untracked — KV planes
    start at 1 because device page 0 is the scratch page and never takes
    an accounted write."""

    __slots__ = ("name", "first", "writes", "flips", "pulses", "by_group",
                 "retired")

    def __init__(self, name: str, n: int, first: int = 0):
        if n < 1:
            raise ValueError(f"wear plane {name!r} needs at least one slot")
        self.name = name
        self.first = first
        self.writes = np.zeros(n, np.int64)
        self.flips = np.zeros(n, np.int64)
        self.pulses = np.zeros(n, np.int64)
        # (id, group) -> [writes, flips, pulses]: the slot×layer-group
        # dimension — which layer family produced each slot's wear
        self.by_group: Dict[Tuple[int, object], List[int]] = {}
        # ids permanently pulled from service after a stuck-at fault —
        # the fault-degradation half of the Hamun story; the owning
        # arena stops issuing them, this plane just remembers them
        self.retired: set = set()

    @property
    def n(self) -> int:
        return int(self.writes.size)

    def record(self, idx: int, *, writes: int = 1, flips: int = 0,
               pulses: int = 0, group=None) -> None:
        i = idx - self.first
        self.writes[i] += writes
        self.flips[i] += flips
        self.pulses[i] += pulses
        if group is not None:
            acc = self.by_group.setdefault((idx, group), [0, 0, 0])
            acc[0] += writes
            acc[1] += flips
            acc[2] += pulses

    def retire(self, idx: int) -> None:
        """Mark id `idx` permanently failed (stuck-at fault detected at
        program time).  Idempotent; wear already accrued stays counted —
        the pulses were spent even though the write didn't verify."""
        self.retired.add(int(idx))

    def counts(self, metric: str = "writes") -> np.ndarray:
        if metric not in _METRICS:
            raise KeyError(f"unknown wear metric {metric!r} "
                           f"(expected one of {_METRICS})")
        return getattr(self, metric)

    def total(self, metric: str = "writes") -> int:
        return int(self.counts(metric).sum())

    def gini(self, metric: str = "writes") -> float:
        return gini_coefficient(self.counts(metric))

    def hottest(self, k: int = 3, metric: str = "writes"
                ) -> List[Tuple[int, int]]:
        """Top-k (id, count) by wear, hottest first; ties break toward the
        lower id so the ranking (and the JSON export) is deterministic."""
        c = self.counts(metric)
        order = np.lexsort((np.arange(c.size), -c))[:k]
        return [(int(i) + self.first, int(c[i])) for i in order]

    def histogram(self, metric: str = "writes", bins: int = 8) -> Dict:
        """Write-count histogram over the plane's ids (the ROADMAP's
        endurance observable): how many locations sit in each wear band."""
        c = self.counts(metric)
        hi = max(int(c.max()), 1)
        counts, edges = np.histogram(c, bins=min(bins, hi), range=(0, hi))
        return {"edges": [float(e) for e in edges],
                "counts": [int(v) for v in counts]}

    def summary(self) -> Dict[str, float]:
        return {
            "n_slots": float(self.n),
            "writes": float(self.total("writes")),
            "flips": float(self.total("flips")),
            "pulses": float(self.total("pulses")),
            "gini_writes": self.gini("writes"),
            "gini_flips": self.gini("flips"),
            "retired": float(len(self.retired)),
        }

    def as_json(self) -> Dict:
        """Deterministic strict-JSON document (`serve.py --wear-json`)."""
        return {
            "first": self.first,
            "writes": [int(v) for v in self.writes],
            "flips": [int(v) for v in self.flips],
            "pulses": [int(v) for v in self.pulses],
            "gini": {m: self.gini(m) for m in _METRICS},
            "hottest": [[i, c] for i, c in self.hottest()],
            "retired": sorted(self.retired),
            "histogram": self.histogram(),
            "by_group": {
                f"{i}/{g}": list(v) for (i, g), v in sorted(
                    self.by_group.items(),
                    key=lambda kv: (kv[0][0], str(kv[0][1])))},
        }


class WearMap:
    """Engine-owned registry of wear planes, one per physical write plane
    (plane "weight" for the arena slots, "kv:<tenant>" per page pool)."""

    def __init__(self):
        self.planes: Dict[str, WearPlane] = {}

    def add_plane(self, name: str, n: int, first: int = 0) -> WearPlane:
        if name in self.planes:
            raise ValueError(f"wear plane {name!r} already registered")
        plane = WearPlane(name, n, first=first)
        self.planes[name] = plane
        return plane

    def plane(self, name: str) -> WearPlane:
        return self.planes[name]

    def gini(self, metric: str = "writes", prefix: str = "") -> float:
        """Spread over the concatenated counts of every plane whose name
        starts with `prefix` (all planes by default) — cross-tenant KV
        wear is one question, not one per pool."""
        parts = [p.counts(metric) for name, p in self.planes.items()
                 if name.startswith(prefix)]
        return gini_coefficient(np.concatenate(parts)) if parts else 0.0

    def as_json(self) -> Dict:
        return {name: self.planes[name].as_json()
                for name in sorted(self.planes)}
