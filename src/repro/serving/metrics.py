"""Engine metrics surface: latency percentiles, throughput, queue depth,
and the weight-arena install accounting merged in by the engine.

`EngineMetrics` is backed by a typed `MetricsRegistry` of counters,
gauges, and histograms; the legacy attribute names (`tokens_generated`,
`preemptions`, ...) and every `summary()` key are preserved on top of it.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.serving.request import Request


def _pct(xs: List[float], p: float) -> float:
    if not xs:
        return float("nan")
    return float(np.percentile(np.asarray(xs, np.float64), p))


class Counter:
    """Monotonic counter (ints stay ints so summaries render cleanly)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, delta: float = 1) -> None:
        if delta < 0:
            raise ValueError(f"counter {self.name} cannot decrease")
        self.value += delta


class Gauge:
    """Point-in-time value with a high-water mark."""

    __slots__ = ("name", "value", "max")

    def __init__(self, name: str):
        self.name = name
        self.value = 0
        self.max = 0

    def set(self, value: float) -> None:
        self.value = value
        if value > self.max:
            self.max = value


class Histogram:
    """Sample accumulator with numpy-percentile quantiles.

    `quantile(p)` matches the legacy `_pct` helper exactly: linear
    interpolation via `np.percentile`, NaN on an empty window.
    """

    __slots__ = ("name", "values")

    def __init__(self, name: str):
        self.name = name
        self.values: List[float] = []

    def observe(self, value: float) -> None:
        self.values.append(float(value))

    @property
    def count(self) -> int:
        return len(self.values)

    @property
    def sum(self) -> float:
        return float(sum(self.values))

    def quantile(self, p: float) -> float:
        return _pct(self.values, p)

    def mean(self) -> float:
        return float(np.mean(self.values)) if self.values else float("nan")


class MetricsRegistry:
    """Typed metric registry: one named instrument per metric.

    `counter`/`gauge`/`histogram` get-or-create; asking for an existing
    name with a different type is an error.  `as_dict()` flattens every
    instrument to floats for JSON export (`serve.py --metrics-json`).
    """

    def __init__(self):
        self._metrics: Dict[str, object] = {}

    def _get(self, name: str, cls):
        m = self._metrics.get(name)
        if m is None:
            m = cls(name)
            self._metrics[name] = m
        elif not isinstance(m, cls):
            raise TypeError(
                f"metric {name!r} already registered as "
                f"{type(m).__name__}, not {cls.__name__}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    def names(self) -> List[str]:
        return sorted(self._metrics)

    def as_dict(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for name in self.names():
            m = self._metrics[name]
            if isinstance(m, Counter):
                out[name] = float(m.value)
            elif isinstance(m, Gauge):
                out[name] = float(m.value)
                out[f"{name}_max"] = float(m.max)
            elif isinstance(m, Histogram):
                out[f"{name}_count"] = float(m.count)
                out[f"{name}_p50"] = m.quantile(50)
                out[f"{name}_p95"] = m.quantile(95)
        return out


class VirtualClock:
    """Injectable simulated time for the engine's `clock` hook: the test
    harness and benchmarks advance it explicitly per step, making every
    latency/stall metric deterministic — no device, no wall clock."""

    def __init__(self, t: float = 0.0):
        self.t = float(t)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError("virtual time cannot run backwards")
        self.t += dt
        return self.t


@dataclasses.dataclass
class StepRecord:
    t: float
    n_active: int
    queue_depth: int
    n_prefills: int
    n_decoded: int
    install_wire_bytes: int
    # paged-KV occupancy snapshot (0/0 when every tenant is slot-managed)
    kv_used_pages: int = 0
    kv_total_pages: int = 0
    # install-pipeline accounting (all zero on the unbudgeted ensure() path):
    # wire bytes of install stream pumped this step (partial installs
    # included), how much of it was hidden under decode/prefill compute, and
    # whether a scheduled tenant sat blocked on installs with no tokens out.
    install_work_bytes: int = 0
    overlap_hidden_bytes: int = 0
    install_stall: bool = False
    # chunked prefill: prompt tokens of chunk work this step (monolithic
    # prefills count their whole prompt here) and chunks launched — the
    # virtual-clock cost models charge step time against prefill_tokens
    prefill_tokens: int = 0
    n_prefill_chunks: int = 0
    # radix-tree prefix cache: prompt tokens served from cached pages this
    # step (skipped chunks — they cost no compute and no prefill budget)
    # and the retained-page gauge across all paged tenants
    prefix_hit_tokens: int = 0
    prefix_cached_pages: int = 0
    # host syncs spent pulling sampled tokens (or logits) off device this
    # step: 1 per decoded tenant batch (fused or batched sampler), never
    # per row
    sample_syncs: int = 0
    # tracer component breakdown for this step: component name -> seconds
    # spent inside spans of that component (empty when tracing is off)
    component_s: Dict[str, float] = dataclasses.field(default_factory=dict)


def _counter_property(attr: str):
    """Expose a registry counter under a legacy EngineMetrics attribute."""

    def fget(self) -> int:
        return getattr(self, attr).value

    def fset(self, value) -> None:
        getattr(self, attr).value = value

    return property(fget, fset)


class EngineMetrics:
    """Aggregate engine metrics, backed by a typed `MetricsRegistry`.

    The legacy counter attributes (`tokens_generated`, `preemptions`, ...)
    are properties over registry instruments, so both the old attribute
    surface and `registry.as_dict()` see the same numbers.
    """

    def __init__(self, registry: Optional[MetricsRegistry] = None):
        self.registry = registry if registry is not None else MetricsRegistry()
        self.finished: List[Request] = []
        self.steps: List[StepRecord] = []
        r = self.registry
        self._c_tokens = r.counter("engine_tokens_generated")
        self._c_preemptions = r.counter("engine_preemptions")
        self._c_prefill_tokens = r.counter("engine_prefill_tokens")
        self._c_prefill_chunks = r.counter("engine_prefill_chunks")
        self._c_prefix_hit_tokens = r.counter("engine_prefix_hit_tokens")
        self._g_concurrent = r.gauge("engine_concurrent")
        self._g_queue_depth = r.gauge("engine_queue_depth")
        self._h_latency = r.histogram("request_latency_s")
        self._h_ttft = r.histogram("request_ttft_s")
        self._h_ttft_queue = r.histogram("request_ttft_queue_s")
        self._h_ttft_prefill = r.histogram("request_ttft_prefill_s")
        self._h_itl_max = r.histogram("request_itl_max_s")

    tokens_generated = _counter_property("_c_tokens")
    preemptions = _counter_property("_c_preemptions")
    prefill_tokens = _counter_property("_c_prefill_tokens")
    prefill_chunks = _counter_property("_c_prefill_chunks")
    prefix_hit_tokens = _counter_property("_c_prefix_hit_tokens")

    @property
    def max_concurrent(self) -> int:
        return self._g_concurrent.max

    @max_concurrent.setter
    def max_concurrent(self, value: int) -> None:
        self._g_concurrent.max = value

    def record_step(self, rec: StepRecord) -> None:
        self.steps.append(rec)
        self._g_concurrent.set(rec.n_active)
        self._g_queue_depth.set(rec.queue_depth)
        self._c_tokens.inc(rec.n_decoded + rec.n_prefills)
        self._c_prefill_tokens.inc(rec.prefill_tokens)
        self._c_prefill_chunks.inc(rec.n_prefill_chunks)
        self._c_prefix_hit_tokens.inc(rec.prefix_hit_tokens)

    def record_finish(self, req: Request) -> None:
        self.finished.append(req)
        if req.latency is not None:
            self._h_latency.observe(req.latency)
        if req.ttft is not None:
            self._h_ttft.observe(req.ttft)
        if req.ttft_queue is not None:
            self._h_ttft_queue.observe(req.ttft_queue)
        if req.ttft_prefill is not None:
            self._h_ttft_prefill.observe(req.ttft_prefill)
        if req.max_itl is not None:
            self._h_itl_max.observe(req.max_itl)

    def record_preemption(self) -> None:
        self._c_preemptions.inc()

    def summary(self, wall_s: float,
                residency: Optional[Dict[str, float]] = None,
                rejected: int = 0,
                paging: Optional[Dict[str, float]] = None,
                prefill_cache: Optional[Dict[str, int]] = None,
                wear: Optional[Dict[str, float]] = None
                ) -> Dict[str, float]:
        # Histograms are fed by record_finish with exactly the non-None
        # per-request stats, so quantiles match the legacy list-comp path.
        lat = self._h_latency.values
        ttft = self._h_ttft.values
        ttft_q = self._h_ttft_queue.values
        ttft_p = self._h_ttft_prefill.values
        itl = self._h_itl_max.values
        depths = [s.queue_depth for s in self.steps]
        out = {
            "requests_finished": float(len(self.finished)),
            "requests_rejected": float(rejected),
            "tokens_generated": float(self.tokens_generated),
            "tokens_per_s": self.tokens_generated / max(wall_s, 1e-9),
            "latency_p50_s": _pct(lat, 50),
            "latency_p95_s": _pct(lat, 95),
            "ttft_p50_s": _pct(ttft, 50),
            "ttft_p95_s": _pct(ttft, 95),
            # TTFT split: queued-for-admission vs chunk-prefilling time (a
            # prefill-token budget trades the latter against decode ITL)
            "ttft_queue_p50_s": _pct(ttft_q, 50),
            "ttft_queue_p95_s": _pct(ttft_q, 95),
            "ttft_prefill_p50_s": _pct(ttft_p, 50),
            "ttft_prefill_p95_s": _pct(ttft_p, 95),
            "prefill_tokens": float(self.prefill_tokens),
            "prefill_chunks": float(self.prefill_chunks),
            # prefix cache: prompt tokens served from retained pages
            # instead of chunk compute; hit rate over all prompt tokens
            # the engine covered (computed + skipped)
            "prefix_hit_tokens": float(self.prefix_hit_tokens),
            "prefix_hit_rate": (
                self.prefix_hit_tokens
                / max(self.prefix_hit_tokens + self.prefill_tokens, 1)),
            # worst inter-token gap per request: the tenant-boundary stall a
            # mean latency hides (install stalls land exactly here)
            "itl_max_p50_s": _pct(itl, 50),
            "itl_max_p95_s": _pct(itl, 95),
            "queue_depth_mean": float(np.mean(depths)) if depths else 0.0,
            "queue_depth_max": float(max(depths)) if depths else 0.0,
            "max_concurrent": float(self.max_concurrent),
            "preemptions": float(self.preemptions),
            "steps": float(len(self.steps)),
            "install_stall_steps": float(
                sum(1 for s in self.steps if s.install_stall)),
            "install_work_bytes": float(
                sum(s.install_work_bytes for s in self.steps)),
            "overlap_hidden_bytes": float(
                sum(s.overlap_hidden_bytes for s in self.steps)),
            "wall_s": wall_s,
        }
        # Tracer component breakdown: total seconds per component across
        # all steps (only present when a tracer fed StepRecord.component_s).
        comp_totals: Dict[str, float] = {}
        for s_rec in self.steps:
            for comp, secs in s_rec.component_s.items():
                comp_totals[comp] = comp_totals.get(comp, 0.0) + secs
        for comp, secs in sorted(comp_totals.items()):
            out[f"component_{comp}_s"] = secs
        if prefill_cache:
            # jit-trace accounting from launch.steps.prefill_cache_info —
            # process-wide (step caches are shared across engine instances
            # of one config), so read deltas when comparing arms
            out.update({f"prefill_cache_{k}": float(v)
                        for k, v in prefill_cache.items()})
        if residency:
            out.update(residency)
        if paging:
            occ = [s.kv_used_pages / s.kv_total_pages
                   for s in self.steps if s.kv_total_pages]
            out.update(paging)
            out["kv_page_occupancy_mean"] = (
                float(np.mean(occ)) if occ else 0.0)
            out["kv_page_occupancy_max"] = float(max(occ)) if occ else 0.0
            cached = [s.prefix_cached_pages for s in self.steps]
            out["prefix_cached_pages_mean"] = (
                float(np.mean(cached)) if cached else 0.0)
            out["prefix_cached_pages_max"] = (
                float(max(cached)) if cached else 0.0)
        if wear:
            # engine._wear_stats(): install/KV write energy priced through
            # the EnergyModel plus the WearMap spread coefficients
            out.update(wear)
        # Per-tenant attribution: every finished request knows its tenant
        # (`Request.model`), so the summary splits the latency picture by
        # tenant under dotted `tenant.<name>.<metric>` keys (no other
        # summary key contains a dot — format_summary keys off that).
        by_tenant: Dict[str, List[Request]] = {}
        for req in self.finished:
            by_tenant.setdefault(req.model, []).append(req)
        for name in sorted(by_tenant):
            reqs = by_tenant[name]
            toks = float(sum(len(r.generated) for r in reqs))
            pre = f"tenant.{name}."
            out[pre + "requests"] = float(len(reqs))
            out[pre + "tokens_generated"] = toks
            out[pre + "tokens_per_s"] = toks / max(wall_s, 1e-9)
            out[pre + "ttft_p95_s"] = _pct(
                [r.ttft for r in reqs if r.ttft is not None], 95)
            out[pre + "itl_max_p95_s"] = _pct(
                [r.max_itl for r in reqs if r.max_itl is not None], 95)
        return out


def format_summary(s: Dict[str, float]) -> str:
    lines = [
        f"finished {int(s['requests_finished'])} requests "
        f"({int(s['requests_rejected'])} rejected, "
        f"{int(s['preemptions'])} preemptions) in {s['wall_s']*1e3:.0f} ms "
        f"over {int(s['steps'])} steps",
        f"throughput {s['tokens_per_s']:.1f} tok/s, "
        f"max concurrent {int(s['max_concurrent'])}",
        f"latency p50/p95 {s['latency_p50_s']*1e3:.1f}/"
        f"{s['latency_p95_s']*1e3:.1f} ms, "
        f"ttft p50/p95 {s['ttft_p50_s']*1e3:.1f}/"
        f"{s['ttft_p95_s']*1e3:.1f} ms",
        f"queue depth mean/max {s['queue_depth_mean']:.1f}/"
        f"{int(s['queue_depth_max'])}",
    ]
    if "kv_pages_total" in s:
        lines.append(
            f"paged KV: occupancy mean/max "
            f"{s['kv_page_occupancy_mean']:.1%}/"
            f"{s['kv_page_occupancy_max']:.1%} of "
            f"{int(s['kv_pages_total'])} pages, "
            f"{int(s['kv_shared_page_hits'])} shared-page hits "
            f"({int(s['kv_pages_saved'])} pages saved), "
            f"{int(s['kv_cow_copies'])} COW copies")
    if "install_wire_bytes" in s:
        lines.append(
            f"weight installs: {int(s['installs'])} "
            f"({int(s['cross_tenant_installs'])} cross-tenant), "
            f"{s['install_wire_bytes']/1e6:.2f} MB wire vs "
            f"{s['install_raw_bytes']/1e6:.2f} MB raw "
            f"(saved {s['install_savings']:.1%}, "
            f"skip {s['install_mean_skip']:.1%})")
    if s.get("prefix_hit_tokens", 0) or s.get("kv_prefix_cached_pages", 0):
        lines.append(
            f"prefix cache: {int(s['prefix_hit_tokens'])} prompt tokens "
            f"served from cache ({s['prefix_hit_rate']:.1%} hit rate), "
            f"{int(s.get('kv_prefix_cached_pages', 0))} pages resident "
            f"(max {int(s.get('prefix_cached_pages_max', 0))}), "
            f"{int(s.get('kv_prefix_evictions', 0))} LRU evictions")
    if s.get("prefill_chunks", 0):
        lines.append(
            f"chunked prefill: {int(s['prefill_tokens'])} prompt tokens in "
            f"{int(s['prefill_chunks'])} chunks; ttft queue/prefill p95 "
            f"{s['ttft_queue_p95_s']*1e3:.1f}/{s['ttft_prefill_p95_s']*1e3:.1f}"
            f" ms; {int(s.get('prefill_cache_traces', 0))} prefill traces "
            f"process-wide")
    if s.get("install_work_bytes", 0) or s.get("install_stall_steps", 0):
        hidden = s["overlap_hidden_bytes"]
        work = max(s["install_work_bytes"], 1.0)
        lines.append(
            f"install pipeline: {int(s['install_stall_steps'])} stall steps, "
            f"{hidden/1e6:.2f} MB of {s['install_work_bytes']/1e6:.2f} MB "
            f"hidden under decode ({hidden/work:.0%}); "
            f"worst inter-token gap p50/p95 "
            f"{s['itl_max_p50_s']*1e3:.1f}/{s['itl_max_p95_s']*1e3:.1f} ms")
    if s.get("install_write_pulses", 0) or s.get("kv_page_writes", 0):
        line = (
            f"wear: installs {s.get('install_energy_j', 0.0)*1e3:.2f} mJ "
            f"({int(s.get('install_cell_flips', 0))} cell flips, "
            f"{int(s.get('install_write_pulses', 0))} pulses), "
            f"KV {s.get('kv_write_energy_j', 0.0)*1e3:.2f} mJ "
            f"({int(s.get('kv_page_writes', 0))} page writes, "
            f"{int(s.get('kv_page_writes_avoided', 0))} avoided); "
            f"gini weight {s.get('wear_gini_weight', 0.0):.3f}")
        if "wear_gini_kv" in s:
            line += f", kv {s['wear_gini_kv']:.3f}"
        lines.append(line)
    if s.get("faults_survived", 0):
        lines.append(
            f"faults: {int(s['faults_survived'])} survived "
            f"({int(s.get('slots_retired', 0))} slots, "
            f"{int(s.get('pages_retired', 0))} pages retired)")
    tenants = sorted({k.split(".", 2)[1] for k in s
                      if k.startswith("tenant.")})
    for name in tenants:
        pre = f"tenant.{name}."
        lines.append(
            f"tenant {name}: {int(s[pre + 'requests'])} requests, "
            f"ttft p95 {s[pre + 'ttft_p95_s']*1e3:.1f} ms, "
            f"itl p95 {s[pre + 'itl_max_p95_s']*1e3:.1f} ms, "
            f"{s[pre + 'tokens_per_s']:.1f} tok/s")
    return "\n".join(lines)
