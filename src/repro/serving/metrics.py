"""Engine metrics surface: latency percentiles, throughput, queue depth,
and the weight-arena install accounting merged in by the engine."""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.serving.request import Request


def _pct(xs: List[float], p: float) -> float:
    if not xs:
        return float("nan")
    return float(np.percentile(np.asarray(xs, np.float64), p))


class VirtualClock:
    """Injectable simulated time for the engine's `clock` hook: the test
    harness and benchmarks advance it explicitly per step, making every
    latency/stall metric deterministic — no device, no wall clock."""

    def __init__(self, t: float = 0.0):
        self.t = float(t)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError("virtual time cannot run backwards")
        self.t += dt
        return self.t


@dataclasses.dataclass
class StepRecord:
    t: float
    n_active: int
    queue_depth: int
    n_prefills: int
    n_decoded: int
    install_wire_bytes: int
    # paged-KV occupancy snapshot (0/0 when every tenant is slot-managed)
    kv_used_pages: int = 0
    kv_total_pages: int = 0
    # install-pipeline accounting (all zero on the unbudgeted ensure() path):
    # wire bytes of install stream pumped this step (partial installs
    # included), how much of it was hidden under decode/prefill compute, and
    # whether a scheduled tenant sat blocked on installs with no tokens out.
    install_work_bytes: int = 0
    overlap_hidden_bytes: int = 0
    install_stall: bool = False
    # chunked prefill: prompt tokens of chunk work this step (monolithic
    # prefills count their whole prompt here) and chunks launched — the
    # virtual-clock cost models charge step time against prefill_tokens
    prefill_tokens: int = 0
    n_prefill_chunks: int = 0
    # radix-tree prefix cache: prompt tokens served from cached pages this
    # step (skipped chunks — they cost no compute and no prefill budget)
    # and the retained-page gauge across all paged tenants
    prefix_hit_tokens: int = 0
    prefix_cached_pages: int = 0


class EngineMetrics:
    def __init__(self):
        self.finished: List[Request] = []
        self.steps: List[StepRecord] = []
        self.tokens_generated = 0
        self.max_concurrent = 0
        self.preemptions = 0
        self.prefill_tokens = 0
        self.prefill_chunks = 0
        self.prefix_hit_tokens = 0

    def record_step(self, rec: StepRecord) -> None:
        self.steps.append(rec)
        self.max_concurrent = max(self.max_concurrent, rec.n_active)
        self.tokens_generated += rec.n_decoded + rec.n_prefills
        self.prefill_tokens += rec.prefill_tokens
        self.prefill_chunks += rec.n_prefill_chunks
        self.prefix_hit_tokens += rec.prefix_hit_tokens

    def record_finish(self, req: Request) -> None:
        self.finished.append(req)

    def record_preemption(self) -> None:
        self.preemptions += 1

    def summary(self, wall_s: float,
                residency: Optional[Dict[str, float]] = None,
                rejected: int = 0,
                paging: Optional[Dict[str, float]] = None,
                prefill_cache: Optional[Dict[str, int]] = None
                ) -> Dict[str, float]:
        lat = [r.latency for r in self.finished if r.latency is not None]
        ttft = [r.ttft for r in self.finished if r.ttft is not None]
        ttft_q = [r.ttft_queue for r in self.finished
                  if r.ttft_queue is not None]
        ttft_p = [r.ttft_prefill for r in self.finished
                  if r.ttft_prefill is not None]
        itl = [r.max_itl for r in self.finished if r.max_itl is not None]
        depths = [s.queue_depth for s in self.steps]
        out = {
            "requests_finished": float(len(self.finished)),
            "requests_rejected": float(rejected),
            "tokens_generated": float(self.tokens_generated),
            "tokens_per_s": self.tokens_generated / max(wall_s, 1e-9),
            "latency_p50_s": _pct(lat, 50),
            "latency_p95_s": _pct(lat, 95),
            "ttft_p50_s": _pct(ttft, 50),
            "ttft_p95_s": _pct(ttft, 95),
            # TTFT split: queued-for-admission vs chunk-prefilling time (a
            # prefill-token budget trades the latter against decode ITL)
            "ttft_queue_p50_s": _pct(ttft_q, 50),
            "ttft_queue_p95_s": _pct(ttft_q, 95),
            "ttft_prefill_p50_s": _pct(ttft_p, 50),
            "ttft_prefill_p95_s": _pct(ttft_p, 95),
            "prefill_tokens": float(self.prefill_tokens),
            "prefill_chunks": float(self.prefill_chunks),
            # prefix cache: prompt tokens served from retained pages
            # instead of chunk compute; hit rate over all prompt tokens
            # the engine covered (computed + skipped)
            "prefix_hit_tokens": float(self.prefix_hit_tokens),
            "prefix_hit_rate": (
                self.prefix_hit_tokens
                / max(self.prefix_hit_tokens + self.prefill_tokens, 1)),
            # worst inter-token gap per request: the tenant-boundary stall a
            # mean latency hides (install stalls land exactly here)
            "itl_max_p50_s": _pct(itl, 50),
            "itl_max_p95_s": _pct(itl, 95),
            "queue_depth_mean": float(np.mean(depths)) if depths else 0.0,
            "queue_depth_max": float(max(depths)) if depths else 0.0,
            "max_concurrent": float(self.max_concurrent),
            "preemptions": float(self.preemptions),
            "steps": float(len(self.steps)),
            "install_stall_steps": float(
                sum(1 for s in self.steps if s.install_stall)),
            "install_work_bytes": float(
                sum(s.install_work_bytes for s in self.steps)),
            "overlap_hidden_bytes": float(
                sum(s.overlap_hidden_bytes for s in self.steps)),
            "wall_s": wall_s,
        }
        if prefill_cache:
            # jit-trace accounting from launch.steps.prefill_cache_info —
            # process-wide (step caches are shared across engine instances
            # of one config), so read deltas when comparing arms
            out.update({f"prefill_cache_{k}": float(v)
                        for k, v in prefill_cache.items()})
        if residency:
            out.update(residency)
        if paging:
            occ = [s.kv_used_pages / s.kv_total_pages
                   for s in self.steps if s.kv_total_pages]
            out.update(paging)
            out["kv_page_occupancy_mean"] = (
                float(np.mean(occ)) if occ else 0.0)
            out["kv_page_occupancy_max"] = float(max(occ)) if occ else 0.0
            cached = [s.prefix_cached_pages for s in self.steps]
            out["prefix_cached_pages_mean"] = (
                float(np.mean(cached)) if cached else 0.0)
            out["prefix_cached_pages_max"] = (
                float(max(cached)) if cached else 0.0)
        return out


def format_summary(s: Dict[str, float]) -> str:
    lines = [
        f"finished {int(s['requests_finished'])} requests "
        f"({int(s['requests_rejected'])} rejected, "
        f"{int(s['preemptions'])} preemptions) in {s['wall_s']*1e3:.0f} ms "
        f"over {int(s['steps'])} steps",
        f"throughput {s['tokens_per_s']:.1f} tok/s, "
        f"max concurrent {int(s['max_concurrent'])}",
        f"latency p50/p95 {s['latency_p50_s']*1e3:.1f}/"
        f"{s['latency_p95_s']*1e3:.1f} ms, "
        f"ttft p50/p95 {s['ttft_p50_s']*1e3:.1f}/"
        f"{s['ttft_p95_s']*1e3:.1f} ms",
        f"queue depth mean/max {s['queue_depth_mean']:.1f}/"
        f"{int(s['queue_depth_max'])}",
    ]
    if "kv_pages_total" in s:
        lines.append(
            f"paged KV: occupancy mean/max "
            f"{s['kv_page_occupancy_mean']:.1%}/"
            f"{s['kv_page_occupancy_max']:.1%} of "
            f"{int(s['kv_pages_total'])} pages, "
            f"{int(s['kv_shared_page_hits'])} shared-page hits "
            f"({int(s['kv_pages_saved'])} pages saved), "
            f"{int(s['kv_cow_copies'])} COW copies")
    if "install_wire_bytes" in s:
        lines.append(
            f"weight installs: {int(s['installs'])} "
            f"({int(s['cross_tenant_installs'])} cross-tenant), "
            f"{s['install_wire_bytes']/1e6:.2f} MB wire vs "
            f"{s['install_raw_bytes']/1e6:.2f} MB raw "
            f"(saved {s['install_savings']:.1%}, "
            f"skip {s['install_mean_skip']:.1%})")
    if s.get("prefix_hit_tokens", 0) or s.get("kv_prefix_cached_pages", 0):
        lines.append(
            f"prefix cache: {int(s['prefix_hit_tokens'])} prompt tokens "
            f"served from cache ({s['prefix_hit_rate']:.1%} hit rate), "
            f"{int(s.get('kv_prefix_cached_pages', 0))} pages resident "
            f"(max {int(s.get('prefix_cached_pages_max', 0))}), "
            f"{int(s.get('kv_prefix_evictions', 0))} LRU evictions")
    if s.get("prefill_chunks", 0):
        lines.append(
            f"chunked prefill: {int(s['prefill_tokens'])} prompt tokens in "
            f"{int(s['prefill_chunks'])} chunks; ttft queue/prefill p95 "
            f"{s['ttft_queue_p95_s']*1e3:.1f}/{s['ttft_prefill_p95_s']*1e3:.1f}"
            f" ms; {int(s.get('prefill_cache_traces', 0))} prefill traces "
            f"process-wide")
    if s.get("install_work_bytes", 0) or s.get("install_stall_steps", 0):
        hidden = s["overlap_hidden_bytes"]
        work = max(s["install_work_bytes"], 1.0)
        lines.append(
            f"install pipeline: {int(s['install_stall_steps'])} stall steps, "
            f"{hidden/1e6:.2f} MB of {s['install_work_bytes']/1e6:.2f} MB "
            f"hidden under decode ({hidden/work:.0%}); "
            f"worst inter-token gap p50/p95 "
            f"{s['itl_max_p50_s']*1e3:.1f}/{s['itl_max_p95_s']*1e3:.1f} ms")
    return "\n".join(lines)
