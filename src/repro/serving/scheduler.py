"""Admission control and step scheduling for the serving engine.

Slot-based continuous batching: between decode steps the scheduler admits
waiting requests into free KV slots (each admission runs that request's
prefill — the step mixes prefill and decode work), and picks which tenants
decode this step.  When every active tenant's weights fit the device weight
arena simultaneously, all of them decode every step; otherwise the
scheduler time-slices tenants in turns of `model_turn_steps` so the weight
arena is rewritten once per turn instead of once per step — the ARAS
install-amortization discipline applied across models.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence

from repro.serving.request import Request, RequestStatus
from repro.serving.tracing import NULL_TRACER


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    max_queue: int = 256          # admission control: reject beyond this
    max_active: Optional[int] = None  # global concurrent-slot budget
    policy: str = "fcfs"          # fcfs | sjf (shortest prompt first)
    max_prefill_per_step: int = 2  # prefill/decode mixing ratio cap
    model_turn_steps: int = 8     # tenant time-slice when weights don't fit
    # Chunked prefill: cap on prompt tokens a single step may spend on
    # chunk work (None = drain every pending chunk immediately).  With a
    # budget, a long prompt's prefill is spread over several steps and the
    # concurrent decode batch keeps emitting a token every step — the ARAS
    # §V discipline of slicing oversized work into scheduler-sized pieces.
    # A step always advances at least one chunk, so a budget smaller than
    # the chunk size degrades to one-chunk-per-step rather than stalling.
    # The budget is cache-aware: prompt tokens served from the radix-tree
    # prefix cache (skipped chunks) cost no compute and are not charged —
    # only chunks the model actually runs count against it.
    prefill_token_budget: Optional[int] = None

    def __post_init__(self):
        if self.policy not in ("fcfs", "sjf"):
            raise ValueError(f"unknown queue policy {self.policy!r} "
                             "(expected 'fcfs' or 'sjf')")
        if (self.prefill_token_budget is not None
                and self.prefill_token_budget < 1):
            raise ValueError("prefill_token_budget must be >= 1 (or None "
                             "for unbudgeted prefill)")


class StepScheduler:
    # structured-event sink for admission/requeue/turn decisions; the
    # engine swaps in its shared Tracer, standalone use keeps the no-op
    tracer = NULL_TRACER

    def __init__(self, cfg: SchedulerConfig = SchedulerConfig()):
        self.cfg = cfg
        self.queue: List[Request] = []
        self.rejected = 0
        self._turn_model: Optional[str] = None
        self._turn_left = 0

    @property
    def queue_depth(self) -> int:
        return len(self.queue)

    def prefill_token_budget(self) -> float:
        """Prompt tokens this step may spend on chunked-prefill work."""
        b = self.cfg.prefill_token_budget
        return float("inf") if b is None else float(b)

    def queue_wait(self, now: float) -> float:
        """Longest wait among currently-queued requests (0 when empty):
        the head-of-line age `ServingEngine.health()` publishes for the
        router tier — a cheap single pass, no history walk."""
        if not self.queue:
            return 0.0
        return max(now - r.arrival_t for r in self.queue)

    # --------------------------------------------------------- admission
    def submit(self, req: Request) -> bool:
        if len(self.queue) >= self.cfg.max_queue:
            req.status = RequestStatus.REJECTED
            self.rejected += 1
            self.tracer.instant("sched_reject", rid=req.rid,
                                queue_depth=len(self.queue))
            return False
        self.queue.append(req)
        return True

    def requeue(self, req: Request) -> None:
        """Preempted requests go to the head: they already hold progress."""
        req.status = RequestStatus.PREEMPTED
        self.queue.insert(0, req)

    def next_admits(self, free_slots: Dict[str, int], n_active: int,
                    can_admit: Optional[Callable[[Request], bool]] = None
                    ) -> List[Request]:
        """Pop up to `max_prefill_per_step` requests that have a free KV
        slot (slot arenas) or decode row (paged arenas) in their tenant's
        arena and fit the global active budget.  `can_admit` adds the
        paged-layout page check — "enough free pages for this request's
        non-shared blocks?" — on top of the per-tenant row count."""
        budget = (float("inf") if self.cfg.max_active is None
                  else self.cfg.max_active)
        order = list(self.queue)
        if self.cfg.policy == "sjf":
            # preempted requests keep their head-of-queue priority (they
            # hold generated progress); only fresh arrivals sort by length
            order.sort(key=lambda r: (
                r.status is not RequestStatus.PREEMPTED,
                len(r.serving_prompt())))
        free = dict(free_slots)
        admits: List[Request] = []
        for req in order:
            if len(admits) >= self.cfg.max_prefill_per_step:
                break
            if n_active + len(admits) >= budget:
                break
            if free.get(req.model, 0) <= 0:
                continue
            if can_admit is not None and not can_admit(req):
                continue
            free[req.model] -= 1
            admits.append(req)
        for req in admits:
            self.queue.remove(req)
        return admits

    # ------------------------------------------------------ decode picks
    @property
    def current_turn_model(self) -> Optional[str]:
        return self._turn_model

    @property
    def turn_steps_left(self) -> int:
        return self._turn_left

    @property
    def turn_ending(self) -> bool:
        """True right after a `pick_models` that handed the turn holder its
        final time-slice step — the install pipeline's cue that the holder's
        slots can be overwritten behind this step's execution front."""
        return self._turn_model is not None and self._turn_left <= 0

    def refund_turn_step(self) -> None:
        """Give the turn holder back one slice step.  The engine calls this
        when the holder spent the step stalled on weight installs instead of
        decoding, so install latency never eats the decode slice (which
        could otherwise rotate a never-resident tenant forever)."""
        if self._turn_model is not None:
            self._turn_left += 1

    def peek_next_model(self, demand_models: Sequence[str]) -> Optional[str]:
        """The tenant the rotation will hand the turn to next — what the
        install pipeline should prefetch during the current holder's final
        steps.  None when no turn is active (co-resident tenants switch
        nothing)."""
        demand = sorted(set(demand_models))
        if not demand or self._turn_model is None:
            return None
        after = [m for m in demand if m > self._turn_model]
        return after[0] if after else demand[0]

    def pick_models(self, demand_models: Sequence[str], residency
                    ) -> List[str]:
        """Which tenants run this step (decode AND admissions — prefill only
        happens on a scheduled, weight-resident tenant).  All tenants with
        demand run when co-resident in the weight arena; otherwise the
        scheduler holds one tenant for `model_turn_steps` steps so installs
        amortize.  The turn is stateful — tenants joining or draining
        mid-turn never remap the current pick."""
        demand = sorted(set(demand_models))
        if not demand:
            self._turn_model, self._turn_left = None, 0
            return []
        if residency is None or residency.fits(demand):
            self._turn_model, self._turn_left = None, 0
            return demand
        if self._turn_model not in demand or self._turn_left <= 0:
            # rotate cyclically past the previous turn holder
            after = [m for m in demand if m > (self._turn_model or "")]
            self._turn_model = after[0] if after else demand[0]
            self._turn_left = max(self.cfg.model_turn_steps, 1)
            self.tracer.instant("turn_rotate", model=self._turn_model,
                                steps=self._turn_left)
        self._turn_left -= 1
        return [self._turn_model]
