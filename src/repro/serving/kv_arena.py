"""Slot-managed KV-cache arena for continuous batching.

The arena is one device cache pytree with a leading *slot* axis (the batch
axis of `nn.model.init_cache`), plus host-side occupancy bookkeeping that
mirrors the crossbar-row `_Occupancy` discipline in `sim/aras.py`: a freed
slot keeps its stale contents until the next occupant's prefill overwrites
them — exactly like a released crossbar row holding the previous layer's
codes — and correctness relies on the per-slot position mask, not on
zeroing.  Requests join and leave between decode steps; a slot write only
ever touches its own row, so eviction cannot corrupt an in-flight neighbor.
"""
from __future__ import annotations

import functools
from collections import deque
from typing import Any, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.nn.config import ModelConfig
from repro.nn.model import init_cache
from repro.nn.transformer import stack_plan


@functools.lru_cache(maxsize=None)
def _cached_slot_write(cfg: ModelConfig):
    """Jitted slot-scatter shared across arena instances of the same config
    (same policy as launch.steps.cached_serve_step): scatter a batch-1
    prefill cache into one arena row.  Scanned segments carry the stacked
    layer axis first, so the slot (batch) axis is 1 there, 0 on unrolled
    segments.  The arena is donated: install() immediately rebinds
    self.caches to the output, so the write updates in place instead of
    copying the whole n_slots × max_seq cache pytree per admission."""
    plan = stack_plan(cfg)

    def write(caches, one, slot):
        out = []
        for seg_a, seg_o, (_, _, scanned) in zip(caches, one, plan):
            ax = 1 if scanned else 0
            out.append(jax.tree.map(
                lambda a, o, ax=ax: jax.lax.dynamic_update_slice_in_dim(
                    a, o.astype(a.dtype), slot, axis=ax),
                seg_a, seg_o))
        return out

    return jax.jit(write, donate_argnums=(0,))


class KVArena:
    def __init__(self, cfg: ModelConfig, n_slots: int, max_seq: int):
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.plan = stack_plan(cfg)
        self.caches = init_cache(cfg, n_slots, max_seq)
        self.owner: List[Optional[int]] = [None] * n_slots   # rid or None
        self.pos = np.zeros(n_slots, np.int32)
        self.last_token = np.zeros(n_slots, np.int32)
        self._free: deque = deque(range(n_slots))
        self.evictions = 0
        self._write = _cached_slot_write(cfg)

    # ------------------------------------------------------------- slots
    @property
    def n_free(self) -> int:
        return len(self._free)

    def active_slots(self) -> List[int]:
        return [s for s, o in enumerate(self.owner) if o is not None]

    def owner_of(self, slot: int) -> Optional[int]:
        return self.owner[slot]

    def alloc(self, rid: int) -> Optional[int]:
        if not self._free:
            return None
        slot = self._free.popleft()
        self.owner[slot] = rid
        return slot

    def evict(self, slot: int) -> Optional[int]:
        """Release a slot (finish or preemption).  Contents stay stale on
        device — the occupancy map is the only thing that changes."""
        rid = self.owner[slot]
        if rid is None:
            return None
        self.owner[slot] = None
        self._free.append(slot)
        self.evictions += 1
        return rid

    # ------------------------------------------------------------ caches
    def install(self, slot: int, one_caches: Any, first_token: int,
                prompt_len: int) -> None:
        """Write a freshly prefilled batch-1 cache into `slot` and arm its
        decode state (next write position = prompt_len)."""
        self.caches = self._write(self.caches, one_caches, jnp.int32(slot))
        self.pos[slot] = prompt_len
        self.last_token[slot] = first_token

    def decode_inputs(self):
        """(tokens (S,), pos (S,)) covering every slot; inactive slots carry
        stale values whose decode output is discarded by the engine."""
        return (jnp.asarray(self.last_token), jnp.asarray(self.pos))

    def advance(self, slot: int, token: int) -> None:
        self.pos[slot] += 1
        self.last_token[slot] = token
