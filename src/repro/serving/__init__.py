"""Continuous-batching serving engine (ARAS scheduling machinery applied to
multi-tenant inference): request queue + admission control, slot-managed or
paged KV-cache arenas (block page tables, refcounted prefix sharing, COW),
multi-model weight-arena residency with cross-tenant §V-C delta reuse, and
an engine metrics surface."""
from repro.serving.engine import EngineModel, ServingEngine
from repro.serving.kv_arena import KVArena
from repro.serving.metrics import EngineMetrics, format_summary
from repro.serving.paging import PageAllocator, PagedKVArena
from repro.serving.request import Request, RequestStatus
from repro.serving.residency import WeightResidencyManager
from repro.serving.sampling import request_key, sample_token
from repro.serving.scheduler import SchedulerConfig, StepScheduler

__all__ = [
    "EngineModel", "ServingEngine", "KVArena", "PageAllocator",
    "PagedKVArena", "EngineMetrics", "format_summary", "Request",
    "RequestStatus", "WeightResidencyManager", "SchedulerConfig",
    "StepScheduler", "request_key", "sample_token",
]
