"""Continuous-batching serving engine (ARAS scheduling machinery applied to
multi-tenant inference): request queue + admission control, slot-managed or
paged KV-cache arenas (block page tables, refcounted prefix sharing, COW),
multi-model weight-arena residency with cross-tenant §V-C delta reuse and a
tick-budgeted install pipeline that overlaps tenant switches with decode,
and an engine metrics surface (drivable on a deterministic VirtualClock)."""
from repro.serving.bucketing import PrefillProgress, bucket_for, bucket_ladder
from repro.serving.engine import EngineModel, ServingEngine
from repro.serving.faults import FaultModel
from repro.serving.harness import drive_simulated
from repro.serving.kv_arena import KVArena
from repro.serving.metrics import (Counter, EngineMetrics, Gauge, Histogram,
                                   MetricsRegistry, VirtualClock,
                                   format_summary)
from repro.serving.paging import PageAllocator, PagedKVArena
from repro.serving.prefix_cache import RadixNode, RadixPrefixCache
from repro.serving.request import Request, RequestStatus
from repro.serving.recorder import FlightRecorder
from repro.serving.residency import InstallPipeline, WeightResidencyManager
from repro.serving.sampling import request_key, sample_token, sample_tokens
from repro.serving.scheduler import SchedulerConfig, StepScheduler
from repro.serving.telemetry import (EngineTelemetry, P2Quantile,
                                     PromEndpoint, SLOConfig, SLOTracker,
                                     SlidingWindow, StreamStat,
                                     TelemetryConfig, prometheus_text,
                                     validate_events_jsonl,
                                     validate_prometheus_text)
from repro.serving.tracing import NULL_TRACER, NullTracer, Tracer
from repro.serving.wear import WearMap, WearPlane, gini_coefficient
from repro.streaming.plan import InstallCostModel

__all__ = [
    "EngineModel", "ServingEngine", "KVArena", "PageAllocator",
    "PagedKVArena", "RadixNode", "RadixPrefixCache",
    "EngineMetrics", "VirtualClock", "format_summary",
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "Tracer", "NullTracer", "NULL_TRACER",
    "Request", "RequestStatus", "InstallPipeline", "InstallCostModel",
    "WeightResidencyManager", "SchedulerConfig", "StepScheduler",
    "drive_simulated", "request_key", "sample_token", "sample_tokens",
    "PrefillProgress", "bucket_for", "bucket_ladder",
    "WearMap", "WearPlane", "gini_coefficient", "FaultModel",
    "TelemetryConfig", "EngineTelemetry", "SLOConfig", "SLOTracker",
    "P2Quantile", "SlidingWindow", "StreamStat", "FlightRecorder",
    "PromEndpoint", "prometheus_text",
    "validate_prometheus_text", "validate_events_jsonl",
]
