"""Request lifecycle for the continuous-batching engine.

A request is a (tenant model, prompt, token budget) triple plus the mutable
serving state the engine tracks: which KV slot it occupies, what it has
generated so far, and the timestamps the metrics surface aggregates.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import List, Optional, Tuple


class RequestStatus(enum.Enum):
    QUEUED = "queued"
    PREFILLING = "prefilling"   # chunked prefill in flight, no token yet
    RUNNING = "running"
    PREEMPTED = "preempted"
    FINISHED = "finished"
    REJECTED = "rejected"


@dataclasses.dataclass
class Request:
    rid: int
    model: str
    prompt: Tuple[int, ...]
    max_new_tokens: int
    arrival_t: float
    # sampling: temperature <= 0 is greedy argmax (the default); otherwise
    # temperature/top-k sampling from fold_in(PRNGKey(seed or rid), n) for
    # the n-th generated token (deterministic across schedules).
    temperature: float = 0.0
    top_k: int = 0
    seed: Optional[int] = None
    status: RequestStatus = RequestStatus.QUEUED
    slot: Optional[int] = None
    generated: List[int] = dataclasses.field(default_factory=list)
    first_token_t: Optional[float] = None
    finish_t: Optional[float] = None
    preemptions: int = 0
    last_token_t: Optional[float] = None
    max_itl: Optional[float] = None   # worst inter-token gap seen
    # when the first prefill chunk (or the monolithic prefill) ran — splits
    # TTFT into time spent queued vs time spent chunk-prefilling
    prefill_start_t: Optional[float] = None

    @property
    def prompt_len(self) -> int:
        return len(self.prompt)

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new_tokens

    def serving_prompt(self) -> Tuple[int, ...]:
        """The token prefix a (re-)prefill must run over.  After a
        preemption this includes everything generated so far, so the next
        prefill's last-position logits produce exactly the token the evicted
        decode would have produced."""
        return self.prompt + tuple(self.generated)

    def note_token(self, t: float) -> None:
        """Record a token emission time; tracks the worst inter-token gap,
        which is where install stalls at tenant-turn boundaries surface."""
        if self.last_token_t is not None:
            gap = t - self.last_token_t
            self.max_itl = gap if self.max_itl is None else max(self.max_itl,
                                                                gap)
        self.last_token_t = t

    @property
    def latency(self) -> Optional[float]:
        if self.finish_t is None:
            return None
        return self.finish_t - self.arrival_t

    @property
    def ttft(self) -> Optional[float]:
        if self.first_token_t is None:
            return None
        return self.first_token_t - self.arrival_t

    @property
    def ttft_queue(self) -> Optional[float]:
        """TTFT share spent waiting for admission (arrival → first chunk)."""
        if self.prefill_start_t is None:
            return None
        return self.prefill_start_t - self.arrival_t

    @property
    def ttft_prefill(self) -> Optional[float]:
        """TTFT share spent prefilling (first chunk → first token) — the
        part a prefill-token budget trades against decode interference."""
        if self.first_token_t is None or self.prefill_start_t is None:
            return None
        return self.first_token_t - self.prefill_start_t
