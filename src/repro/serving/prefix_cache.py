"""Radix-tree prefix cache over KV pages: retained blocks + LRU eviction.

ARAS §V-C avoids expensive writes by exploiting similarity between what is
already resident and what is about to be written.  The paging layer applies
that to *live* KV (refcounted prefix sharing between concurrent requests),
but PR 2's exact-tuple index dies with the last live reference: once the
original holder exits, an identical system prompt re-prefills from scratch.
This module is the retention layer — a radix tree over token-*block* edges
whose nodes pin physical pages in the `PageAllocator`:

  * each node covers one `page_size`-token block and names the physical
    page holding its K/V; a node's path from the root spells the full
    token prefix the page is valid for (hash-chained per-block keys: one
    lookup step hashes one block tuple, so a whole-prompt match costs
    O(blocks) dict probes instead of the old O(blocks·len) full-prefix
    tuples — quadratic in prompt length);
  * a *retained* node owns one allocator refcount on its page, so the page
    survives its last live holder (finished requests donate their
    prompt+generated pages into the tree instead of freeing them);
  * non-retained nodes index pages of live requests only (the PR 2
    publish-on-install behavior) and vanish when the page's refcount hits
    zero — including cascade removal of any subtree hanging below them,
    which releases retained descendants' refcounts so no page leaks
    unreachable;
  * eviction is LRU over *evictable leaves*: retained nodes whose page is
    referenced by nobody but the tree and that have no surviving children
    (an inner node can only go after its subtree — removing it first would
    orphan reachable pages).  The allocator evicts on demand whenever an
    admission, a mid-prefill reservation, a decode append, or a COW would
    otherwise fail, and on the retained-page budget (`max_cached`).

Both eviction-side queries are incremental rather than O(tree) walks:

  * `evict_lru` pops candidates off a lazily-invalidated min-heap keyed on
    LRU stamp.  Stale entries (node gone, grew children, or re-stamped)
    are discarded on pop — every state transition into candidacy pushes a
    fresh entry, so a retained leaf always has an entry carrying its
    current stamp.  Entries that fail only the *caller's* predicate
    (`sole`/`exclude`) are set aside and re-pushed, since they stay
    candidates for later calls.
  * `evictable_count` maintains the exact size of the maximal evictable
    set (a node is in it iff its whole subtree is retained, solely
    tree-held, and not excluded) via per-node `n_bad_kids` bookkeeping:
    a node is *good* iff it is retained, externally unreferenced, and has
    no bad child; badness propagates upward on every transition, so the
    count is O(1) and per-call `exclude` handling is O(excluded chain).
    The allocator reports refcount crossings of the ==1 boundary through
    `note_refcount`, and `evictable_walk` keeps the O(tree) reference
    implementation for invariant tests to compare against.

Only the final block of a donated sequence may be partial; partial edges
are always leaves (nothing descends past a partial block) and match only
an exact-tuple lookup, like the index they replace.  Everything here is
host-side bookkeeping — pages keep their device contents; validity comes
from position masks, exactly like a released crossbar row.
"""
from __future__ import annotations

import heapq
from typing import Callable, Dict, FrozenSet, Iterable, List, Optional, Tuple

Tokens = Tuple[int, ...]


class RadixNode:
    """One block edge: `edge` (≤ page_size tokens) extends the parent's
    prefix, `page` holds its K/V.  `retained` means the tree owns one
    allocator refcount on the page; `stamp` is the LRU clock.  `ok` and
    `n_bad_kids` are the incremental evictable-count bookkeeping: `ok`
    means the whole subtree rooted here is evictable (retained, solely
    tree-held, no bad child anywhere); `n_bad_kids` counts children whose
    `ok` is False."""

    __slots__ = ("edge", "page", "parent", "children", "retained", "stamp",
                 "ok", "n_bad_kids")

    def __init__(self, edge: Tokens, page: int, parent: "RadixNode",
                 stamp: int):
        self.edge = edge
        self.page = page
        self.parent = parent
        self.children: Dict[Tokens, "RadixNode"] = {}
        self.retained = False
        self.stamp = stamp
        self.ok = False
        self.n_bad_kids = 0


class RadixPrefixCache:
    """The tree plus its page index.  Refcounts live in the PageAllocator;
    the tree reports which refs it owns (retained nodes) and takes a
    `free_ref` callback wherever it gives one back.  `refcount_of` (the
    allocator's live refcount lookup) feeds the incremental evictable
    count; standalone trees default to "always solely held"."""

    def __init__(self, page_size: int, max_cached: Optional[int] = None,
                 refcount_of: Optional[Callable[[int], int]] = None):
        if page_size < 1:
            raise ValueError("page_size must be >= 1")
        if max_cached is not None and max_cached < 0:
            raise ValueError("max_cached must be >= 0 (None = unbounded)")
        self.page_size = page_size
        self.max_cached = max_cached
        self._refcount_of = refcount_of or (lambda page: 1)
        self._root = RadixNode((), -1, None, 0)   # sentinel, never matched
        self._root.parent = None
        self._by_page: Dict[int, RadixNode] = {}
        self._tick = 0
        # LRU candidate heap: (stamp, seq, page).  Lazily invalidated —
        # entries go stale when the node is removed, grows children, or is
        # re-stamped; evict_lru discards those on pop.
        self._heap: List[Tuple[int, int, int]] = []
        self._heap_seq = 0
        # incremental evictable count: number of `ok` nodes
        self._n_good = 0
        # stats (surfaced through PagedKVArena.stats)
        self.n_cached = 0          # retained nodes currently resident
        self.evictions = 0         # LRU evictions (pages returned to pool)

    # ------------------------------------------------------------ helpers
    def _edges(self, tokens: Tokens) -> Iterable[Tokens]:
        ps = self.page_size
        n = len(tokens)
        for i in range(0, max(n, 1), ps):
            yield tuple(tokens[i:min(i + ps, n)])

    def _bump(self) -> int:
        self._tick += 1
        return self._tick

    def __len__(self) -> int:
        return len(self._by_page)

    # -------------------------------------------- incremental bookkeeping
    def _recompute_up(self, node: Optional[RadixNode]) -> None:
        """Recompute `ok` from this node upward until nothing flips.  Each
        flip adjusts the parent's `n_bad_kids`, which may flip the parent
        in turn — badness (and goodness) propagate along root paths only,
        so the walk is bounded by the node's depth and amortizes O(1)
        across an operation's contiguous chain of updates."""
        while node is not None and node is not self._root:
            new_ok = (node.retained and node.n_bad_kids == 0
                      and self._refcount_of(node.page) == 1)
            if new_ok == node.ok:
                break
            node.ok = new_ok
            self._n_good += 1 if new_ok else -1
            parent = node.parent
            parent.n_bad_kids += -1 if new_ok else 1
            node = parent

    def _attach(self, parent: RadixNode, child: RadixNode) -> None:
        """Insert `child` (fresh, ok=False) under `parent`, keeping the
        n_bad_kids invariant and the good-count consistent."""
        parent.children[child.edge] = child
        self._by_page[child.page] = child
        parent.n_bad_kids += 1           # fresh child starts not-ok
        self._recompute_up(child)        # may turn ok (retained donations)
        self._recompute_up(parent)       # parent may have just gone bad

    def _detach_count(self, node: RadixNode) -> None:
        """Account a node leaving the tree: drop its good-count share or
        its parent's bad-kid share (exactly one applies)."""
        if node.ok:
            self._n_good -= 1
        else:
            node.parent.n_bad_kids -= 1

    def note_refcount(self, page: int) -> None:
        """The allocator's refcount for `page` crossed the ==1 boundary
        (a sharer pinned a retained page, or the last external holder
        left).  Re-evaluates the holding node's evictability."""
        node = self._by_page.get(page)
        if node is not None:
            self._recompute_up(node)

    def _heap_push(self, node: RadixNode) -> None:
        """Push a candidate entry if `node` is currently a retained leaf.
        Called on every transition *into* candidacy (became retained,
        became a leaf) and on stamp bumps of existing candidates, so a
        retained leaf always owns an entry with its current stamp."""
        if node.retained and not node.children:
            self._heap_seq += 1
            heapq.heappush(self._heap,
                           (node.stamp, self._heap_seq, node.page))

    # ------------------------------------------------------------- lookup
    def match(self, tokens: Tokens, touch: bool = True) -> List[int]:
        """Pages covering the longest resident block-aligned prefix of
        `tokens` (the final partial block matches only an exact edge, and
        partial edges are leaves).  One dict probe per block — the
        hash-chained incremental match.  `touch=False` for pure capacity
        checks, so scheduler probing does not pollute the LRU order."""
        if not tokens:
            return []
        node, pages = self._root, []
        stamp = self._bump() if touch else None
        for edge in self._edges(tokens):
            child = node.children.get(edge)
            if child is None:
                break
            pages.append(child.page)
            if stamp is not None:
                child.stamp = stamp
                self._heap_push(child)   # re-stamped candidates re-enter
            node = child
            if len(edge) < self.page_size:
                break              # partial edges never have children
        return pages

    # ------------------------------------------------------------ publish
    def register(self, tokens: Tokens, pages: List[int]) -> None:
        """Index a live request's freshly installed pages (non-retained:
        the tree owns no refcount; the nodes die with the pages).  First
        writer wins per block; on a collision the existing node stays and
        insertion descends through it — the token path, not the physical
        page, determines content, so deeper blocks still attach soundly."""
        node = self._root
        stamp = self._bump()
        for i, edge in enumerate(self._edges(tokens)):
            if i >= len(pages) or not edge:
                break
            page = pages[i]
            child = node.children.get(edge)
            if child is None:
                if page in self._by_page:
                    break          # one page, one key — like the old index
                child = RadixNode(edge, page, node, stamp)
                self._attach(node, child)
            child.stamp = stamp
            self._heap_push(child)
            node = child
            if len(edge) < self.page_size:
                break

    def donate(self, tokens: Tokens, pages: List[int],
               free_ref: Callable[[int], None]) -> int:
        """A finished request's pages enter the tree *retained* instead of
        being freed: for each block, either the caller's refcount transfers
        to the tree (fresh node, or marking a live node retained) or it is
        released through `free_ref` (node already retained, or a collision
        with a different physical page).  Returns blocks newly retained.
        Enforces `max_cached` by LRU-evicting the overflow."""
        node = self._root
        stamp = self._bump()
        gained = 0
        blocked = False
        for i, edge in enumerate(self._edges(tokens)):
            if i >= len(pages) or not edge:
                break
            page = pages[i]
            if blocked:
                free_ref(page)
                continue
            child = node.children.get(edge)
            if child is None:
                if page in self._by_page:
                    # page already indexed under another key: cannot insert,
                    # and with no node here deeper blocks have no parent
                    free_ref(page)
                    blocked = True
                    continue
                child = RadixNode(edge, page, node, stamp)
                child.retained = True
                self._attach(node, child)
                self.n_cached += 1
                gained += 1
            elif child.page == page:
                if child.retained:
                    free_ref(page)          # tree already owns a ref
                else:
                    child.retained = True   # absorb the caller's ref
                    self.n_cached += 1
                    gained += 1
                    self._recompute_up(child)
            else:
                # collision: identical token block on a different physical
                # page — keep the resident one, release ours, but keep
                # descending (content is a function of the token path)
                free_ref(page)
            child.stamp = stamp
            self._heap_push(child)
            node = child
            if len(edge) < self.page_size:
                break
        if self.max_cached is not None:
            while self.n_cached > self.max_cached:
                if not self.evict_lru(lambda p: True, free_ref):
                    break
        return gained

    # ----------------------------------------------------------- removal
    def drop_page(self, page: int, free_ref: Callable[[int], None]) -> None:
        """The page's last external refcount just dropped: unindex its node
        and cascade through the subtree below it (now unreachable), giving
        retained descendants' refcounts back through `free_ref`."""
        node = self._by_page.get(page)
        if node is None:
            return
        assert not node.retained, (
            f"page {page} hit refcount 0 while the tree still held a ref")
        parent = node.parent
        parent.children.pop(node.edge, None)
        self._detach_count(node)
        subtree = [node]
        i = 0
        while i < len(subtree):
            subtree.extend(subtree[i].children.values())
            i += 1
        for sub in subtree:        # unindex first: free_ref may re-enter
            self._by_page.pop(sub.page, None)
        for sub in subtree[1:]:
            if sub.ok:
                self._n_good -= 1
            if sub.retained:
                sub.retained = False
                self.n_cached -= 1
                free_ref(sub.page)
        self._recompute_up(parent)
        self._heap_push(parent)    # parent may have just become a leaf

    # ---------------------------------------------------------- eviction
    def _evictable_leaf(self, sole: Callable[[int], bool],
                        exclude: FrozenSet[int]) -> Optional[RadixNode]:
        """O(tree) reference scan for the LRU evictable leaf — kept for
        invariant tests; production eviction uses the candidate heap."""
        best: Optional[RadixNode] = None
        stack = list(self._root.children.values())
        while stack:
            node = stack.pop()
            if node.children:
                stack.extend(node.children.values())
                continue
            if (node.retained and node.page not in exclude
                    and sole(node.page)
                    and (best is None or node.stamp < best.stamp)):
                best = node
        return best

    def evict_lru(self, sole: Callable[[int], bool],
                  free_ref: Callable[[int], None],
                  exclude: FrozenSet[int] = frozenset()) -> bool:
        """Evict the least-recently-used evictable leaf: retained, no
        children, and `sole(page)` (nobody but the tree holds it).  Gives
        the tree's refcount back through `free_ref` — which returns the
        page to the allocator's free list.  False when nothing is
        evictable.

        The victim comes off the stamp-ordered candidate heap: stale
        entries (node gone, grew children, or re-stamped) are discarded —
        a fresh entry was pushed at each of those transitions — while
        structurally valid candidates failing only this call's
        `sole`/`exclude` predicate are set aside and re-pushed, since
        they remain candidates for later calls."""
        victim: Optional[RadixNode] = None
        aside: List[Tuple[int, int, int]] = []
        while self._heap:
            entry = heapq.heappop(self._heap)
            stamp, _, page = entry
            node = self._by_page.get(page)
            if (node is None or not node.retained or node.children
                    or node.stamp != stamp):
                continue               # stale: candidacy re-pushed elsewhere
            if page in exclude or not sole(page):
                aside.append(entry)    # still a candidate for later calls
                continue
            victim = node
            break
        for entry in aside:
            heapq.heappush(self._heap, entry)
        if victim is None:
            return False
        parent = victim.parent
        parent.children.pop(victim.edge, None)
        self._by_page.pop(victim.page, None)
        self._detach_count(victim)
        victim.retained = False
        self.n_cached -= 1
        self.evictions += 1
        self._recompute_up(parent)
        self._heap_push(parent)        # parent may have just become a leaf
        free_ref(victim.page)
        return True

    def evictable_count(self, exclude: FrozenSet[int] = frozenset()) -> int:
        """How many pages on-demand eviction could actually free right now
        — the incremental good-node count, adjusted for this call's
        `exclude` set.  Exact: the admission path uses this, and an
        optimistic count would let `can_admit` promise pages `evict_lru`
        cannot deliver, livelocking the engine's requeue loop.

        Goodness is downward-closed (a good node's subtree is all good)
        and badness upward-closed, so excluding a page can only strike its
        node and that node's currently-good ancestors — O(chain depth)
        with a visited set, and `exclude` sets are match-prefix root
        chains on the hot path."""
        if not exclude:
            return self._n_good
        n = self._n_good
        seen = set()
        for page in exclude:
            node = self._by_page.get(page)
            while (node is not None and node is not self._root
                   and node.ok and id(node) not in seen):
                seen.add(id(node))
                n -= 1
                node = node.parent
        return n

    def evictable_walk(self, sole: Callable[[int], bool],
                       exclude: FrozenSet[int] = frozenset()) -> int:
        """O(tree) reference implementation of `evictable_count`: the
        maximal set S where a node is in S iff it is retained, solely
        tree-held, not excluded, and its whole subtree is in S (children
        must go before parents).  Kept for the invariant tests to assert
        the incremental bookkeeping never drifts.  Iterative (pre-order
        collect, reverse for children-before-parents) — a long retained
        conversation is one linear chain deep enough to blow the
        recursion limit."""
        order: List[RadixNode] = []
        stack = list(self._root.children.values())
        while stack:
            node = stack.pop()
            order.append(node)
            stack.extend(node.children.values())
        ok: Dict[int, bool] = {}
        total = 0
        for node in reversed(order):
            self_ok = (node.retained and node.page not in exclude
                       and sole(node.page)
                       and all(ok[id(c)] for c in node.children.values()))
            ok[id(node)] = self_ok
            if self_ok:
                total += 1
        return total

    # Back-compat alias: the O(tree) walk under its original name.
    evictable = evictable_walk
