"""Deterministic stuck-at fault injection for ReRAM-backed arenas.

ReRAM cells wear out and fail under repeated SET/RESET pulses; Hamun
(PAPERS.md) prolongs accelerator lifespan by steering writes away from
worn cells *and* by surviving the cells that fail anyway.  This module
provides the failure half: a seeded, deterministic stuck-at fault model
in the spirit of the yzlite ReRAM wrapper (SNIPPETS.md), sampled at the
write sites the engine already owns — weight-slot installs and KV page
allocations.

Design constraints, in order:

* **Deterministic.**  Whether write #k to unit u of plane p faults is a
  pure function of ``(seed, plane, unit, k)`` — no global RNG state, no
  dependence on wall clock or iteration order.  Two runs with the same
  seed and the same schedule fault the same units at the same writes,
  which is what makes the token-equivalence sweep in
  ``tests/test_faults.py`` a real property test.
* **Zero cost when off.**  The engine only constructs a ``FaultModel``
  when ``fault_rate > 0``; every check site is guarded on the model
  being present, so ``fault_rate=0`` is bit-for-bit today's behavior.
* **Stuck-at semantics.**  A fault is detected *at write time* (program
  -and-verify, as real ReRAM controllers do) and the unit is then
  retired permanently — the caller remaps to a healthy unit and never
  re-issues the bad one.
"""
from __future__ import annotations

import hashlib
from typing import Dict, Tuple

__all__ = ["FaultModel"]

# hash-derived uniforms: take 8 bytes of blake2b -> [0, 1)
_DENOM = float(1 << 64)


class FaultModel:
    """Seeded stuck-at faults at a configurable per-write rate.

    ``check(plane, unit)`` is called once per physical write (weight
    install into an arena slot, KV page program) and returns ``True``
    when that write hits a failing cell.  Each call advances a
    per-``(plane, unit)`` write ordinal, so the decision sequence for a
    unit is a fixed pseudorandom stream keyed by the seed — replaying
    the same schedule replays the same faults.
    """

    def __init__(self, rate: float, seed: int = 0) -> None:
        if not 0.0 <= rate <= 1.0:
            raise ValueError(f"fault rate must be in [0, 1], got {rate}")
        self.rate = float(rate)
        self.seed = int(seed)
        # write ordinal per (plane, unit): the k in "k-th write to u"
        self._ordinal: Dict[Tuple[str, int], int] = {}
        self.checks = 0
        self.faults = 0

    def _uniform(self, plane: str, unit: int, ordinal: int) -> float:
        payload = f"{self.seed}:{plane}:{unit}:{ordinal}".encode()
        digest = hashlib.blake2b(payload, digest_size=8).digest()
        return int.from_bytes(digest, "big") / _DENOM

    def check(self, plane: str, unit: int) -> bool:
        """Does this write to ``unit`` of ``plane`` hit a bad cell?"""
        key = (plane, int(unit))
        ordinal = self._ordinal.get(key, 0)
        self._ordinal[key] = ordinal + 1
        self.checks += 1
        if self.rate <= 0.0:
            return False
        faulted = self._uniform(plane, key[1], ordinal) < self.rate
        if faulted:
            self.faults += 1
        return faulted

    def stats(self) -> Dict[str, int]:
        return {"fault_checks": self.checks, "faults_injected": self.faults}
