"""Multi-model weight-arena residency: §V-C weight reuse across tenants.

The engine serves several models off one device weight arena of layer-sized
slots.  Every tenant's big tensors are quantized into ONE `QuantizedStore`,
so the §V-C mean-centering picks a single Center across *all* tenants —
cross-model deltas then skip as many cells as cross-layer deltas do inside
one model.  When the step scheduler switches which model's slots decode, the
manager installs that model's layer codes, choosing for each incoming layer
the victim slot whose current occupant minimizes the delta wire bytes
(greedy min-delta assignment = "order installs by delta similarity"), and
accounts raw vs wire bytes exactly like `streaming/executor.py` does for a
single model.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterable, List, Optional, Set, Tuple

import jax
import numpy as np

from repro.nn.config import ModelConfig
from repro.nn.transformer import stack_plan
from repro.streaming.delta import QuantizedStore
from repro.streaming.executor import _split_block_params


def model_layer_tensors(params: Any, cfg: ModelConfig) -> List[List[np.ndarray]]:
    """Per-layer big (quantizable) tensors, mirroring StreamingExecutor's
    block extraction: scanned segments are unstacked into individual layers."""
    blocks = []
    for seg_params, (start, length, scanned) in zip(
            params["stack"]["segments"], stack_plan(cfg)):
        if scanned:
            blocks.extend(
                jax.tree.map(lambda a, i=i: np.asarray(a[i]), seg_params)
                for i in range(length))
        else:
            blocks.append(seg_params)
    return [_split_block_params(bp)[0] for bp in blocks]


@dataclasses.dataclass
class ResidencyStats:
    raw_bytes: int = 0
    wire_bytes: int = 0
    installs: int = 0
    cold_installs: int = 0
    cross_tenant_installs: int = 0
    skips: float = 0.0

    @property
    def mean_skip(self) -> float:
        return self.skips / max(self.installs, 1)

    @property
    def savings(self) -> float:
        """Fraction of raw install traffic the delta stream avoided."""
        if self.raw_bytes == 0:
            return 0.0
        return 1.0 - self.wire_bytes / self.raw_bytes

    def as_dict(self) -> Dict[str, float]:
        return {
            "install_raw_bytes": float(self.raw_bytes),
            "install_wire_bytes": float(self.wire_bytes),
            "installs": float(self.installs),
            "cold_installs": float(self.cold_installs),
            "cross_tenant_installs": float(self.cross_tenant_installs),
            "install_mean_skip": self.mean_skip,
            "install_savings": self.savings,
        }


class WeightResidencyManager:
    def __init__(self, models: Dict[str, Tuple[Any, ModelConfig]],
                 arena_slots: int, *, reuse: bool = True):
        store_input: List[Tuple[str, List[np.ndarray]]] = []
        offset_groups: List[int] = []
        self.layer_ids: Dict[str, List[int]] = {}
        self.model_of: List[str] = []
        for name, (params, cfg) in models.items():
            per_layer = model_layer_tensors(params, cfg)
            ids = []
            for i, tensors in enumerate(per_layer):
                ids.append(len(store_input))
                store_input.append((f"{name}/L{i}", tensors))
                offset_groups.append(i)   # align tenants layer-by-layer
                self.model_of.append(name)
            self.layer_ids[name] = ids
        # reuse=False is the paper's baseline: every cell programmed on every
        # install (raw stream, no centering).  reuse=True is §V-C applied
        # across tenants: equal-cell skipping + pooled per-layer-group
        # centering so model variants stay code-aligned.
        self.reuse = reuse
        self.store = QuantizedStore(store_input, reuse=reuse,
                                    offset_groups=offset_groups)

        biggest = max(len(ids) for ids in self.layer_ids.values())
        if arena_slots < biggest:
            raise ValueError(
                f"weight arena of {arena_slots} slots cannot hold the "
                f"largest model ({biggest} layers)")
        self.arena_slots = arena_slots
        self.slots: List[Optional[int]] = [None] * arena_slots  # store idx
        self.resident: Dict[int, int] = {}                      # layer -> slot
        self._stamp = [0] * arena_slots                         # LRU step
        self.stats = ResidencyStats()
        # Codes are immutable after store construction, so the (occupant,
        # incoming) pair cost is memoizable — tenant turns repeat the same
        # pairs every switch.
        self._cost_cache: Dict[Tuple[Optional[int], int], Tuple[int, float]] = {}

    # ---------------------------------------------------------- capacity
    def layers_of(self, models: Iterable[str]) -> int:
        return sum(len(self.layer_ids[m]) for m in set(models))

    def fits(self, models: Iterable[str]) -> bool:
        """Can all these tenants be simultaneously resident?"""
        return self.layers_of(models) <= self.arena_slots

    def resident_fraction(self, model: str) -> float:
        ids = self.layer_ids[model]
        return sum(1 for l in ids if l in self.resident) / max(len(ids), 1)

    # ----------------------------------------------------------- install
    def _cost(self, occupant: Optional[int], layer: int) -> Tuple[int, float]:
        """Wire bytes to install `layer` over `occupant`.  The installer
        ships whichever stream is cheaper — the entropy-coded cell delta or
        the raw codes — so a dissimilar occupant never costs MORE than a
        cold install (delta entropy can exceed 2 bits/cell between
        unrelated tenants).  With reuse off every install ships raw."""
        raw = self.store.layers[layer].codes.size
        if not self.reuse:
            return raw, 0.0
        key = (occupant, layer)
        if key not in self._cost_cache:
            wire, skip = self.store.install_cost(occupant, layer)
            self._cost_cache[key] = (raw, 0.0) if wire >= raw else (wire, skip)
        return self._cost_cache[key]

    def _install(self, layer: int, slot: int, step: int) -> int:
        occupant = self.slots[slot]
        wire, skip = self._cost(occupant, layer)
        raw = self.store.layers[layer].codes.size
        self.stats.raw_bytes += raw
        self.stats.wire_bytes += wire
        self.stats.installs += 1
        self.stats.skips += skip
        if occupant is None:
            self.stats.cold_installs += 1
        else:
            self.resident.pop(occupant, None)
            if self.model_of[occupant] != self.model_of[layer]:
                self.stats.cross_tenant_installs += 1
        self.slots[slot] = layer
        self.resident[layer] = slot
        self._stamp[slot] = step
        return wire

    def ensure(self, model: str, step: int,
               pinned: Set[str] = frozenset()) -> int:
        """Make every layer of `model` resident; returns wire bytes moved.

        Victim slots are those holding no layer of a pinned (actively
        decoding) tenant.  Installs are ordered greedily by delta
        similarity: at each step the (incoming layer, victim slot) pair with
        the cheapest delta stream installs first, so similar cross-tenant
        layers land on top of each other.
        """
        pinned = set(pinned) | {model}
        missing = [l for l in self.layer_ids[model] if l not in self.resident]
        if not missing:
            for l in self.layer_ids[model]:
                self._stamp[self.resident[l]] = step
            return 0

        def evictable(slot: int) -> bool:
            occ = self.slots[slot]
            return occ is None or self.model_of[occ] not in pinned

        candidates = [s for s in range(self.arena_slots) if evictable(s)]
        if len(candidates) < len(missing):
            raise RuntimeError(
                f"weight arena too small: need {len(missing)} slots for "
                f"{model}, only {len(candidates)} evictable")

        wire_total = 0
        while missing:
            best = None
            for layer in missing:
                for slot in candidates:
                    wire, _ = self._cost(self.slots[slot], layer)
                    # ties (e.g. reuse off: everything raw) break LRU-first
                    key = (wire, self._stamp[slot])
                    if best is None or key < best[0]:
                        best = (key, layer, slot)
            _, layer, slot = best
            wire_total += self._install(layer, slot, step)
            missing.remove(layer)
            candidates.remove(slot)
        for l in self.layer_ids[model]:
            self._stamp[self.resident[l]] = step
        return wire_total
