"""Multi-model weight-arena residency: §V-C weight reuse across tenants.

The engine serves several models off one device weight arena of layer-sized
slots.  Every tenant's big tensors are quantized into ONE `QuantizedStore`,
so the §V-C mean-centering picks a single Center across *all* tenants —
cross-model deltas then skip as many cells as cross-layer deltas do inside
one model.  When the step scheduler switches which model's slots decode, the
manager installs that model's layer codes, choosing for each incoming layer
the victim slot whose current occupant minimizes the delta wire bytes
(greedy min-delta assignment = "order installs by delta similarity"), and
accounts raw vs wire bytes exactly like `streaming/executor.py` does for a
single model.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterable, List, Optional, Set, Tuple

import jax
import numpy as np

from repro.nn.config import ModelConfig
from repro.nn.transformer import stack_plan
from repro.serving.tracing import NULL_TRACER
from repro.streaming.delta import QuantizedStore
from repro.streaming.executor import _split_block_params
from repro.streaming.plan import InstallCostModel


def model_layer_tensors(params: Any, cfg: ModelConfig) -> List[List[np.ndarray]]:
    """Per-layer big (quantizable) tensors, mirroring StreamingExecutor's
    block extraction: scanned segments are unstacked into individual layers."""
    blocks = []
    for seg_params, (start, length, scanned) in zip(
            params["stack"]["segments"], stack_plan(cfg)):
        if scanned:
            blocks.extend(
                jax.tree.map(lambda a, i=i: np.asarray(a[i]), seg_params)
                for i in range(length))
        else:
            blocks.append(seg_params)
    return [_split_block_params(bp)[0] for bp in blocks]


@dataclasses.dataclass
class ResidencyStats:
    raw_bytes: int = 0
    wire_bytes: int = 0
    installs: int = 0
    cold_installs: int = 0
    cross_tenant_installs: int = 0
    skips: float = 0.0
    # device-side write activity (wear/energy telemetry): cells actually
    # programmed and incremental pulses issued, equal-skip aware under reuse
    cell_flips: int = 0
    write_pulses: int = 0
    # arena slots permanently pulled from service after a stuck-at fault
    # was detected at program time (Hamun-style graceful degradation)
    slots_retired: int = 0

    @property
    def mean_skip(self) -> float:
        return self.skips / max(self.installs, 1)

    @property
    def savings(self) -> float:
        """Fraction of raw install traffic the delta stream avoided."""
        if self.raw_bytes == 0:
            return 0.0
        return 1.0 - self.wire_bytes / self.raw_bytes

    def as_dict(self) -> Dict[str, float]:
        return {
            "install_raw_bytes": float(self.raw_bytes),
            "install_wire_bytes": float(self.wire_bytes),
            "installs": float(self.installs),
            "cold_installs": float(self.cold_installs),
            "cross_tenant_installs": float(self.cross_tenant_installs),
            "install_mean_skip": self.mean_skip,
            "install_savings": self.savings,
            "install_cell_flips": float(self.cell_flips),
            "install_write_pulses": float(self.write_pulses),
            "slots_retired": float(self.slots_retired),
        }


class WeightResidencyManager:
    # structured-event sink for committed installs; the engine swaps in
    # its shared Tracer, standalone use keeps the no-op
    tracer = NULL_TRACER
    # wear telemetry sinks, injected like the tracer: `wear` is the weight
    # arena's WearPlane (per-slot writes/flips/pulses, keyed by layer
    # group), `flip_hist` a MetricsRegistry histogram of per-install flips;
    # standalone use records nothing
    wear = None
    flip_hist = None
    # wear-aware victim blending (Hamun policy half): weight > 0 adds a
    # per-prior-write penalty to each victim slot's delta cost so installs
    # rotate toward cold slots; 0 keeps the pure greedy min-delta picker
    # bit-for-bit.  The engine sets it from its `wear_aware` knob.
    wear_weight = 0.0
    # stuck-at fault model (serving/faults.py), injected like the tracer;
    # None = fault-free, every check site skipped
    faults = None

    def __init__(self, models: Dict[str, Tuple[Any, ModelConfig]],
                 arena_slots: int, *, reuse: bool = True):
        store_input: List[Tuple[str, List[np.ndarray]]] = []
        offset_groups: List[int] = []
        self.layer_ids: Dict[str, List[int]] = {}
        self.model_of: List[str] = []
        for name, (params, cfg) in models.items():
            per_layer = model_layer_tensors(params, cfg)
            ids = []
            for i, tensors in enumerate(per_layer):
                ids.append(len(store_input))
                store_input.append((f"{name}/L{i}", tensors))
                offset_groups.append(i)   # align tenants layer-by-layer
                self.model_of.append(name)
            self.layer_ids[name] = ids
        # layer-group label per store layer (the §V-C offset group): the
        # wear map's slot×group dimension keys on it
        self.group_of: List[int] = offset_groups
        # reuse=False is the paper's baseline: every cell programmed on every
        # install (raw stream, no centering).  reuse=True is §V-C applied
        # across tenants: equal-cell skipping + pooled per-layer-group
        # centering so model variants stay code-aligned.
        self.reuse = reuse
        self.store = QuantizedStore(store_input, reuse=reuse,
                                    offset_groups=offset_groups)

        biggest = max(len(ids) for ids in self.layer_ids.values())
        if arena_slots < biggest:
            raise ValueError(
                f"weight arena of {arena_slots} slots cannot hold the "
                f"largest model ({biggest} layers)")
        self.arena_slots = arena_slots
        self.slots: List[Optional[int]] = [None] * arena_slots  # store idx
        self.resident: Dict[int, int] = {}                      # layer -> slot
        self._stamp = [0] * arena_slots                         # LRU step
        # slots retired after a detected stuck-at fault — never issued again
        self.retired: Set[int] = set()
        # one prior write to a slot weighs `wear_weight` raw layer installs
        # in the blended victim cost; the mean layer size converts "writes"
        # into the wire-byte units the greedy picker already ranks by
        self._wear_unit = max(1, int(np.mean(
            [lay.codes.size for lay in self.store.layers])))
        self.stats = ResidencyStats()
        # Codes are immutable after store construction, so the (occupant,
        # incoming) pair cost is memoizable — tenant turns repeat the same
        # pairs every switch.
        self._cost_cache: Dict[Tuple[Optional[int], int],
                               Tuple[int, float, int, int]] = {}

    # ---------------------------------------------------------- capacity
    def layers_of(self, models: Iterable[str]) -> int:
        return sum(len(self.layer_ids[m]) for m in set(models))

    def fits(self, models: Iterable[str]) -> bool:
        """Can all these tenants be simultaneously resident?  Retired
        (faulted) slots no longer count toward capacity."""
        return self.layers_of(models) <= self.arena_slots - len(self.retired)

    def resident_fraction(self, model: str) -> float:
        ids = self.layer_ids[model]
        return sum(1 for l in ids if l in self.resident) / max(len(ids), 1)

    def is_resident(self, model: str) -> bool:
        """Every layer of `model` currently occupies an arena slot."""
        return all(l in self.resident for l in self.layer_ids[model])

    def touch(self, model: str, step: int) -> None:
        """Refresh the LRU stamp of `model`'s resident layers (a tenant that
        decoded this step must not look like an eviction candidate)."""
        for l in self.layer_ids[model]:
            slot = self.resident.get(l)
            if slot is not None:
                self._stamp[slot] = step

    # ----------------------------------------------------------- install
    def _cost(self, occupant: Optional[int], layer: int
              ) -> Tuple[int, float, int, int]:
        """(wire bytes, skip ratio, cells flipped, programming pulses) to
        install `layer` over `occupant`.  The installer ships whichever
        stream is cheaper — the entropy-coded cell delta or the raw codes —
        so a dissimilar occupant never costs MORE than a cold install
        (delta entropy can exceed 2 bits/cell between unrelated tenants);
        the device-side flip/pulse counts depend only on resident-vs-
        incoming cells, not on which stream shipped.  With reuse off every
        install ships raw and the programmer rewrites every cell."""
        key = (occupant, layer)
        got = self._cost_cache.get(key)
        if got is None:
            raw = self.store.layers[layer].codes.size
            flips, pulses = self.store.install_flips(
                occupant, layer, skip_equal=self.reuse)
            if not self.reuse:
                got = (raw, 0.0, flips, pulses)
            else:
                wire, skip = self.store.install_cost(occupant, layer)
                if wire >= raw:
                    wire, skip = raw, 0.0
                got = (wire, skip, flips, pulses)
            self._cost_cache[key] = got
        return got

    def _victim_key(self, slot: int, wire: int) -> Tuple[int, int]:
        """Victim-ranking key blending delta cost with slot wear.  With
        `wear_weight` 0 this is `(wire, 0)` — the pure greedy min-delta
        order, bit-for-bit.  With weight w > 0 each prior write to the slot
        penalizes it by `w * mean_layer_size` wire-byte-equivalents, and the
        raw write count breaks exact-cost ties toward the coldest slot."""
        if self.wear_weight <= 0.0 or self.wear is None:
            return (wire, 0)
        writes = int(self.wear.writes[slot])
        penalty = int(round(self.wear_weight * self._wear_unit * writes))
        return (wire + penalty, writes)

    def _install(self, layer: int, slot: int, step: int) -> Optional[int]:
        """Commit `layer` into `slot`; returns wire bytes, or None when the
        program-and-verify detects a stuck-at fault — the slot is then
        retired (its occupant, if any, is no longer resident) and the caller
        must remap the layer to a healthy slot."""
        occupant = self.slots[slot]
        wire, skip, flips, pulses = self._cost(occupant, layer)
        if self.faults is not None and self.faults.check("weight", slot):
            # the pulses were spent before verify failed: wear still lands,
            # then the slot leaves service for good
            if self.wear is not None:
                self.wear.record(slot, flips=flips, pulses=pulses,
                                 group=self.group_of[layer])
                self.wear.retire(slot)
            self.stats.cell_flips += flips
            self.stats.write_pulses += pulses
            self.stats.slots_retired += 1
            self.retired.add(slot)
            if occupant is not None:
                self.resident.pop(occupant, None)
            self.slots[slot] = None
            if self.tracer.enabled:
                self.tracer.instant("slot_retired", slot=slot, layer=layer,
                                    model=self.model_of[layer])
            return None
        raw = self.store.layers[layer].codes.size
        self.stats.raw_bytes += raw
        self.stats.wire_bytes += wire
        self.stats.installs += 1
        self.stats.skips += skip
        self.stats.cell_flips += flips
        self.stats.write_pulses += pulses
        if self.wear is not None:
            self.wear.record(slot, flips=flips, pulses=pulses,
                             group=self.group_of[layer])
        if self.flip_hist is not None:
            self.flip_hist.observe(flips)
        if occupant is None:
            self.stats.cold_installs += 1
        else:
            self.resident.pop(occupant, None)
            if self.model_of[occupant] != self.model_of[layer]:
                self.stats.cross_tenant_installs += 1
        self.slots[slot] = layer
        self.resident[layer] = slot
        self._stamp[slot] = step
        if self.tracer.enabled:
            self.tracer.instant(
                "install_land", layer=layer, slot=slot, wire=wire,
                model=self.model_of[layer],
                victim=(None if occupant is None
                        else self.model_of[occupant]))
        return wire

    def ensure(self, model: str, step: int,
               pinned: Set[str] = frozenset()) -> int:
        """Make every layer of `model` resident; returns wire bytes moved.

        Victim slots are those holding no layer of a pinned (actively
        decoding) tenant.  Installs are ordered greedily by delta
        similarity: at each step the (incoming layer, victim slot) pair with
        the cheapest delta stream installs first, so similar cross-tenant
        layers land on top of each other.
        """
        pinned = set(pinned) | {model}
        missing = [l for l in self.layer_ids[model] if l not in self.resident]
        if not missing:
            self.touch(model, step)
            return 0

        def evictable(slot: int) -> bool:
            occ = self.slots[slot]
            return occ is None or self.model_of[occ] not in pinned

        candidates = [s for s in range(self.arena_slots)
                      if s not in self.retired and evictable(s)]
        if len(candidates) < len(missing):
            raise RuntimeError(
                f"weight arena too small: need {len(missing)} slots for "
                f"{model}, only {len(candidates)} evictable")

        wire_total = 0
        while missing:
            best = None
            for layer in missing:
                for slot in candidates:
                    wire = self._cost(self.slots[slot], layer)[0]
                    # ties (e.g. reuse off: everything raw) break LRU-first
                    key = (*self._victim_key(slot, wire), self._stamp[slot])
                    if best is None or key < best[0]:
                        best = (key, layer, slot)
            _, layer, slot = best
            wire = self._install(layer, slot, step)
            candidates.remove(slot)
            if wire is None:
                # slot died at program time: the layer stays missing and
                # retries on the next-best healthy slot
                if len(candidates) < len(missing):
                    raise RuntimeError(
                        f"weight arena exhausted by faults: need "
                        f"{len(missing)} slots for {model}, only "
                        f"{len(candidates)} healthy evictable left")
                continue
            wire_total += wire
            missing.remove(layer)
        self.touch(model, step)
        return wire_total


class InstallPipeline:
    """Budgeted, overlappable layer installs — ARAS §IV applied to tenant
    switches.

    Where `ensure()` installs a whole tenant synchronously at the turn
    boundary, the pipeline spreads the same greedy min-delta installs over
    per-step tick budgets so they run *while* the outgoing tenant's final
    decode steps still compute.  One tick is the DMA work one decode step
    hides (`InstallCostModel.bytes_per_tick` wire bytes); an install commits
    — and its stats land in `ResidencyStats` — only when its whole tick cost
    has been pumped, mirroring a transfer that completes mid-turn.

    Victim choice is `ensure()`'s rule evaluated incrementally: each unit
    picks the (incoming layer, evictable slot) pair with the cheapest delta
    stream, tie-broken toward the incoming tenant's earliest layers, so the
    target's first-executed layers become resident first (the order its
    first post-switch decode step needs them — the serving analogue of
    `streaming/executor.py` installing layer i+1 behind layer i's compute).
    """

    # structured-event sink for begin/abort/victim-pick decisions; the
    # engine swaps in its shared Tracer, standalone use keeps the no-op
    tracer = NULL_TRACER

    def __init__(self, residency: WeightResidencyManager,
                 cost: InstallCostModel):
        self.res = residency
        self.cost = cost
        self.target: Optional[str] = None
        self._missing: List[int] = []
        # in-flight install: [layer, slot, ticks_left, ticks_total, wire]
        self._cur: Optional[List[int]] = None
        self.pumped_ticks = 0
        self.aborts = 0

    @property
    def idle(self) -> bool:
        return self.target is None

    @property
    def queue_depth(self) -> int:
        """Layers still queued for the current target, the in-flight
        partial install included — the live install-backlog counter."""
        return len(self._missing) + (self._cur is not None)

    def begin(self, model: str, step: int) -> None:
        """(Re)target the pipeline.  Retargeting drops any in-flight
        partial install — its ticks are sunk cost, counted in `aborts`."""
        missing = [l for l in self.res.layer_ids[model]
                   if l not in self.res.resident]
        if self.target == model:
            if self._cur is not None:
                missing = [l for l in missing if l != self._cur[0]]
            self._missing = missing
            return
        if self._cur is not None:
            self.aborts += 1
            self.tracer.instant("install_abort", layer=self._cur[0],
                                reason="retarget", target=model)
            self._cur = None
        self.target = model
        self._missing = missing
        self.tracer.instant("install_begin", target=model,
                            missing=len(missing), step=step)

    def _evictable(self, slot: int, pinned: Set[str]) -> bool:
        if slot in self.res.retired:
            return False
        occ = self.res.slots[slot]
        return occ is None or self.res.model_of[occ] not in pinned

    def _pick(self, pinned: Set[str]) -> Optional[Tuple[int, int, int]]:
        best = None
        for slot in range(self.res.arena_slots):
            if not self._evictable(slot, pinned):
                continue
            for layer in self._missing:
                wire = self.res._cost(self.res.slots[slot], layer)[0]
                key = (*self.res._victim_key(slot, wire), layer,
                       self.res._stamp[slot])
                if best is None or key < best[0]:
                    best = (key, layer, slot)
        if best is None:
            return None
        _, layer, slot = best
        # key[0] is the wear-blended cost, not the wire bytes — re-read the
        # memoized cost for the tick budget
        wire = self.res._cost(self.res.slots[slot], layer)[0]
        return layer, slot, wire

    def pump(self, ticks: int, pinned: Set[str], step: int
             ) -> Tuple[int, int]:
        """Spend up to `ticks` install ticks toward the target's missing
        layers.  Returns (wire bytes committed, wire bytes processed) — the
        latter includes the pro-rata share of partially pumped installs, so
        the engine can attribute this step's DMA work to overlap-hidden vs
        stalled time."""
        if self.target is None:
            return 0, 0
        pinned = set(pinned) | {self.target}
        committed = 0
        processed = 0.0
        while ticks > 0:
            if self._cur is None:
                if not self._missing:
                    break
                pick = self._pick(pinned)
                if pick is None:
                    break               # nothing evictable right now
                layer, slot, wire = pick
                self._missing.remove(layer)   # _missing never holds in-flight
                t = self.cost.ticks_for(wire)
                self._cur = [layer, slot, t, t, wire]
                if self.tracer.enabled:
                    self.tracer.instant("install_victim", layer=layer,
                                        slot=slot, wire=wire, ticks=t)
            elif not self._evictable(self._cur[1], pinned):
                # our victim got re-pinned (e.g. the outgoing tenant's turn
                # did not actually end) — drop the partial transfer and put
                # the layer back on the queue
                self.aborts += 1
                self.tracer.instant("install_abort", layer=self._cur[0],
                                    reason="victim repinned")
                self._missing.append(self._cur[0])
                self._cur = None
                continue
            layer, slot, left, total, wire = self._cur
            spend = min(ticks, left)
            ticks -= spend
            left -= spend
            self.pumped_ticks += spend
            processed += wire * (spend / total)
            self._cur[2] = left
            if left == 0:
                done = self.res._install(layer, slot, step)
                self._cur = None
                if done is None:
                    # the victim slot faulted at program time — it is now
                    # retired; re-queue the layer so the next unit picks a
                    # healthy slot
                    self._missing.append(layer)
                else:
                    committed += done
        if self._cur is None and not self._missing:
            self.target = None          # fully resident: pipeline drains
        return committed, int(round(processed))
