"""Simulated-time driving harness for the serving engine.

One canonical arrival-clocked loop shared by the deterministic benchmark
arms and the install-overlap tests, so "submit at virtual arrival time,
step while there is work, advance the clock" has a single definition: the
engine runs on a `VirtualClock` and every latency/stall metric comes out
bit-for-bit reproducible, no device or wall clock involved.
"""
from __future__ import annotations

from typing import Callable, Dict, Iterable, Optional, Sequence, Tuple

Job = Tuple[float, str, Sequence[int], int]   # (arrival_t, model, prompt, gen)


def drive_simulated(eng, clock, jobs: Iterable[Job], *, dt: float = 1.0,
                    max_steps: int = 100_000,
                    before_step: Optional[Callable] = None,
                    after_step: Optional[Callable] = None,
                    step_dt: Optional[Callable] = None,
                    health_every: int = 0,
                    on_health: Optional[Callable] = None
                    ) -> Dict[str, float]:
    """Drive `eng` over `jobs` in virtual time and return its summary.

    Each iteration submits every job whose arrival time has passed, steps
    the engine if it has work, and advances `clock` by `dt` (idle waits
    included, so arrival gaps cost virtual time too).  `step_dt`, when
    given, is a per-step cost model: it receives the just-recorded
    StepRecord and returns that step's virtual duration — how the chunked
    prefill benchmarks charge a step for the prompt tokens it prefilled
    (`rec.prefill_tokens`), so a monolithic long prefill shows up as one
    long step while a budgeted chunked prefill shows up as several short
    ones.  Idle iterations (no step) always advance by `dt`.  `before_step`
    / `after_step` hooks receive the engine around each step — the tests
    use them to assert invariants mid-flight.  `health_every` > 0 calls
    `on_health(eng.health())` every that-many driven steps — how the
    tests and bench sample the live router-probe snapshot at
    deterministic virtual times.  Raises RuntimeError instead of
    spinning forever if the workload does not drain within `max_steps`.
    """
    pending = sorted(jobs)
    n_steps = 0
    for _ in range(max_steps):
        if not pending and not eng.has_work():
            break
        while pending and pending[0][0] <= clock.t:
            _, model, prompt, gen = pending.pop(0)
            eng.submit(model, prompt, max_new_tokens=gen)
        stepped = False
        if eng.has_work():
            if before_step is not None:
                before_step(eng)
            eng.step()
            stepped = True
            n_steps += 1
            if (health_every > 0 and on_health is not None
                    and n_steps % health_every == 0):
                on_health(eng.health())
            if after_step is not None:
                after_step(eng)
        if stepped and step_dt is not None:
            clock.advance(step_dt(eng.metrics.steps[-1]))
        else:
            clock.advance(dt)
    else:
        raise RuntimeError(
            f"simulated drive did not drain the workload in {max_steps} "
            "steps — engine livelock?")
    return eng.summary(clock.t)
