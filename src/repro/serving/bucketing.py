"""Prompt-length bucketing and chunked-prefill progress tracking.

jit specializes a prefill on every input length, so an engine fed arbitrary
prompt lengths compiles an unbounded family of executables.  Chunking fixes
most of it for free — every full chunk is exactly `prefill_chunk` tokens —
and the geometric bucket ladder bounds the rest: the final partial chunk is
padded up to the nearest ladder rung, so the number of distinct traces is
at most the ladder size (`O(log_growth(chunk))`) instead of one per prompt
length.  This is the FPSA/ARAS full-stack argument at the compiler level: a
fixed set of compiled tiles serves arbitrary workloads because the
scheduler slices and pads work to fit them.

Ladder guarantees (property-tested in tests/test_chunked_prefill.py):
  * coverage   — bucket_for(n) >= n for every n <= the top rung;
  * monotone   — rungs strictly increase, bucket_for is non-decreasing;
  * bounded waste — bucket_for(n) <= growth * n: a rung r is followed by at
    most ceil(r * growth), so any n > r pays at most (r·g + 1)/(r + 1) <= g
    padding overhead.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, List, Optional, Tuple


def bucket_ladder(lo: int, hi: int, growth: float) -> List[int]:
    """Geometric rungs lo, ~lo·g, ... capped at hi (always the top rung)."""
    if lo < 1 or hi < 1:
        raise ValueError("ladder bounds must be >= 1")
    if growth <= 1.0:
        raise ValueError("bucket growth must be > 1 (use bucketing=off "
                         "instead of a degenerate ladder)")
    if hi <= lo:
        return [hi]
    rungs = [lo]
    while rungs[-1] < hi:
        rungs.append(min(max(math.ceil(rungs[-1] * growth),
                             rungs[-1] + 1), hi))
    return rungs


def bucket_for(n: int, ladder: List[int]) -> int:
    """Smallest rung >= n (the top rung for anything larger)."""
    for rung in ladder:
        if rung >= n:
            return rung
    return ladder[-1]


@dataclasses.dataclass
class PrefillProgress:
    """Chunked-prefill state of one request: the prompt being prefilled,
    the batch-1 staging cache the chunks accumulate into, and how far they
    got.  Survives mid-prefill preemption — pages/slots are released, but
    the staging (per-request memory, not pool) keeps every completed
    chunk's K/V, so readmission resumes at `done` instead of re-running
    the prompt.  A prefix-cache hit advances `done` without compute:
    `skipped` counts tokens whose K/V came out of cached pages instead of
    a chunk run (the staging carry-in is seeded from the pool up to the
    hit boundary)."""
    tokens: Tuple[int, ...]          # full serving prompt (incl. generated)
    caches: Any                      # batch-1 staging cache pytree
    done: int = 0                    # prompt tokens covered so far
    skipped: int = 0                 # of those, sourced from cached pages
    staging_len: int = 0             # this request's staging-ladder rung
    logits: Any = None               # final chunk's next-token logits
    start_t: Optional[float] = None  # first chunk launch (TTFT split)

    @property
    def finished(self) -> bool:
        return self.done >= len(self.tokens)
