"""Live telemetry plane: streaming quantiles, SLO burn-rate tracking,
and standard exporters (Prometheus text exposition + JSONL events).

Everything the engine measured before this module is *postmortem*:
`EngineMetrics.summary()` walks whole-run sample lists at exit.  The
router/cluster tier (ROADMAP) needs a *live* per-replica signal, so this
module keeps O(1)-memory streaming views instead:

- `P2Quantile` — the Jain & Chlamtac P-squared estimator: one quantile
  tracked with five markers, O(1) update, no stored samples.  Exact for
  the first five observations, convergent after.
- `SlidingWindow` — fixed-size ring over the last N samples with exact
  `np.percentile` quantiles (so results are *exact* whenever the stream
  is no longer than the window) — the "recent behaviour" view.
- `StreamStat` — one metric's live view: sliding window + lifetime P²
  p50/p95.
- `SLOConfig`/`SLOTracker` — declared p95 targets evaluated as SRE-style
  burn rates over a short and a long indicator window; transitions emit
  `slo_breach` / `slo_recover`.
- `EngineTelemetry` — the per-engine aggregator fed by the step loop:
  TTFT / worst-ITL / queue-wait / latency windows per tenant and global,
  queue-depth / free-pages / prefix-hit windows per step, the SLO
  tracker, and an optional append-mode JSONL event stream.
- `prometheus_text` / `PromEndpoint` — text exposition from the typed
  `MetricsRegistry` (+ live windows), as a textfile or a stdlib
  `http.server` endpoint.
- `validate_prometheus_text` / `validate_events_jsonl` — format checkers
  used by CI (`python -m repro.serving.telemetry --prom ... --events ...`).

All of it is observation-only: enabling telemetry must never change a
token the engine emits (asserted in tests and bench part 10).
"""
from __future__ import annotations

import collections
import dataclasses
import json
import math
import re
import threading
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.serving.metrics import MetricsRegistry
from repro.serving.request import Request
from repro.serving.tracing import NULL_TRACER


def _nan() -> float:
    return float("nan")


def _jsonable(v):
    """NaN/Inf -> None so every exported document is strict JSON."""
    if isinstance(v, float) and not math.isfinite(v):
        return None
    if isinstance(v, dict):
        return {k: _jsonable(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    return v


def dumps_deterministic(doc: dict) -> str:
    """Canonical JSON used for every telemetry artifact: sorted keys,
    compact separators, NaN scrubbed — byte-identical across runs when
    the inputs are (which under `VirtualClock` they are)."""
    return json.dumps(_jsonable(doc), sort_keys=True,
                      separators=(",", ":"))


# ------------------------------------------------------------ quantiles
class P2Quantile:
    """Streaming quantile via the P-squared algorithm (Jain & Chlamtac,
    CACM 1985): five markers whose heights approximate the running
    p-quantile, adjusted with a piecewise-parabolic fit.  O(1) memory and
    time per observation; exact (sorted-sample percentile) until the
    fifth sample arrives."""

    __slots__ = ("p", "count", "_q", "_n", "_np", "_dn")

    def __init__(self, p: float):
        if not 0.0 < p < 1.0:
            raise ValueError(f"quantile p must be in (0, 1), got {p}")
        self.p = float(p)
        self.count = 0
        self._q: List[float] = []       # marker heights
        self._n: List[float] = []       # marker positions (1-based)
        self._np: List[float] = []      # desired positions
        self._dn = [0.0, p / 2.0, p, (1.0 + p) / 2.0, 1.0]

    def observe(self, x: float) -> None:
        x = float(x)
        self.count += 1
        if self.count <= 5:
            self._q.append(x)
            self._q.sort()
            if self.count == 5:
                p = self.p
                self._n = [1.0, 2.0, 3.0, 4.0, 5.0]
                self._np = [1.0, 1.0 + 2.0 * p, 1.0 + 4.0 * p,
                            3.0 + 2.0 * p, 5.0]
            return
        q, n = self._q, self._n
        # locate the cell, extending the extreme markers if needed
        if x < q[0]:
            q[0] = x
            k = 0
        elif x >= q[4]:
            q[4] = x
            k = 3
        else:
            k = 0
            while k < 3 and x >= q[k + 1]:
                k += 1
        for i in range(k + 1, 5):
            n[i] += 1.0
        for i in range(5):
            self._np[i] += self._dn[i]
        # nudge the three interior markers toward their desired positions
        for i in (1, 2, 3):
            d = self._np[i] - n[i]
            if ((d >= 1.0 and n[i + 1] - n[i] > 1.0)
                    or (d <= -1.0 and n[i - 1] - n[i] < -1.0)):
                s = 1.0 if d >= 1.0 else -1.0
                qi = self._parabolic(i, s)
                if not q[i - 1] < qi < q[i + 1]:
                    qi = self._linear(i, s)
                q[i] = qi
                n[i] += s

    def _parabolic(self, i: int, s: float) -> float:
        q, n = self._q, self._n
        return q[i] + s / (n[i + 1] - n[i - 1]) * (
            (n[i] - n[i - 1] + s) * (q[i + 1] - q[i])
            / (n[i + 1] - n[i])
            + (n[i + 1] - n[i] - s) * (q[i] - q[i - 1])
            / (n[i] - n[i - 1]))

    def _linear(self, i: int, s: float) -> float:
        q, n = self._q, self._n
        j = i + int(s)
        return q[i] + s * (q[j] - q[i]) / (n[j] - n[i])

    @property
    def value(self) -> float:
        if self.count == 0:
            return _nan()
        if self.count < 5:
            return float(np.percentile(
                np.asarray(self._q, np.float64), self.p * 100.0))
        return self._q[2]


class SlidingWindow:
    """Fixed-size ring over the last `window` samples.  `quantile(p)`
    matches `np.percentile` over exactly that tail — so whenever the
    whole stream fits in the window the answer is *exact*, and it is NaN
    on an empty window.  Memory never grows past `window`."""

    __slots__ = ("window", "total", "_ring")

    def __init__(self, window: int):
        if window < 1:
            raise ValueError(f"window must be >= 1, got {window}")
        self.window = int(window)
        self.total = 0                       # lifetime observation count
        self._ring: Deque[float] = collections.deque(maxlen=self.window)

    def observe(self, x: float) -> None:
        self.total += 1
        self._ring.append(float(x))

    def __len__(self) -> int:
        return len(self._ring)

    @property
    def last(self) -> float:
        return self._ring[-1] if self._ring else _nan()

    def quantile(self, p: float) -> float:
        if not self._ring:
            return _nan()
        return float(np.percentile(
            np.asarray(self._ring, np.float64), p))

    def mean(self) -> float:
        if not self._ring:
            return _nan()
        return float(np.mean(np.asarray(self._ring, np.float64)))


class StreamStat:
    """One metric's live view: exact sliding-window p50/p95 over the
    last `window` samples plus lifetime P² p50/p95 at O(1) memory."""

    __slots__ = ("win", "_p50", "_p95")

    def __init__(self, window: int = 128):
        self.win = SlidingWindow(window)
        self._p50 = P2Quantile(0.50)
        self._p95 = P2Quantile(0.95)

    def observe(self, x: float) -> None:
        self.win.observe(x)
        self._p50.observe(x)
        self._p95.observe(x)

    @property
    def count(self) -> int:
        return self.win.total

    def p50(self) -> float:
        return self.win.quantile(50.0)

    def p95(self) -> float:
        return self.win.quantile(95.0)

    def snapshot(self) -> Dict[str, float]:
        return {
            "n": self.win.total,
            "last": self.win.last,
            "p50": self.p50(),
            "p95": self.p95(),
            "stream_p50": self._p50.value,
            "stream_p95": self._p95.value,
        }


# ------------------------------------------------------------------ SLO
@dataclasses.dataclass(frozen=True)
class SLOConfig:
    """Declared p95 latency targets (seconds); 0 disables a target.

    Each target is evaluated as an SRE-style burn rate: the fraction of
    recent samples over the limit, in a short and a long indicator
    window.  For a p95 objective the error budget is 5%, so a target is
    *breached* when both windows burn above `burn_threshold` (default
    0.05) — the short window makes detection fast, the long window keeps
    one bad sample from flapping the state."""

    ttft_p95_s: float = 0.0
    itl_p95_s: float = 0.0
    queue_wait_p95_s: float = 0.0
    short_window: int = 20
    long_window: int = 100
    burn_threshold: float = 0.05
    min_samples: int = 3     # short-window samples needed before a breach

    def targets(self) -> Dict[str, float]:
        out = {}
        if self.ttft_p95_s > 0:
            out["ttft_p95"] = self.ttft_p95_s
        if self.itl_p95_s > 0:
            out["itl_p95"] = self.itl_p95_s
        if self.queue_wait_p95_s > 0:
            out["queue_wait_p95"] = self.queue_wait_p95_s
        return out


class _SLOTarget:
    __slots__ = ("limit", "short", "long", "breached")

    def __init__(self, limit: float, cfg: SLOConfig):
        self.limit = float(limit)
        self.short: Deque[int] = collections.deque(maxlen=cfg.short_window)
        self.long: Deque[int] = collections.deque(maxlen=cfg.long_window)
        self.breached = False

    def burn(self) -> Tuple[float, float]:
        s = (sum(self.short) / len(self.short)) if self.short else 0.0
        lo = (sum(self.long) / len(self.long)) if self.long else 0.0
        return s, lo


class SLOTracker:
    """Burn-rate evaluation of `SLOConfig` targets over sample streams.

    `observe(name, sample)` files a boolean over-limit indicator;
    `evaluate()` returns the state *transitions* since the last call as
    `(kind, target, short_burn, long_burn)` tuples with kind in
    {"slo_breach", "slo_recover"}.  Pure function of the sample stream:
    deterministic under `VirtualClock`."""

    def __init__(self, cfg: SLOConfig):
        self.cfg = cfg
        self._targets = {name: _SLOTarget(limit, cfg)
                         for name, limit in cfg.targets().items()}

    def observe(self, name: str, sample: float) -> None:
        t = self._targets.get(name)
        if t is None:
            return
        bad = 1 if sample > t.limit else 0
        t.short.append(bad)
        t.long.append(bad)

    def evaluate(self) -> List[Tuple[str, str, float, float]]:
        out: List[Tuple[str, str, float, float]] = []
        thr = self.cfg.burn_threshold
        for name in sorted(self._targets):
            t = self._targets[name]
            s, lo = t.burn()
            if (not t.breached and len(t.short) >= self.cfg.min_samples
                    and s > thr and lo > thr):
                t.breached = True
                out.append(("slo_breach", name, s, lo))
            elif t.breached and s <= thr and lo <= thr:
                t.breached = False
                out.append(("slo_recover", name, s, lo))
        return out

    @property
    def any_breached(self) -> bool:
        return any(t.breached for t in self._targets.values())

    def status(self) -> Dict[str, Dict[str, float]]:
        out: Dict[str, Dict[str, float]] = {}
        for name in sorted(self._targets):
            t = self._targets[name]
            s, lo = t.burn()
            out[name] = {
                "target_s": t.limit,
                "breached": int(t.breached),
                "burn_short": s,
                "burn_long": lo,
                "samples": len(t.long),
            }
        return out


# -------------------------------------------------------- engine plumbing
@dataclasses.dataclass(frozen=True)
class TelemetryConfig:
    """Knobs for the live telemetry plane (all surfaces off by default
    at the engine level — constructing a config turns the plane on)."""

    window: int = 128                 # sliding-window size (samples/steps)
    slo: Optional[SLOConfig] = None
    events_path: str = ""             # append-mode JSONL stream ("" = off)


class JsonlWriter:
    """Append-mode JSONL event stream; one canonical-JSON object per
    line, flushed per write so a crash loses at most the current line."""

    def __init__(self, path: str):
        self.path = path
        self._f = open(path, "a", encoding="utf-8")

    def write(self, obj: dict) -> None:
        self._f.write(dumps_deterministic(obj) + "\n")
        self._f.flush()

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None


# metric names fed per finished request (per tenant + global scope)
FINISH_STATS = ("ttft_s", "itl_max_s", "queue_wait_s", "latency_s")
# metric names fed per engine step (global scope only)
STEP_STATS = ("queue_depth", "kv_free_pages", "prefix_hit_rate")
_GLOBAL = "_global"


class EngineTelemetry:
    """Per-engine live-telemetry aggregator.

    The engine calls `on_finish(req)` when a request completes and
    `on_step(step_no, t, rec, free_pages)` at the end of every step;
    both are O(1).  Everything here *observes* — no scheduling input
    ever reads a telemetry value, so enabling it is token-identical."""

    def __init__(self, cfg: TelemetryConfig, *, tracer=NULL_TRACER):
        self.cfg = cfg
        self.tracer = tracer
        self.slo = SLOTracker(cfg.slo) if cfg.slo is not None else None
        self.events = JsonlWriter(cfg.events_path) if cfg.events_path \
            else None
        self._stats: Dict[Tuple[str, str], StreamStat] = {}
        self._pending: List[Tuple[str, str, float, float]] = []
        self.finishes = 0

    def _stat(self, scope: str, name: str) -> StreamStat:
        key = (scope, name)
        st = self._stats.get(key)
        if st is None:
            st = StreamStat(self.cfg.window)
            self._stats[key] = st
        return st

    def _observe_finish(self, scope: str, name: str, value) -> None:
        if value is None:
            return
        self._stat(scope, name).observe(value)

    def on_finish(self, req: Request) -> None:
        """File one finished request's latency samples (global + tenant
        scope) and its SLO indicators; emits any SLO transition as a
        trace instant immediately."""
        self.finishes += 1
        samples = {"ttft_s": req.ttft, "itl_max_s": req.max_itl,
                   "queue_wait_s": req.ttft_queue, "latency_s": req.latency}
        for scope in (_GLOBAL, req.model):
            for name, v in samples.items():
                self._observe_finish(scope, name, v)
        if self.slo is not None:
            if req.ttft is not None:
                self.slo.observe("ttft_p95", req.ttft)
            if req.max_itl is not None:
                self.slo.observe("itl_p95", req.max_itl)
            if req.ttft_queue is not None:
                self.slo.observe("queue_wait_p95", req.ttft_queue)
            for kind, target, s, lo in self.slo.evaluate():
                self._pending.append((kind, target, s, lo))
                if self.tracer.enabled:
                    self.tracer.instant(kind, target=target, burn_short=s,
                                        burn_long=lo)
                if self.events is not None:
                    self.events.write({"type": kind, "t": req.finish_t,
                                       "target": target, "burn_short": s,
                                       "burn_long": lo})
        if self.events is not None:
            self.events.write({
                "type": "finish", "t": req.finish_t, "rid": req.rid,
                "tenant": req.model, "n_generated": len(req.generated),
                "ttft_s": req.ttft, "itl_max_s": req.max_itl,
                "queue_wait_s": req.ttft_queue, "latency_s": req.latency})

    def on_step(self, step_no: int, rec, free_pages: int
                ) -> List[Tuple[str, str, float, float]]:
        """File the per-step gauges and drain SLO transitions collected
        since the last step (the engine forwards them to the recorder).
        `rec` is the step's `StepRecord`."""
        self._stat(_GLOBAL, "queue_depth").observe(rec.queue_depth)
        if rec.kv_total_pages:
            self._stat(_GLOBAL, "kv_free_pages").observe(free_pages)
        covered = rec.prefix_hit_tokens + rec.prefill_tokens
        if covered:
            self._stat(_GLOBAL, "prefix_hit_rate").observe(
                rec.prefix_hit_tokens / covered)
        if self.events is not None:
            g = self.snapshot_scope(_GLOBAL)
            self.events.write({
                "type": "step", "step": step_no, "t": rec.t,
                "queue_depth": rec.queue_depth, "free_pages": free_pages,
                "n_active": rec.n_active, "windows": g})
        out, self._pending = self._pending, []
        return out

    # ------------------------------------------------------- snapshots
    def scopes(self) -> List[str]:
        return sorted({scope for scope, _ in self._stats})

    def snapshot_scope(self, scope: str) -> Dict[str, Dict[str, float]]:
        return {name: st.snapshot()
                for (sc, name), st in sorted(self._stats.items())
                if sc == scope}

    def snapshot(self) -> Dict[str, object]:
        tenants = {sc: self.snapshot_scope(sc) for sc in self.scopes()
                   if sc != _GLOBAL}
        doc: Dict[str, object] = {
            "finishes": self.finishes,
            "global": self.snapshot_scope(_GLOBAL),
            "tenants": tenants,
        }
        if self.slo is not None:
            doc["slo"] = self.slo.status()
        return doc

    def close(self) -> None:
        if self.events is not None:
            self.events.close()


# ------------------------------------------------------------ exporters
_NAME_RE = re.compile(r"[^a-zA-Z0-9_]")


def _prom_name(name: str) -> str:
    out = _NAME_RE.sub("_", name)
    if out and out[0].isdigit():
        out = "_" + out
    return out


def _prom_esc(value: str) -> str:
    return (str(value).replace("\\", "\\\\").replace("\"", "\\\"")
            .replace("\n", "\\n"))


def _prom_num(v: float) -> str:
    v = float(v)
    if math.isnan(v):
        return "NaN"
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    if v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return repr(v)


def prometheus_text(registry: MetricsRegistry,
                    telemetry: Optional[EngineTelemetry] = None,
                    namespace: str = "repro") -> str:
    """Render the typed `MetricsRegistry` (plus, when given, the live
    telemetry windows and SLO status) as Prometheus text exposition
    format: `# HELP`/`# TYPE` headers, counters as `_total`, histograms
    as summaries with `quantile` labels, windows as labeled gauges."""
    from repro.serving.metrics import Counter, Gauge, Histogram

    lines: List[str] = []

    def family(name: str, typ: str, help_text: str) -> None:
        lines.append(f"# HELP {name} {help_text}")
        lines.append(f"# TYPE {name} {typ}")

    for name in registry.names():
        m = registry._metrics[name]
        pname = f"{namespace}_{_prom_name(name)}"
        if isinstance(m, Counter):
            family(f"{pname}_total", "counter", f"counter {name}")
            lines.append(f"{pname}_total {_prom_num(m.value)}")
        elif isinstance(m, Gauge):
            family(pname, "gauge", f"gauge {name}")
            lines.append(f"{pname} {_prom_num(m.value)}")
            family(f"{pname}_max", "gauge", f"high-water mark of {name}")
            lines.append(f"{pname}_max {_prom_num(m.max)}")
        elif isinstance(m, Histogram):
            family(pname, "summary", f"histogram {name}")
            for q, p in (("0.5", 50), ("0.95", 95)):
                lines.append(
                    f"{pname}{{quantile=\"{q}\"}} "
                    f"{_prom_num(m.quantile(p))}")
            lines.append(f"{pname}_sum {_prom_num(m.sum)}")
            lines.append(f"{pname}_count {_prom_num(m.count)}")
    if telemetry is not None:
        wname = f"{namespace}_window"
        family(wname, "gauge",
               "sliding-window quantile (label metric/tenant/quantile)")
        for scope in telemetry.scopes():
            tenant = "" if scope == _GLOBAL else scope
            for name, snap in telemetry.snapshot_scope(scope).items():
                for q, key in (("0.5", "p50"), ("0.95", "p95")):
                    lines.append(
                        f"{wname}{{metric=\"{_prom_esc(name)}\","
                        f"tenant=\"{_prom_esc(tenant)}\","
                        f"quantile=\"{q}\"}} {_prom_num(snap[key])}")
        if telemetry.slo is not None:
            bname = f"{namespace}_slo_breached"
            family(bname, "gauge", "1 while the SLO target is breached")
            for target, st in telemetry.slo.status().items():
                lines.append(
                    f"{bname}{{target=\"{_prom_esc(target)}\"}} "
                    f"{_prom_num(st['breached'])}")
    return "\n".join(lines) + "\n"


class PromEndpoint:
    """Minimal stdlib `/metrics` endpoint: a daemon-threaded
    `ThreadingHTTPServer` rendering `render()` on each scrape.  Never on
    the step path — scrapes read whatever the last step published."""

    def __init__(self, port: int, render):
        import http.server

        endpoint = self

        class Handler(http.server.BaseHTTPRequestHandler):
            def do_GET(self):                          # noqa: N802
                if self.path.rstrip("/") not in ("", "/metrics"):
                    self.send_error(404)
                    return
                body = endpoint.render().encode("utf-8")
                self.send_response(200)
                self.send_header(
                    "Content-Type", "text/plain; version=0.0.4")
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *args):              # quiet
                pass

        self.render = render
        self._srv = http.server.ThreadingHTTPServer(("127.0.0.1", port),
                                                    Handler)
        self.port = self._srv.server_address[1]
        self._thread = threading.Thread(
            target=self._srv.serve_forever, daemon=True,
            name="prom-endpoint")
        self._thread.start()

    def close(self) -> None:
        self._srv.shutdown()
        self._srv.server_close()


# ----------------------------------------------------------- validators
_SAMPLE_RE = re.compile(
    r"^([a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(\{([^{}]*)\})?"
    r" (NaN|[+-]Inf|[-+]?[0-9]*\.?[0-9]+([eE][-+]?[0-9]+)?)"
    r"( [0-9]+)?$")
_LABEL_RE = re.compile(
    r"^[a-zA-Z_][a-zA-Z0-9_]*=\"(?:[^\"\\\n]|\\[\\\"n])*\"$")
_TYPE_RE = re.compile(
    r"^# TYPE ([a-zA-Z_:][a-zA-Z0-9_:]*) "
    r"(counter|gauge|summary|histogram|untyped)$")
_SUFFIXES = ("_total", "_sum", "_count", "_bucket")


def validate_prometheus_text(text: str) -> List[str]:
    """Check Prometheus text-exposition well-formedness: TYPE lines
    declared once with a known type, every sample line syntactically
    valid (name, label syntax, value), and every sample belonging to a
    declared family.  Returns a list of error strings (empty = valid)."""
    errors: List[str] = []
    families: Dict[str, str] = {}
    n_samples = 0
    for ln, raw in enumerate(text.splitlines(), 1):
        line = raw.rstrip()
        if not line:
            continue
        if line.startswith("#"):
            if line.startswith("# TYPE "):
                m = _TYPE_RE.match(line)
                if not m:
                    errors.append(f"line {ln}: malformed TYPE line")
                    continue
                name, typ = m.group(1), m.group(2)
                if name in families:
                    errors.append(
                        f"line {ln}: duplicate TYPE for {name}")
                families[name] = typ
            elif not line.startswith("# HELP "):
                errors.append(f"line {ln}: unknown comment directive")
            continue
        m = _SAMPLE_RE.match(line)
        if not m:
            errors.append(f"line {ln}: malformed sample line: {line!r}")
            continue
        n_samples += 1
        name, labels = m.group(1), m.group(3)
        if labels:
            for pair in labels.split(","):
                if not _LABEL_RE.match(pair):
                    errors.append(
                        f"line {ln}: malformed label {pair!r}")
        base_names = [name] + [name[: -len(sfx)]
                               for sfx in _SUFFIXES
                               if name.endswith(sfx)]
        if not any(b in families for b in base_names):
            errors.append(
                f"line {ln}: sample {name!r} has no TYPE declaration")
    if n_samples == 0:
        errors.append("no sample lines found")
    return errors


# JSONL event schema: type -> required fields
EVENT_SCHEMA: Dict[str, Tuple[str, ...]] = {
    "step": ("step", "t", "queue_depth", "windows"),
    "finish": ("t", "rid", "tenant", "n_generated"),
    "slo_breach": ("t", "target", "burn_short", "burn_long"),
    "slo_recover": ("t", "target", "burn_short", "burn_long"),
    "flight_dump": ("t", "reason", "path"),
    "run_start": ("t",),
    "run_end": ("t",),
}


def validate_events_jsonl(text: str) -> List[str]:
    """Check the `--events-out` JSONL stream: every line strict JSON,
    every event of a known type with its required fields present."""
    errors: List[str] = []
    n = 0
    for ln, raw in enumerate(text.splitlines(), 1):
        line = raw.strip()
        if not line:
            continue
        n += 1
        try:
            obj = json.loads(line)
        except ValueError as e:
            errors.append(f"line {ln}: not valid JSON ({e})")
            continue
        if not isinstance(obj, dict):
            errors.append(f"line {ln}: event is not an object")
            continue
        typ = obj.get("type")
        if typ not in EVENT_SCHEMA:
            errors.append(f"line {ln}: unknown event type {typ!r}")
            continue
        missing = [k for k in EVENT_SCHEMA[typ] if k not in obj]
        if missing:
            errors.append(
                f"line {ln}: {typ} event missing fields {missing}")
    if n == 0:
        errors.append("no event lines found")
    return errors


def _main(argv=None) -> int:
    """CI validator: `python -m repro.serving.telemetry --prom f
    --events g` exits non-zero listing every format error found."""
    import argparse

    ap = argparse.ArgumentParser(
        description="validate telemetry export artifacts")
    ap.add_argument("--prom", action="append", default=[],
                    help="Prometheus text-exposition file(s) to validate")
    ap.add_argument("--events", action="append", default=[],
                    help="JSONL event-stream file(s) to validate")
    args = ap.parse_args(argv)
    if not args.prom and not args.events:
        ap.error("nothing to validate: pass --prom and/or --events")
    failed = False
    for path in args.prom:
        with open(path, encoding="utf-8") as f:
            errs = validate_prometheus_text(f.read())
        for e in errs:
            print(f"{path}: {e}")
        failed = failed or bool(errs)
        if not errs:
            print(f"{path}: valid Prometheus text exposition")
    for path in args.events:
        with open(path, encoding="utf-8") as f:
            errs = validate_events_jsonl(f.read())
        for e in errs:
            print(f"{path}: {e}")
        failed = failed or bool(errs)
        if not errs:
            print(f"{path}: valid JSONL event stream")
    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(_main())
