"""Continuous-batching serving engine with an ARAS-style multi-model
weight arena.

One engine serves many concurrent requests across one or more tenant models
on a fixed device budget:

  * each tenant owns a KV arena — slot-managed (`KVArena`) or paged
    (`PagedKVArena`, `kv_layout="paged"`): block-granular pages with
    refcounted prefix sharing and COW, admission gated on free *pages*
    instead of free whole-sequence slots, and no per-request `max_seq`
    ceiling below the pool itself (requests join/leave the decode batch
    between steps — no head-of-line blocking either way);
  * every step admits up to `max_prefill_per_step` queued requests (their
    prefill runs immediately and yields their first token), then decodes
    one token for every active slot of the scheduled tenants in a single
    batched, per-slot-position decode step (`launch.steps.cached_serve_step`);
  * with `prefill_chunk > 0` a prompt's prefill is instead split into
    chunk-sized pieces spread across steps under the scheduler's
    prefill-token budget (ARAS §V: slice oversized work into
    scheduler-sized pieces and overlap it with ongoing compute), so long
    prompts no longer stall concurrent decodes; tail chunks are padded to
    a geometric bucket ladder so distinct prefill jit traces stay bounded
    by the ladder size instead of growing with every new prompt length.
    Chunked and monolithic prefill are token-for-token identical on both
    KV layouts (tests/test_chunked_prefill.py); mLSTM tenants are rejected
    at construction (chunkwise-parallel mLSTM is not chunking-invariant —
    the engine refuses rather than serving silently divergent tokens);
  * paged tenants with `prefix_cache=True` keep a radix-tree prefix cache
    (`serving/prefix_cache.py`): finished requests donate their
    prompt+generated pages into the tree (LRU-evicted on demand) and a
    later request over the shared prefix *skips* straight to the exact
    covered token (capped at len-1 so the final chunk still produces real
    logits) — the staging carry-in is seeded from the pool at the hit
    boundary, so warm prefill is token-for-token identical to cold while
    recomputing none of the covered tokens (ARAS §V-C write-avoidance
    applied to the KV plane);
  * a `WeightResidencyManager` decides which tenant's quantized layer codes
    occupy the device weight slots, delta-installing on tenant switches and
    reporting wire bytes saved by §V-C cross-tenant reuse;
  * with `install_ticks_per_step > 0` those installs run through an
    `InstallPipeline` under a per-step tick budget, and `overlap_installs`
    starts the next turn holder's installs while the current one still
    decodes (ARAS §IV: hide weight writes under compute) — steps a tenant
    spends blocked on installs are counted as `install_stall_steps`, bytes
    pumped while tokens flowed as `overlap_hidden_bytes`;
  * `EngineMetrics` aggregates p50/p95 latency, tokens/s, queue depth,
    worst inter-token gaps, and install traffic.

For dense GQA tenants decode outputs are token-for-token identical to the
sequential prefill + `make_serve_step` loop (tests/test_serving.py asserts
this).  On MoE/MLA architectures batch-composition float numerics can flip
argmax near-ties, so greedy decode there may depend on who shares the
batch — the vector-position path itself is exact (batch-1 matches the
scalar oracle); the reassociation is inherent to batched matmuls.
"""
from __future__ import annotations

import dataclasses
import logging
import math
import time
from typing import Any, Callable, Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.steps import (cached_chunk_prefill_step,
                                cached_fused_paged_serve_step,
                                cached_paged_serve_step, cached_prefill_step,
                                cached_sample_tokens, cached_serve_step,
                                cached_stage_install, cached_stage_quantize,
                                prefill_cache_info)
from repro.nn.config import ModelConfig
from repro.nn.model import init_cache
from repro.nn.transformer import layer_kind
from repro.serving.bucketing import (PrefillProgress, bucket_for,
                                     bucket_ladder)
from repro.ft import Watchdog
from repro.serving.faults import FaultModel
from repro.serving.kv_arena import KVArena
from repro.serving.metrics import EngineMetrics, StepRecord
from repro.serving.recorder import FlightRecorder
from repro.serving.telemetry import EngineTelemetry, TelemetryConfig
from repro.serving.paging import PagedKVArena
from repro.serving.request import Request, RequestStatus
from repro.serving.residency import InstallPipeline, WeightResidencyManager
from repro.serving.sampling import request_key, sample_token
from repro.serving.scheduler import SchedulerConfig, StepScheduler
from repro.serving.tracing import NULL_TRACER, Tracer
from repro.serving.wear import WearMap
from repro.sim.energy import EnergyModel
from repro.streaming.plan import InstallCostModel

_log = logging.getLogger(__name__)


@dataclasses.dataclass
class EngineModel:
    """One tenant: a named (params, config) pair plus its KV budget.

    kv_layout picks the arena: "slot" binds each request to a whole
    `max_seq` sequence slot; "paged" stores KV in `page_size`-token pages
    (`kv_slots` becomes the decode-batch row count and the per-request
    ceiling is the whole pool — n_pages · page_size tokens)."""
    name: str
    params: Any
    cfg: ModelConfig
    kv_slots: int = 4
    max_seq: int = 64
    kv_layout: str = "slot"          # "slot" | "paged"
    page_size: int = 8
    n_pages: int = 0                 # 0 → kv_slots · ceil(max_seq/page_size)
    # Radix-tree prefix cache (paged layout only): finished requests donate
    # their prompt+generated pages into a retained, LRU-evicted tree, and
    # later requests sharing the prefix skip whole prefill chunks over the
    # resident pages.  prefix_cache_pages caps the retained pages
    # (0 = bounded only by on-demand eviction).
    prefix_cache: bool = False
    prefix_cache_pages: int = 0
    # Decode attention backend: "xla" gathers the full page-table width
    # per step; "pallas" routes paged GQA decode through the
    # kernels/paged_attention kernel, which walks only each row's live
    # pages (interpret mode off-TPU — see ServingEngine kernel_interpret).
    kernel_backend: str = "xla"      # "xla" | "pallas"

    def __post_init__(self):
        if self.kv_layout not in ("slot", "paged"):
            raise ValueError(f"unknown kv_layout {self.kv_layout!r} "
                             "(expected 'slot' or 'paged')")
        if self.prefix_cache and self.kv_layout != "paged":
            raise ValueError(
                f"{self.name}: prefix_cache needs kv_layout='paged' "
                "(slot arenas have no pages to retain)")
        if self.kernel_backend not in ("xla", "pallas"):
            raise ValueError(f"unknown kernel_backend "
                             f"{self.kernel_backend!r} "
                             "(expected 'xla' or 'pallas')")
        if self.kernel_backend == "pallas" and self.kv_layout != "paged":
            raise ValueError(
                f"{self.name}: kernel_backend='pallas' needs "
                "kv_layout='paged' (the kernel reads a page pool)")


class ServingEngine:
    def __init__(self, models: Sequence[EngineModel], *,
                 sched: SchedulerConfig = SchedulerConfig(),
                 weight_arena_slots: Optional[int] = None,
                 reuse: bool = True,
                 clock: Callable[[], float] = time.perf_counter,
                 install_ticks_per_step: int = 0,
                 overlap_installs: bool = False,
                 install_cost: Optional[InstallCostModel] = None,
                 prefill_chunk: int = 0,
                 bucket_growth: float = 2.0,
                 bucket_min: int = 8,
                 staging_growth: float = 2.0,
                 tracer: Optional[Tracer] = None,
                 energy_model: Optional[EnergyModel] = None,
                 wear_aware: float = 0.0,
                 fault_rate: float = 0.0,
                 fault_seed: int = 0,
                 kernel_backend: Optional[str] = None,
                 kernel_interpret: Optional[bool] = None,
                 fuse_sampling: bool = True,
                 telemetry: Optional[TelemetryConfig] = None,
                 recorder: Optional[FlightRecorder] = None,
                 stall_timeout_s: float = 0.0):
        if not models:
            raise ValueError("need at least one tenant model")
        names = [m.name for m in models]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate tenant names: {names}")
        for m in models:
            if m.cfg.is_encoder or m.cfg.input_mode != "tokens":
                raise ValueError(f"{m.name}: engine serves causal token LMs")
            if prefill_chunk and any(
                    layer_kind(m.cfg, i) == "mlstm"
                    for i in range(m.cfg.n_layers)):
                # the chunkwise-parallel mLSTM groups floats per chunk
                # boundary, so chunked prefill diverges token-for-token
                # from monolithic — refuse loudly instead of serving
                # silently different tokens (per-token sLSTM/mamba scans
                # are chunking-exact and stay allowed)
                raise ValueError(
                    f"{m.name}: prefill_chunk > 0 is not supported for "
                    "mLSTM tenants — chunkwise-parallel mLSTM prefill is "
                    "not chunking-invariant (float regrouping at chunk "
                    "boundaries changes tokens); serve this tenant with "
                    "prefill_chunk=0")
        self.models: Dict[str, EngineModel] = {m.name: m for m in models}
        self.arenas: Dict[str, Any] = {}
        self._decode: Dict[str, Callable] = {}
        self._decode_fused: Dict[str, Optional[Callable]] = {}
        self._backend: Dict[str, str] = {}
        # kernel_backend (engine-level) overrides every paged tenant's
        # EngineModel.kernel_backend; kernel_interpret=None resolves to
        # interpret mode off-TPU (CI equivalence runs force True).
        # fuse_sampling keeps sampling inside the jitted paged decode step
        # so logits never leave device; False splits it back out (the
        # batched sampler still makes it one device call per step).
        if kernel_backend is not None and kernel_backend not in (
                "xla", "pallas"):
            raise ValueError(f"unknown kernel_backend {kernel_backend!r} "
                             "(expected 'xla' or 'pallas')")
        self._interpret = (jax.default_backend() != "tpu"
                           if kernel_interpret is None
                           else bool(kernel_interpret))
        self._fuse = bool(fuse_sampling)
        for m in models:
            if m.kv_layout == "paged":
                n_pages = m.n_pages or m.kv_slots * -(-m.max_seq
                                                      // m.page_size)
                self.arenas[m.name] = PagedKVArena(
                    m.cfg, m.kv_slots, n_pages, m.page_size,
                    prefix_cache=m.prefix_cache,
                    prefix_cache_pages=m.prefix_cache_pages)
                backend = (kernel_backend if kernel_backend is not None
                           else m.kernel_backend)
                interp = self._interpret if backend == "pallas" else False
                self._backend[m.name] = backend
                self._decode[m.name] = cached_paged_serve_step(
                    m.cfg, backend, interp)
                self._decode_fused[m.name] = (
                    cached_fused_paged_serve_step(m.cfg, backend, interp)
                    if self._fuse else None)
            else:
                self.arenas[m.name] = KVArena(m.cfg, m.kv_slots, m.max_seq)
                self._backend[m.name] = "xla"
                self._decode[m.name] = cached_serve_step(m.cfg)
                self._decode_fused[m.name] = None

        self.residency = WeightResidencyManager(
            {m.name: (m.params, m.cfg) for m in models},
            weight_arena_slots if weight_arena_slots is not None
            else sum(m.cfg.n_layers for m in models),
            reuse=reuse)

        # Structured tracing: NULL_TRACER (no-op, allocation-free) when
        # disabled; a shared Tracer instance otherwise, injected into the
        # scheduler, install pipeline, and paged arenas so resource
        # decisions (admission verdicts, evictions, victim picks, COW)
        # land in the same trace as the engine's component spans.
        self.tracer: Any = tracer if tracer is not None else NULL_TRACER
        self.residency.tracer = self.tracer
        for arena in self.arenas.values():
            if isinstance(arena, PagedKVArena):
                arena.tracer = self.tracer
                arena.allocator.tracer = self.tracer

        self.scheduler = StepScheduler(sched)
        self.scheduler.tracer = self.tracer
        self.metrics = EngineMetrics()

        # Live telemetry plane (all observation-only — no scheduling
        # decision ever reads a telemetry value, so enabling any of it is
        # token-identical to defaults-off; tests + bench part 10 assert
        # this).  telemetry: streaming windowed percentiles + SLO burn
        # tracking, fed per step / per finished request.  recorder: a
        # bounded flight ring dumped on retirement / SLO breach / stall /
        # SIGUSR1 / crash.  stall_timeout_s > 0 arms the ft.Watchdog
        # around every step as a serving heartbeat.
        self.telemetry: Optional[EngineTelemetry] = (
            EngineTelemetry(telemetry, tracer=self.tracer)
            if telemetry is not None else None)
        self.recorder = recorder
        if recorder is not None:
            recorder.tracer = self.tracer
        self._retired_seen = 0          # retirement-delta dump trigger
        self._stall_timeout_s = float(stall_timeout_s)
        self.watchdog: Optional[Watchdog] = (
            Watchdog(self._stall_timeout_s, on_timeout=self._on_stall)
            if self._stall_timeout_s > 0 else None)

        # Wear telemetry: one WearPlane per physical write plane — the
        # weight arena's slots and each paged tenant's KV page pool —
        # injected into the leaf modules like the tracer, and priced in
        # joules through the energy model by `_wear_stats()`.
        self.energy_model = energy_model or EnergyModel()
        self.wear = WearMap()
        self.residency.wear = self.wear.add_plane(
            "weight", self.residency.arena_slots)
        self.residency.flip_hist = self.metrics.registry.histogram(
            "install_cell_flips")
        for name, arena in self.arenas.items():
            if isinstance(arena, PagedKVArena):
                # first=1: device page 0 is the scratch page and never
                # takes an accounted write
                arena.wear = self.wear.add_plane(
                    f"kv:{name}", arena.allocator.n_pages, first=1)

        # Hamun policy half: act on the wear the planes record.
        # wear_aware > 0 (True coerces to 1.0) blends the install victim
        # picker's delta cost with per-slot write pressure and switches
        # page allocation to coldest-page-first; 0/False keeps today's
        # FIFO + pure min-delta behavior bit-for-bit.  fault_rate > 0
        # arms seeded stuck-at faults over both planes: a write that
        # fails verify retires its slot/page for good and the engine
        # remaps — faulted runs stay token-equivalent to fault-free.
        self._wear_weight = float(wear_aware)
        if self._wear_weight < 0:
            raise ValueError("wear_aware must be >= 0 (a blend weight)")
        if self._wear_weight > 0:
            self.residency.wear_weight = self._wear_weight
            for arena in self.arenas.values():
                if isinstance(arena, PagedKVArena):
                    arena.allocator.enable_wear_aware(arena.wear)
        self.faults: Optional[FaultModel] = (
            FaultModel(fault_rate, fault_seed) if fault_rate else None)
        if self.faults is not None:
            self.residency.faults = self.faults
            for name, arena in self.arenas.items():
                if isinstance(arena, PagedKVArena):
                    arena.allocator.faults = self.faults
                    arena.allocator.fault_plane = f"kv:{name}"
        self.requests: Dict[int, Request] = {}
        # per-request raw uint32 PRNG roots, host-cached so building the
        # batched sampler inputs costs no device syncs on the decode path
        # (greedy requests get a zero key — the sampled lane is discarded)
        self._keys: Dict[int, np.ndarray] = {}
        self._clock = clock
        self._next_rid = 0
        self._step_no = 0
        self._wall_s = 0.0   # cumulative time spent inside step()

        # Install pipelining: install_ticks_per_step > 0 budgets weight-arena
        # installs (one tick = install_cost.bytes_per_tick wire bytes per
        # step, the DMA a step can hide); 0 keeps the legacy instant
        # ensure() at the turn boundary.  overlap_installs additionally
        # prefetches the next turn holder's layers while the current one
        # still decodes — free slots mid-turn, the holder's own slots behind
        # the execution front on its final slice step.
        self.install_cost = install_cost or InstallCostModel()
        self._ticks_per_step = int(install_ticks_per_step)
        self._overlap = bool(overlap_installs)
        if self._overlap and self._ticks_per_step <= 0:
            raise ValueError("overlap_installs needs install_ticks_per_step "
                             "> 0 (unbudgeted installs have nothing to hide)")
        self.pipeline: Optional[InstallPipeline] = (
            InstallPipeline(self.residency, self.install_cost)
            if self._ticks_per_step > 0 else None)
        if self.pipeline is not None:
            self.pipeline.tracer = self.tracer

        # Chunked prefill: prefill_chunk > 0 splits every prompt into
        # chunk-sized pieces run across steps under the scheduler's
        # prefill-token budget (queued → PREFILLING(k chunks done) →
        # RUNNING), so a long prompt never freezes concurrent decodes.
        # Each chunk runs against a fixed-length staging cache and the tail
        # chunk is padded up to a geometric bucket ladder rung, bounding
        # distinct prefill jit traces at the ladder size (bucket_growth <=
        # 1 disables the padding: traces then grow with every new tail
        # length).  0 keeps the legacy monolithic per-prompt-length
        # prefill.
        self._chunk = int(prefill_chunk)
        if self._chunk < 0:
            raise ValueError("prefill_chunk must be >= 0 (0 = monolithic)")
        self._ladder: Optional[list] = None
        if self._chunk > 0 and bucket_growth > 1.0:
            self._ladder = bucket_ladder(min(bucket_min, self._chunk),
                                         self._chunk, bucket_growth)
        self._prefills: Dict[int, PrefillProgress] = {}
        self._staging_ladders: Dict[str, list] = {}
        if self._chunk > 0:
            for m in models:
                cap = (self.arenas[m.name].max_tokens
                       if m.kv_layout == "paged" else m.max_seq)
                # Staging-length ladder: each in-flight prefill stages into
                # the smallest geometric rung covering its prompt instead
                # of one max-capacity buffer per tenant, so short prompts
                # no longer hold worst-case memory while they chunk.
                # Rungs are multiples of the chunk size (bucket-padded
                # tails always fit; chunk starts stay aligned) and, for
                # paged tenants, of the page size too (the install's
                # per-page dynamic slices stay in bounds).  Distinct jit
                # traces grow ×rungs — staging_growth <= 1 collapses the
                # ladder back to the single max-capacity length.
                quantum = self._chunk
                if m.kv_layout == "paged":
                    quantum = math.lcm(self._chunk, m.page_size)
                top = -(-cap // quantum) * quantum
                if staging_growth > 1.0 and top > quantum:
                    rungs = sorted({-(-r // quantum) * quantum for r in
                                    bucket_ladder(quantum, top,
                                                  staging_growth)})
                else:
                    rungs = [top]
                self._staging_ladders[m.name] = rungs

    def staging_len_for(self, name: str, n_tokens: int) -> int:
        """The staging-ladder rung an `n_tokens`-token prefill stages into
        (smallest rung covering it; rungs are chunk multiples, so the
        bucket-padded tail chunk always fits)."""
        return bucket_for(n_tokens, self._staging_ladders[name])

    # ------------------------------------------------------------ intake
    def _prefill_fn(self, name: str, prompt_len: int):
        """Legacy monolithic prefill (prefill_chunk == 0): slot tenants
        prefill into a fixed max_seq cache; paged tenants into a
        page-multiple bucket so installs write whole pages.  NB the prompt
        itself is not padded, so jit traces once per prompt length — the
        chunked path (`_pump_prefills`) is what bounds compile counts."""
        m = self.models[name]
        arena = self.arenas[name]
        if isinstance(arena, PagedKVArena):
            bucket = arena.blocks_for(prompt_len) * arena.page_size
            return cached_prefill_step(m.cfg, bucket)
        return cached_prefill_step(m.cfg, m.max_seq)

    def _capacity(self, model: str) -> int:
        """Per-request token ceiling: max_seq for slot arenas, the whole
        page pool for paged ones."""
        arena = self.arenas[model]
        if isinstance(arena, PagedKVArena):
            return arena.max_tokens
        return self.models[model].max_seq

    def submit(self, model: str, prompt: Sequence[int],
               max_new_tokens: int = 16,
               arrival_t: Optional[float] = None,
               temperature: float = 0.0, top_k: int = 0,
               seed: Optional[int] = None) -> Request:
        if model not in self.models:
            raise KeyError(f"unknown tenant {model!r}")
        if max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1: the prefill "
                             "itself produces the first token")
        req = Request(rid=self._next_rid, model=model,
                      prompt=tuple(int(t) for t in prompt),
                      max_new_tokens=max_new_tokens,
                      temperature=temperature, top_k=top_k, seed=seed,
                      arrival_t=self._clock() if arrival_t is None
                      else arrival_t)
        self._next_rid += 1
        self.requests[req.rid] = req
        if req.prompt_len + max_new_tokens > self._capacity(model):
            req.status = RequestStatus.REJECTED
            self.scheduler.rejected += 1
            self.tracer.request_phase(req.rid, "rejected", model=model)
            return req
        self.scheduler.submit(req)
        self.tracer.request_phase(req.rid, "queued", model=model)
        return req

    def preempt(self, rid: int) -> None:
        """Evict a running request's KV slot and requeue it; its generated
        prefix is re-prefilled on readmission, so no tokens are lost.  A
        mid-prefill (chunked) request keeps its staging and resumes at the
        last completed chunk instead."""
        req = self.requests[rid]
        if req.status is RequestStatus.PREFILLING:
            self._preempt_prefill(req)
            return
        if req.status is not RequestStatus.RUNNING:
            return
        self.arenas[req.model].evict(req.slot)
        req.slot = None
        req.preemptions += 1
        self.metrics.record_preemption()
        self.scheduler.requeue(req)
        self.tracer.request_phase(req.rid, "preempted")
        self._note_requeue(req, "decode preemption")

    def _note_requeue(self, req: Request, reason: str) -> None:
        """One-line per-request timeline summary on preemption or
        pool-exhaustion requeue (spans so far + pages held + chunks
        completed), so exhaustion livelock reports are debuggable from
        output alone.  No-op when tracing is disabled."""
        if not self.tracer.enabled:
            return
        arena = self.arenas[req.model]
        pages = 0
        if isinstance(arena, PagedKVArena):
            pages = len(arena.allocator.tables.get(req.rid, ()))
        st = self._prefills.get(req.rid)
        chunks = (-(-st.done // self._chunk)
                  if st is not None and self._chunk > 0 else 0)
        self.tracer.instant("requeue", rid=req.rid, reason=reason,
                            pages_held=pages, chunks_done=chunks)
        _log.info(
            "request %d (%s) requeued [%s]: timeline[%s] pages_held=%d "
            "chunks_done=%d generated=%d preemptions=%d",
            req.rid, req.model, reason,
            self.tracer.request_timeline(req.rid), pages, chunks,
            len(req.generated), req.preemptions)

    # ------------------------------------------------------------- step
    def _pick_token(self, req: Request, logits_row) -> int:
        """Next token for `req` from its row of logits: greedy argmax by
        default, seeded temperature/top-k sampling otherwise.  The sample
        index is the request's generated count, so re-prefills after
        preemption resample the exact same continuation."""
        vocab = self.models[req.model].cfg.vocab
        if req.temperature <= 0.0:
            return int(jnp.argmax(logits_row[:vocab]))
        return sample_token(logits_row, vocab, temperature=req.temperature,
                            top_k=req.top_k,
                            key=request_key(req.seed, req.rid),
                            step=len(req.generated))

    def _admit(self, allowed) -> tuple:
        """Admit queued requests of the scheduled (weight-resident) tenants
        only — a prefill never computes on a tenant whose layer codes are
        not installed in the weight arena.  Slot tenants gate on a free
        slot; paged tenants on a free decode row AND enough free pages for
        the request's non-shared blocks."""
        free = {name: (arena.n_free if name in allowed else 0)
                for name, arena in self.arenas.items()}
        n_active = sum(len(a.active_slots()) for a in self.arenas.values())

        def can_admit(req: Request) -> bool:
            arena = self.arenas[req.model]
            if isinstance(arena, PagedKVArena):
                return arena.can_admit(req.serving_prompt())
            return True

        admits = self.scheduler.next_admits(free, n_active, can_admit)
        n_admitted = 0
        n_tokens = 0
        for req in admits:
            m = self.models[req.model]
            arena = self.arenas[req.model]
            prompt = req.serving_prompt()
            if isinstance(arena, PagedKVArena):
                slot = arena.alloc(req.rid, prompt)
                if slot is None:
                    # an earlier admit this step consumed the pages the
                    # pre-pop check saw; head-of-queue retry next step.
                    # The request never ran, so it stays QUEUED (requeue's
                    # PREEMPTED tag is for evicted progress).
                    self.scheduler.requeue(req)
                    req.status = RequestStatus.QUEUED
                    self._note_requeue(req, "admission page race")
                    continue
            else:
                slot = arena.alloc(req.rid)
            self.tracer.request_phase(req.rid, "prefilling")
            if req.prefill_start_t is None:
                # re-prefills after preemption keep the FIRST admission
                # time: the ttft split describes the road to the first
                # token, which a later re-prefill is not on
                req.prefill_start_t = self._clock()
            tokens = jnp.asarray(prompt, jnp.int32)[None]
            logits, caches = self._prefill_fn(req.model, len(prompt))(
                m.params, {"tokens": tokens})
            tok = self._pick_token(req, logits[0])
            n_tokens += len(prompt)
            if isinstance(arena, PagedKVArena):
                arena.install(slot, caches, tok, prompt)
            else:
                arena.install(slot, caches, tok, len(prompt))
            req.slot = slot
            req.status = RequestStatus.RUNNING
            self.tracer.request_phase(req.rid, "running")
            req.generated.append(tok)
            req.note_token(self._clock())
            if req.first_token_t is None:
                req.first_token_t = self._clock()
            if req.done:
                self._finish(req)
            n_admitted += 1
        return n_admitted, n_tokens

    def _sample_key(self, req: Request) -> np.ndarray:
        """Host-cached raw uint32 PRNG root for `req` (zeros for greedy —
        that lane's sampled value is discarded).  One device sync per
        request lifetime instead of one per decode step."""
        k = self._keys.get(req.rid)
        if k is None:
            k = (np.zeros(2, np.uint32) if req.temperature <= 0.0
                 else np.asarray(request_key(req.seed, req.rid),
                                 dtype=np.uint32))
            self._keys[req.rid] = k
        return k

    def _sample_inputs(self, arena) -> tuple:
        """Per-row sampler inputs over a tenant's whole decode batch.
        Inactive rows get temperature 0 / zero keys; their lanes compute a
        greedy argmax of scratch logits that nobody reads."""
        n_rows = len(arena.owner)
        temps = np.zeros(n_rows, np.float32)
        tks = np.zeros(n_rows, np.int32)
        keys = np.zeros((n_rows, 2), np.uint32)
        steps = np.zeros(n_rows, np.int32)
        for slot in arena.active_slots():
            req = self.requests[arena.owner_of(slot)]
            temps[slot] = req.temperature
            tks[slot] = req.top_k
            keys[slot] = self._sample_key(req)
            steps[slot] = len(req.generated)
        return (jnp.asarray(temps), jnp.asarray(tks), jnp.asarray(keys),
                jnp.asarray(steps))

    def _finish(self, req: Request) -> None:
        arena = self.arenas[req.model]
        self._keys.pop(req.rid, None)
        if isinstance(arena, PagedKVArena):
            # with the prefix cache on, the finished request donates its
            # prompt+generated pages into the radix tree instead of
            # freeing them — the next request over the shared prefix
            # skips the covered prefill chunks entirely
            arena.evict(req.slot, donate=req.prompt + tuple(req.generated))
        else:
            arena.evict(req.slot)
        req.slot = None
        req.status = RequestStatus.FINISHED
        req.finish_t = self._clock()
        self.tracer.request_phase(req.rid, "finished",
                                  n_generated=len(req.generated))
        self.metrics.record_finish(req)
        if self.telemetry is not None:
            self.telemetry.on_finish(req)

    # ------------------------------------------------- chunked prefill
    def _admit_staged(self, allowed) -> int:
        """Chunked-prefill admission: claim a slot/row and a staging cache,
        but run no model yet — chunks run under _pump_prefills' token
        budget.  A preempted mid-prefill request re-enters here with its
        PrefillProgress intact and resumes at the last completed chunk.

        Prefix-cache hit path: when the tenant's radix tree covers a
        block-aligned prefix of the prompt, every chunk fully inside the
        cover is skipped — the staging carry-in is seeded straight from
        the cached pages up to the hit boundary and `done` jumps there, so
        the skipped tokens are never recomputed and cost no prefill
        budget.  The skip is floored to a chunk boundary (later chunks
        keep their cold-path traces) and capped at prompt_len - 1 (the
        final chunk must run: its logits are the first token).  Returns
        the prompt tokens served from cache this step."""
        free = {name: (arena.n_free if name in allowed else 0)
                for name, arena in self.arenas.items()}
        n_active = sum(len(a.active_slots()) for a in self.arenas.values())

        def can_admit(req: Request) -> bool:
            arena = self.arenas[req.model]
            if isinstance(arena, PagedKVArena):
                return arena.can_admit(req.serving_prompt())
            return True

        hit_tokens = 0
        for req in self.scheduler.next_admits(free, n_active, can_admit):
            arena = self.arenas[req.model]
            prompt = req.serving_prompt()
            if isinstance(arena, PagedKVArena):
                row = arena.stage(req.rid, prompt)
                if row is None:
                    # an earlier admit this step consumed the row the
                    # pre-pop check saw; head-of-queue retry next step
                    self.scheduler.requeue(req)
                    req.status = RequestStatus.QUEUED
                    self._note_requeue(req, "staging row race")
                    continue
            else:
                row = arena.alloc(req.rid)
            req.slot = row
            req.status = RequestStatus.PREFILLING
            self.tracer.request_phase(req.rid, "prefilling")
            st = self._prefills.get(req.rid)
            if st is None or st.tokens != prompt:
                # fresh prefill (or a decode-preempted request whose prompt
                # grew by its generated tokens): new staging from zeros,
                # sized to the smallest ladder rung covering the prompt
                m = self.models[req.model]
                slen = self.staging_len_for(req.model, len(prompt))
                st = PrefillProgress(
                    tokens=prompt, staging_len=slen,
                    caches=init_cache(m.cfg, 1, slen, staging=True))
                self._prefills[req.rid] = st
            if isinstance(arena, PagedKVArena) and arena.skip_ok:
                covered = arena.covered_tokens(req.rid, len(prompt))
                # skip to the exact covered token (capped at len-1 so the
                # final chunk produces real logits) — a sub-chunk resume
                # start is fine: the chunk step slices from a dynamic
                # start, and per-query attention is position-exact
                skip = min(covered, len(prompt) - 1)
                if skip > st.done:
                    # covers a resumed prefill too: pages donated since the
                    # preemption extend the hit past the completed chunks
                    st.caches = arena.load_prefix(req.rid, st.caches, skip)
                    hit_tokens += skip - st.done
                    st.skipped += skip - st.done
                    st.done = skip
        return hit_tokens

    def _run_chunk(self, req: Request, st: PrefillProgress) -> int:
        """Advance one chunk; returns valid tokens processed, or -1 when a
        paged tenant could not reserve the chunk's pages (the prefill is
        preempted, staging intact, and resumes once pages free up)."""
        m = self.models[req.model]
        arena = self.arenas[req.model]
        start = st.done
        remaining = len(st.tokens) - start
        size = min(self._chunk, remaining)
        if isinstance(arena, PagedKVArena):
            if not arena.grow(req.rid, arena.blocks_for(start + size)):
                self._preempt_prefill(req)
                return -1
        if remaining > self._chunk:
            padded = self._chunk
        elif self._ladder is not None:
            padded = bucket_for(remaining, self._ladder)
        else:
            padded = remaining
        # a sub-chunk prefix-cache skip can start the tail chunk at an
        # unaligned position; clamp the padding so the staging write never
        # spills past the cache (dynamic_update_slice would clamp the
        # start and corrupt covered positions).  Aligned starts always
        # satisfy start + padded <= staging_len, so this is a no-op there.
        padded = min(padded, st.staging_len - start)
        buf = np.zeros((1, padded), np.int32)
        buf[0, :size] = st.tokens[start:start + size]
        if st.start_t is None:
            st.start_t = self._clock()
            if req.prefill_start_t is None:
                # a decode-preempted request re-prefilling its generated
                # prefix keeps its original first-chunk stamp: the ttft
                # split describes the road to the FIRST token only
                req.prefill_start_t = st.start_t
        step_fn = cached_chunk_prefill_step(
            m.cfg, padded, st.staging_len)
        logits, st.caches = step_fn(m.params, jnp.asarray(buf), st.caches,
                                    jnp.int32(start), jnp.int32(size))
        st.done += size
        self.tracer.instant("prefill_chunk", rid=req.rid, start=start,
                            tokens=size)
        if st.finished:
            st.logits = logits
        return size

    def _finish_prefill(self, req: Request, st: PrefillProgress) -> None:
        """Last chunk done: install the staging cache into the arena (ring
        + slice + int8 quantization for slot rows; per-page scatter of the
        non-shared blocks for paged rows), emit the first token (TTFT), and
        hand the request to the decode batch."""
        m = self.models[req.model]
        arena = self.arenas[req.model]
        tok = self._pick_token(req, st.logits[0])
        n_tok = len(st.tokens)
        staging_len = st.staging_len
        if isinstance(arena, PagedKVArena):
            source = st.caches
            if m.cfg.kv_cache_dtype == "int8":
                source = cached_stage_quantize(m.cfg, staging_len)(
                    source, jnp.int32(n_tok))
            arena.finish_stage(req.slot, source, tok, st.tokens)
        else:
            row = cached_stage_install(m.cfg, staging_len, m.max_seq)(
                st.caches, jnp.int32(n_tok))
            arena.install(req.slot, row, tok, n_tok)
        del self._prefills[req.rid]
        req.status = RequestStatus.RUNNING
        self.tracer.request_phase(req.rid, "running",
                                  tokens_skipped=st.skipped)
        req.generated.append(tok)
        req.note_token(self._clock())
        if req.first_token_t is None:
            req.first_token_t = self._clock()
        if req.done:
            self._finish(req)

    def _preempt_prefill(self, req: Request) -> None:
        """Mid-prefill preemption: release the slot/row and any reserved
        pages, keep the PrefillProgress (staging is per-request memory, not
        pool), and requeue at the head — readmission resumes at the last
        completed chunk."""
        self.arenas[req.model].evict(req.slot)
        req.slot = None
        req.preemptions += 1
        self.metrics.record_preemption()
        self.scheduler.requeue(req)
        self.tracer.request_phase(req.rid, "preempted")
        self._note_requeue(req, "prefill page exhaustion")

    def _pump_prefills(self, allowed) -> tuple:
        """One step of chunked-prefill work: admit queued requests into
        staging, then advance in-flight prefills (FIFO by rid) under the
        scheduler's prefill-token budget.  Returns (prefills completed,
        prompt tokens computed, chunks run, cache-hit tokens skipped) —
        hit tokens never touch the budget: a cache hit is free work."""
        hit_tokens = self._admit_staged(allowed)
        budget = self.scheduler.prefill_token_budget()
        n_done = tokens = chunks = 0
        for rid in sorted(self._prefills):
            req = self.requests[rid]
            if (req.status is not RequestStatus.PREFILLING
                    or req.model not in allowed):
                continue
            while not self._prefills[rid].finished and tokens < budget:
                n = self._run_chunk(req, self._prefills[rid])
                if n < 0:
                    break
                tokens += n
                chunks += 1
            if (req.status is RequestStatus.PREFILLING
                    and self._prefills[rid].finished):
                self._finish_prefill(req, self._prefills[rid])
                n_done += 1
        return n_done, tokens, chunks, hit_tokens

    def _can_progress(self, name: str) -> bool:
        """A tenant belongs in the turn rotation only if scheduling it can
        generate tokens this step: it has active slots to decode, or a
        queued request it could actually admit (free KV slot AND global
        budget headroom).  Without this filter the time-slice can land on a
        budget-blocked queued-only tenant and livelock the engine."""
        arena = self.arenas[name]
        if arena.active_slots():
            # includes PREFILLING rows: the tenant must be scheduled (and
            # weight-resident) for its chunks to advance
            return True
        if arena.n_free == 0:
            return False
        budget = self.scheduler.cfg.max_active
        if budget is not None:
            n_active = sum(len(a.active_slots())
                           for a in self.arenas.values())
            if n_active >= budget:
                return False
        if isinstance(arena, PagedKVArena):
            # a queued-only paged tenant needs pages, not just a row
            return any(r.model == name and arena.can_admit(r.serving_prompt())
                       for r in self.scheduler.queue)
        return any(r.model == name for r in self.scheduler.queue)

    def _pump_installs(self, run_models, demand) -> tuple:
        """Budgeted install path: grant this step's tick budget to the
        install pipeline.  Returns (decodable tenants, wire bytes committed,
        wire bytes of install stream processed)."""
        decodable = [n for n in run_models if self.residency.is_resident(n)]
        blocked = [n for n in run_models if n not in decodable]
        for name in decodable:
            self.residency.touch(name, self._step_no)
        target = blocked[0] if blocked else None
        if target is None and self._overlap:
            # the turn schedule names the next tenant: prefetch its layers
            # while the current holder still decodes
            nxt = self.scheduler.peek_next_model(demand)
            if (nxt is not None and nxt not in run_models
                    and not self.residency.is_resident(nxt)):
                target = nxt
        if target is None:
            return decodable, 0, 0
        self.pipeline.begin(target, self._step_no)
        pinned = set(decodable) | {target}
        holder = self.scheduler.current_turn_model
        if (self.scheduler.turn_ending and holder is not None
                and holder != target and self._steal_ok(target)):
            # the holder's final slice step: its slots free up behind the
            # execution front, so installs may overwrite them mid-step —
            # streaming/executor.py's per-layer overlap at the tenant scale
            pinned.discard(holder)
        wire, work = self.pipeline.pump(self._ticks_per_step, pinned,
                                        self._step_no)
        return decodable, wire, work

    def _steal_ok(self, target: str) -> bool:
        """Steal the ending turn holder's slots only when the prefetch
        target can actually take the next turn: it already decodes, or the
        global active budget leaves admission headroom even after this
        step's prefills.  A queued-only target behind an exhausted budget
        may drop out of demand next step — stealing for it would hand the
        turn straight back to the tenant whose layers we just evicted."""
        if self.arenas[target].active_slots():
            return True
        budget = self.scheduler.cfg.max_active
        if budget is None:
            return True
        n_active = sum(len(a.active_slots()) for a in self.arenas.values())
        return (n_active + self.scheduler.cfg.max_prefill_per_step) < budget

    def step(self) -> None:
        """One engine step: pick the scheduled tenants (by demand — active
        slots or queued requests), make their weights resident (instantly,
        or via the budgeted install pipeline), admit+prefill their queued
        requests, then decode one token for every active slot.

        With `stall_timeout_s > 0` the step runs under the ft.Watchdog:
        a step that overruns the deadline fires `_on_stall` (trace
        instant + flight-recorder dump) while the step keeps running —
        the heartbeat observes, it never kills work."""
        if self.watchdog is None:
            self._step_inner()
            return
        with self.watchdog.armed(self._step_no):
            self._step_inner()

    def _step_inner(self) -> None:
        now = self._clock()
        with self.tracer.span("schedule"):
            demand = [name for name in self.models
                      if self._can_progress(name)]
            run_models = self.scheduler.pick_models(demand, self.residency)
        wire = 0
        work = 0
        with self.tracer.span("install"):
            if self.pipeline is None:
                for name in run_models:
                    wire += self.residency.ensure(name, self._step_no,
                                                  pinned=set(run_models))
                decodable = list(run_models)
            else:
                decodable, wire, work = self._pump_installs(run_models,
                                                            demand)

        with self.tracer.span("prefill"):
            if self._chunk > 0:
                n_prefills, prefill_tokens, n_chunks, hit_tokens = (
                    self._pump_prefills(set(decodable)))
            else:
                n_prefills, prefill_tokens = self._admit(set(decodable))
                n_chunks = hit_tokens = 0

        n_decoded = 0
        sample_syncs = 0
        for name in decodable:
            m = self.models[name]
            arena = self.arenas[name]
            paged = isinstance(arena, PagedKVArena)

            def decoding(slot) -> bool:
                # PREFILLING rows sit in the arena (their slot is claimed,
                # their pages reserved) but are not in the decode batch yet:
                # the batched step still computes their row, whose write
                # lands in the scratch page (paged) or is overwritten by
                # the install (slot) and whose output is discarded here
                s = self.requests[arena.owner_of(slot)].status
                return s is RequestStatus.RUNNING

            if paged:
                # extend tables across page boundaries and COW shared pages
                # before the step writes; pool exhaustion preempts (the
                # request re-prefills once pages free up — ARAS-style
                # adaptation to the occupancy map, not a hard failure)
                with self.tracer.span("page", tenant=name):
                    for slot in arena.active_slots():
                        if decoding(slot) and not arena.prepare_decode(slot):
                            self.preempt(arena.owner_of(slot))
            slots = [s for s in arena.active_slots() if decoding(s)]
            if not slots:
                continue
            fused = self._decode_fused[name] is not None
            temps, tks, keys, steps = self._sample_inputs(arena)
            with self.tracer.span("decode", tenant=name, n_slots=len(slots)):
                if paged and fused:
                    # fused step: sampling runs on device, logits never
                    # leave it — toks is the only thing the host pulls
                    tokens, pos, tables = arena.decode_inputs()
                    toks_dev, arena.caches = self._decode_fused[name](
                        m.params, tokens, arena.caches, pos, tables,
                        temps, tks, keys, steps)
                elif paged:
                    tokens, pos, tables = arena.decode_inputs()
                    logits, arena.caches = self._decode[name](
                        m.params, tokens, arena.caches, pos, tables)
                else:
                    tokens, pos = arena.decode_inputs()
                    logits, arena.caches = self._decode[name](
                        m.params, tokens, arena.caches, pos)
            with self.tracer.span("sample", tenant=name,
                                  fused=fused, n_slots=len(slots)):
                if not fused:
                    # split path: one batched sampler call + one host sync
                    # for the whole batch (never per row)
                    toks_dev = cached_sample_tokens(m.cfg.vocab)(
                        logits, temps, tks, keys, steps)
                nxt = np.asarray(toks_dev)
                sample_syncs += 1
                for slot in slots:
                    req = self.requests[arena.owner_of(slot)]
                    tok = int(nxt[slot])
                    req.generated.append(tok)
                    req.note_token(self._clock())
                    arena.advance(slot, tok)
                    n_decoded += 1
                    if req.done:
                        self._finish(req)

        with self.tracer.span("bookkeep"):
            tokens_out = n_decoded + n_prefills
            stall = (bool(run_models) and len(decodable) < len(run_models)
                     and tokens_out == 0 and prefill_tokens == 0
                     and hit_tokens == 0)
            if stall:
                # the step produced nothing because the scheduled tenant sat
                # waiting on installs — don't charge it a decode-slice step
                self.scheduler.refund_turn_step()

            kv_used = kv_total = cached_pages = 0
            for arena in self.arenas.values():
                if isinstance(arena, PagedKVArena):
                    kv_used += arena.allocator.n_used
                    kv_total += arena.allocator.n_pages
                    cached_pages += arena.allocator.tree.n_cached
        if self.tracer.enabled:
            self.tracer.counter("kv_used_pages", kv_used)
            self.tracer.counter("queue_depth", self.scheduler.queue_depth)
            # wear telemetry tracks: cumulative flips, current wear spread,
            # pool headroom, and install backlog — per-step counter series
            # in the Chrome trace (chrome://tracing renders them as tracks)
            self.tracer.counter("install_flips",
                                self.residency.stats.cell_flips)
            self.tracer.counter("wear_gini_weight",
                                round(self.residency.wear.gini("flips"), 4))
            self.tracer.counter("kv_free_pages", kv_total - kv_used)
            self.tracer.counter("install_queue_depth",
                                self.pipeline.queue_depth
                                if self.pipeline is not None else 0)
        rec = StepRecord(
            t=now,
            n_active=sum(len(a.active_slots()) for a in self.arenas.values()),
            queue_depth=self.scheduler.queue_depth,
            n_prefills=n_prefills,
            n_decoded=n_decoded,
            install_wire_bytes=wire,
            kv_used_pages=kv_used,
            kv_total_pages=kv_total,
            install_work_bytes=work,
            overlap_hidden_bytes=work if tokens_out > 0 else 0,
            install_stall=stall,
            prefill_tokens=prefill_tokens,
            n_prefill_chunks=n_chunks,
            prefix_hit_tokens=hit_tokens,
            prefix_cached_pages=cached_pages,
            sample_syncs=sample_syncs,
            component_s=self.tracer.step_components())
        self.metrics.record_step(rec)
        if self.telemetry is not None or self.recorder is not None:
            self._observe_step(rec, kv_total - kv_used)
        self._step_no += 1
        self._wall_s += self._clock() - now

    def _observe_step(self, rec: StepRecord, free_pages: int) -> None:
        """Feed the live-telemetry plane after a step: window updates,
        SLO transitions, the flight ring, and the two recorder triggers
        the engine itself detects (unit retirement, SLO breach)."""
        transitions = (self.telemetry.on_step(self._step_no, rec,
                                              free_pages)
                       if self.telemetry is not None else [])
        if self.recorder is None:
            return
        self.recorder.record_step(self._step_no, rec, self.health())
        retired = self.residency.stats.slots_retired + sum(
            a.allocator.pages_retired for a in self.arenas.values()
            if isinstance(a, PagedKVArena))
        if retired > self._retired_seen:
            # a slot/page retirement happened this step: capture the
            # steps that led up to it (Hamun-style incident forensics)
            self._retired_seen = retired
            self.recorder.trigger("unit_retired", step=self._step_no,
                                  retired_total=retired)
        for kind, target, burn_s, burn_l in transitions:
            if kind == "slo_breach":
                self.recorder.trigger("slo_breach", step=self._step_no,
                                      target=target, burn_short=burn_s,
                                      burn_long=burn_l)

    def _on_stall(self, step: int) -> None:
        """Watchdog deadline missed: the step loop has been inside step
        `step` for more than `stall_timeout_s`.  Observation only — the
        step keeps running; we flag the suspicion and snapshot the ring
        so a genuinely hung replica leaves forensics behind."""
        if self.tracer.enabled:
            self.tracer.instant("stall_suspected", step=step,
                                timeout_s=self._stall_timeout_s)
        if self.recorder is not None:
            self.recorder.trigger("stall_suspected", step=step,
                                  timeout_s=self._stall_timeout_s)

    def health(self) -> Dict[str, Any]:
        """Cheap live-health snapshot — the router-tier placement probe.

        Pure observation over already-tracked state (no device sync, no
        list walks over history), deterministic under `VirtualClock`:
        two identical runs produce byte-identical `health()` JSON.  The
        `slo`/`windows` sections appear only when telemetry is on; the
        resource half is always available."""
        kv_free = kv_total = pages_retired = cached_pages = 0
        for arena in self.arenas.values():
            if isinstance(arena, PagedKVArena):
                kv_free += arena.allocator.n_free
                kv_total += arena.allocator.n_pages
                pages_retired += arena.allocator.pages_retired
                cached_pages += arena.allocator.tree.n_cached
        res = self.residency
        slots_free = sum(1 for i, s in enumerate(res.slots)
                         if s is None and i not in res.retired)
        now = self._clock()
        hit = self.metrics.prefix_hit_tokens
        covered = hit + self.metrics.prefill_tokens
        doc: Dict[str, Any] = {
            "t": now,
            "step": self._step_no,
            "queue_depth": self.scheduler.queue_depth,
            "queue_wait_s": self.scheduler.queue_wait(now),
            "n_active": sum(len(a.active_slots())
                            for a in self.arenas.values()),
            "kv_free_pages": kv_free,
            "kv_total_pages": kv_total,
            "weight_slots_free": slots_free,
            "weight_slots_total": res.arena_slots,
            "slots_retired": res.stats.slots_retired,
            "pages_retired": pages_retired,
            "prefix_cached_pages": cached_pages,
            "prefix_hit_rate": hit / max(covered, 1),
            "install_backlog": (self.pipeline.queue_depth
                                if self.pipeline is not None else 0),
            "ok": True,
        }
        if self.telemetry is not None and self.telemetry.slo is not None:
            doc["ok"] = not self.telemetry.slo.any_breached
            doc["slo"] = self.telemetry.slo.status()
        if self.telemetry is not None:
            doc["windows"] = self.telemetry.snapshot_scope("_global")
        return doc

    # -------------------------------------------------------------- run
    def has_work(self) -> bool:
        return bool(self.scheduler.queue) or any(
            a.active_slots() for a in self.arenas.values())

    def run(self, max_steps: Optional[int] = None) -> Dict[str, float]:
        """Drive steps until idle; returns the metrics summary."""
        stall = 0
        while self.has_work():
            if max_steps is not None and self._step_no >= max_steps:
                break
            before = self.metrics.tokens_generated
            chunks_before = self.metrics.prefill_tokens
            hits_before = self.metrics.prefix_hit_tokens
            ticks_before = self.pipeline.pumped_ticks if self.pipeline else 0
            self.step()
            progressed = (
                self.metrics.tokens_generated != before
                or self.metrics.prefill_tokens != chunks_before
                or self.metrics.prefix_hit_tokens != hits_before
                or (self.pipeline is not None
                    and self.pipeline.pumped_ticks != ticks_before))
            stall = 0 if progressed else stall + 1
            if stall > 3:
                raise RuntimeError(
                    "engine stalled: queued work but no admissible slots")
        return self.summary()

    def summary(self, wall_s: Optional[float] = None) -> Dict[str, float]:
        """Metrics over `wall_s` if given (e.g. a benchmark's own clock
        including arrival idle time), else over the engine's cumulative
        in-step time — counters are lifetime totals, so the default stays
        consistent across multiple run()/step() episodes."""
        return self.metrics.summary(
            self._wall_s if wall_s is None else wall_s,
            residency=self.residency.stats.as_dict(),
            rejected=self.scheduler.rejected,
            paging=self._paging_stats(),
            prefill_cache=prefill_cache_info() if self._chunk > 0 else None,
            wear=self._wear_stats())

    def _wear_stats(self) -> Dict[str, float]:
        """Write energy and wear spread: install pulses and KV page bytes
        priced through the energy model, Gini coefficients per plane
        family.  `wear_gini_kv` only appears once a paged tenant exists —
        a slot-arena engine has no KV write plane to speak of."""
        em = self.energy_model
        kv_bytes = sum(a.kv_bytes_written for a in self.arenas.values()
                       if isinstance(a, PagedKVArena))
        out = {
            "install_energy_j": em.weight_write_j(
                self.residency.stats.write_pulses),
            "kv_write_energy_j": em.kv_write_j(kv_bytes),
            "wear_gini_weight": self.residency.wear.gini("flips"),
        }
        if any(name.startswith("kv:") for name in self.wear.planes):
            out["wear_gini_kv"] = self.wear.gini(prefix="kv:")
        # fault-degradation counters: units retired after a stuck-at fault
        # was survived (slots_retired rides in on the residency stats)
        pages_retired = sum(
            a.allocator.pages_retired for a in self.arenas.values()
            if isinstance(a, PagedKVArena))
        out["pages_retired"] = float(pages_retired)
        out["faults_survived"] = float(
            self.residency.stats.slots_retired + pages_retired)
        return out

    def _paging_stats(self) -> Optional[Dict[str, float]]:
        """Aggregate paged-arena stats across tenants (None when every
        tenant is slot-managed).  Each shared-page hit is one page of KV
        the pool never had to store or prefill twice."""
        agg: Optional[Dict[str, float]] = None
        for arena in self.arenas.values():
            if isinstance(arena, PagedKVArena):
                s = arena.stats()
                if agg is None:
                    agg = dict.fromkeys(s, 0.0)
                for k, v in s.items():
                    agg[k] += v
        if agg is not None:
            agg["kv_page_occupancy"] = (
                agg["kv_pages_used"] / max(agg["kv_pages_total"], 1.0))
            agg["kv_pages_saved"] = agg["kv_shared_page_hits"]
        return agg
