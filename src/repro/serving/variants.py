"""Tenant-variant helper shared by the launcher, example and benchmark:
derive a "fine-tuned" copy of a model's params (small deltas on the big
tensors) — the co-hosted model-variant regime where cross-tenant §V-C
delta installs have real structure to exploit."""
from __future__ import annotations

from typing import Any

import jax
import numpy as np


def perturbed_variant(params: Any, scale: float = 0.02, seed: int = 1) -> Any:
    rng = np.random.default_rng(seed)

    def perturb(leaf):
        a = np.asarray(leaf)
        if a.ndim >= 2 and a.size >= 1024:
            return a + (scale * a.std() *
                        rng.standard_normal(a.shape)).astype(a.dtype)
        return a

    return jax.tree.map(perturb, params)
