"""Structured tracing for the serving engine.

The :class:`Tracer` records two kinds of structured events:

* **Component spans** — per-step timings of engine phases (``schedule``,
  ``prefill``, ``decode``, ``sample``, ``install``, ``page``, ``prefix``,
  ``bookkeep``).  The engine drains the per-step accumulation via
  :meth:`Tracer.step_components` and stores it on ``StepRecord.component_s``.
* **Request lifecycle spans** — one span per scheduling phase of each
  request (``queued`` → ``prefilling`` → ``running`` → ``finished`` /
  ``preempted``), driven by :meth:`Tracer.request_phase`.

Both are clocked by an injectable ``clock`` callable.  Pass a
``VirtualClock`` (see :mod:`repro.serving.metrics`) to make traces from
``drive_simulated`` runs fully deterministic — the virtual clock only
advances between steps, so two identical runs produce byte-identical
trace files.  Pass ``time.perf_counter`` (the default) for real wall-time
breakdowns.

Export is Chrome-trace-format JSON (the ``traceEvents`` array form),
loadable in ``chrome://tracing`` or https://ui.perfetto.dev.  Component
spans live under pid 0 with one tid per component; request lifecycle
spans live under pid 1 with one tid per request id.

When tracing is disabled use :data:`NULL_TRACER`: every method is a
no-op that allocates no event objects, so instrumented code paths can
call it unconditionally.
"""
from __future__ import annotations

import json
from typing import Any, Callable, Dict, List, Optional, Tuple

__all__ = [
    "Tracer",
    "NullTracer",
    "NULL_TRACER",
    "TRACE_COMPONENTS",
    "REQUEST_PHASES",
]

# Canonical component names, in display order.  The engine may emit spans
# for any subset per step; consumers should treat missing components as 0.
TRACE_COMPONENTS: Tuple[str, ...] = (
    "schedule",
    "install",
    "prefill",
    "decode",
    "sample",
    "page",
    "prefix",
    "bookkeep",
)

# Request lifecycle phases.  ``finished`` / ``preempted`` / ``rejected``
# are terminal markers: they close the current span without opening one.
REQUEST_PHASES: Tuple[str, ...] = (
    "queued",
    "prefilling",
    "running",
    "finished",
    "preempted",
    "rejected",
)

_TERMINAL_PHASES = frozenset(("finished", "rejected"))


class _NullSpan:
    """Reusable no-op context manager shared by every NullTracer call."""

    __slots__ = ()

    def __enter__(self):  # pragma: no cover - trivial
        return self

    def __exit__(self, *exc):  # pragma: no cover - trivial
        return False


_NULL_SPAN = _NullSpan()


class NullTracer:
    """Disabled tracer: every method is a cheap no-op.

    All methods return shared singletons and allocate nothing, so leaving
    instrumentation calls in hot paths is free when tracing is off.  Use
    the module-level :data:`NULL_TRACER` instance rather than constructing
    new ones.
    """

    __slots__ = ()

    enabled = False

    def span(self, component: str, **attrs):
        return _NULL_SPAN

    def instant(self, name: str, **attrs) -> None:
        return None

    def counter(self, name: str, value: float, **attrs) -> None:
        return None

    def request_phase(self, rid: str, phase: str, **attrs) -> None:
        return None

    def step_components(self) -> Dict[str, float]:
        return {}

    def events_since(self, index: int):
        return 0, []

    def request_timeline(self, rid: str) -> str:
        return ""

    def export_chrome_trace(self, path: str) -> None:  # pragma: no cover
        raise RuntimeError("tracing is disabled; no events to export")


NULL_TRACER = NullTracer()


class _Span:
    """Context manager recording one component span on exit."""

    __slots__ = ("_tracer", "_component", "_attrs", "_t0")

    def __init__(self, tracer: "Tracer", component: str, attrs: Dict[str, Any]):
        self._tracer = tracer
        self._component = component
        self._attrs = attrs
        self._t0 = 0.0

    def __enter__(self):
        self._t0 = self._tracer._clock()
        return self

    def __exit__(self, *exc):
        self._tracer._end_span(self._component, self._t0, self._attrs)
        return False


class Tracer:
    """Structured event recorder with Chrome-trace JSON export.

    Parameters
    ----------
    clock:
        0-arg callable returning seconds.  Defaults to
        ``time.perf_counter``.  Pass a ``VirtualClock`` for deterministic
        traces from simulated runs.
    """

    enabled = True

    def __init__(self, clock: Optional[Callable[[], float]] = None):
        if clock is None:
            import time

            clock = time.perf_counter
        self._clock = clock
        # Chrome trace events, in emission order.
        self.events: List[Dict[str, Any]] = []
        # Per-step component-duration accumulator (seconds), drained by
        # the engine at the end of each step via step_components().
        self._step_acc: Dict[str, float] = {}
        # rid -> (phase, t0) for the currently-open lifecycle span.
        self._open_phase: Dict[str, Tuple[str, float]] = {}
        # rid -> list of (phase, t0, t1) closed lifecycle spans.
        self._timelines: Dict[str, List[Tuple[str, float, float]]] = {}
        self._t_origin = self._clock()

    # ------------------------------------------------------------------
    # component spans

    def span(self, component: str, **attrs) -> _Span:
        """Open a component span; use as ``with tracer.span("decode"):``."""
        return _Span(self, component, attrs)

    def _end_span(self, component: str, t0: float, attrs: Dict[str, Any]) -> None:
        t1 = self._clock()
        dur = t1 - t0
        self._step_acc[component] = self._step_acc.get(component, 0.0) + dur
        ev: Dict[str, Any] = {
            "name": component,
            "ph": "X",
            "pid": 0,
            "tid": component,
            "ts": self._us(t0),
            "dur": round((t1 - t0) * 1e6, 3),
        }
        if attrs:
            ev["args"] = attrs
        self.events.append(ev)

    def step_components(self) -> Dict[str, float]:
        """Return and reset the per-step component-duration accumulator."""
        acc = self._step_acc
        self._step_acc = {}
        return acc

    def events_since(self, index: int):
        """The event tail appended since `index`, plus the new cursor —
        how the flight recorder slices each step's events into its ring
        without copying the whole log every step."""
        return len(self.events), self.events[index:]

    # ------------------------------------------------------------------
    # instants and counters

    def instant(self, name: str, **attrs) -> None:
        """Record a zero-duration event (e.g. an eviction or verdict)."""
        ev: Dict[str, Any] = {
            "name": name,
            "ph": "i",
            "s": "g",
            "pid": 0,
            "tid": "events",
            "ts": self._us(self._clock()),
        }
        if attrs:
            ev["args"] = attrs
        self.events.append(ev)

    def counter(self, name: str, value: float, **attrs) -> None:
        """Record a counter sample (rendered as a track in Perfetto)."""
        args = {"value": value}
        args.update(attrs)
        self.events.append(
            {
                "name": name,
                "ph": "C",
                "pid": 0,
                "ts": self._us(self._clock()),
                "args": args,
            }
        )

    # ------------------------------------------------------------------
    # request lifecycle

    def request_phase(self, rid: str, phase: str, **attrs) -> None:
        """Transition request ``rid`` into ``phase``.

        Closes the previously open phase span (if any) and opens a span
        for the new phase.  Terminal phases (``finished``, ``rejected``)
        only close; ``preempted`` both closes the prior phase and opens a
        ``queued``-like ``preempted`` span that the next phase closes.
        """
        now = self._clock()
        prev = self._open_phase.pop(rid, None)
        if prev is not None:
            prev_phase, t0 = prev
            self._emit_phase(rid, prev_phase, t0, now)
        if phase in _TERMINAL_PHASES:
            # Zero-duration marker so terminal state is visible in trace.
            ev: Dict[str, Any] = {
                "name": phase,
                "ph": "i",
                "s": "t",
                "pid": 1,
                "tid": rid,
                "ts": self._us(now),
            }
            if attrs:
                ev["args"] = attrs
            self.events.append(ev)
            self._timelines.setdefault(rid, []).append((phase, now, now))
            return
        self._open_phase[rid] = (phase, now)
        if attrs:
            # Mark phase entry attrs (e.g. chunk index) as an instant.
            self.events.append(
                {
                    "name": f"{phase}:enter",
                    "ph": "i",
                    "s": "t",
                    "pid": 1,
                    "tid": rid,
                    "ts": self._us(now),
                    "args": attrs,
                }
            )

    def _emit_phase(self, rid: str, phase: str, t0: float, t1: float) -> None:
        self.events.append(
            {
                "name": phase,
                "ph": "X",
                "pid": 1,
                "tid": rid,
                "ts": self._us(t0),
                "dur": round((t1 - t0) * 1e6, 3),
            }
        )
        self._timelines.setdefault(rid, []).append((phase, t0, t1))

    def request_timeline(self, rid: str) -> str:
        """One-line summary of a request's phase history so far.

        Includes the currently-open phase (duration up to now).  Used for
        preemption / requeue log lines so livelock reports are debuggable
        from output alone.
        """
        parts: List[str] = []
        for phase, t0, t1 in self._timelines.get(rid, []):
            parts.append(f"{phase}={t1 - t0:.3f}s")
        cur = self._open_phase.get(rid)
        if cur is not None:
            phase, t0 = cur
            parts.append(f"{phase}={self._clock() - t0:.3f}s*")
        return " ".join(parts) if parts else "(no spans)"

    # ------------------------------------------------------------------
    # export

    def _us(self, t: float) -> float:
        """Seconds-since-origin -> microseconds, rounded for stable JSON."""
        return round((t - self._t_origin) * 1e6, 3)

    def chrome_trace_doc(self) -> Dict[str, Any]:
        """Build the Chrome trace format document (Perfetto-loadable)."""
        meta: List[Dict[str, Any]] = [
            {
                "name": "process_name",
                "ph": "M",
                "pid": 0,
                "args": {"name": "engine"},
            },
            {
                "name": "process_name",
                "ph": "M",
                "pid": 1,
                "args": {"name": "requests"},
            },
        ]
        # Chrome trace tids must be integers; map string tids stably by
        # first appearance and emit thread_name metadata.
        tid_map: Dict[Tuple[int, str], int] = {}
        next_tid: Dict[int, int] = {0: 0, 1: 0}

        def map_tid(pid: int, tid: Any) -> int:
            key = (pid, str(tid))
            if key not in tid_map:
                tid_map[key] = next_tid[pid]
                next_tid[pid] += 1
                meta.append(
                    {
                        "name": "thread_name",
                        "ph": "M",
                        "pid": pid,
                        "tid": tid_map[key],
                        "args": {"name": str(tid)},
                    }
                )
            return tid_map[key]

        out: List[Dict[str, Any]] = []
        for ev in self.events:
            ev = dict(ev)
            if "tid" in ev:
                ev["tid"] = map_tid(ev["pid"], ev["tid"])
            out.append(ev)
        return {"traceEvents": meta + out, "displayTimeUnit": "ms"}

    def to_chrome_json(self) -> str:
        """Return the Chrome-trace JSON document as a string."""
        return (
            json.dumps(self.chrome_trace_doc(), separators=(",", ":"), sort_keys=True)
            + "\n"
        )

    def export_chrome_trace(self, path: str) -> None:
        """Write events as Chrome trace format JSON (Perfetto-loadable)."""
        with open(path, "w") as f:
            f.write(self.to_chrome_json())
