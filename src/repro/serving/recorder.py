"""Flight recorder: a bounded ring of the engine's recent steps, dumped
to JSON when something goes wrong.

Production incidents on a serving replica usually leave nothing behind:
the process dies (crash), a unit retires (Hamun-style stuck-at fault),
or an SLO burns — and the postmortem summary only says *that* it
happened, not what the steps leading up to it looked like.  The
`FlightRecorder` keeps the last N `StepRecord`s + the trace events and
`health()` snapshot of each step in a `deque` ring, and `trigger()`
writes the whole ring as one deterministic JSON document on:

- fault retirement (`slot_retired` / `page_retired`, engine-detected),
- SLO breach transitions (forwarded from the `SLOTracker`),
- a watchdog-suspected stall (`stall_suspected`),
- SIGUSR1 (`install_signal_handler`), and
- an unhandled exception (`install_excepthook`).

Dumps are canonical JSON (sorted keys, NaN scrubbed), so under
`VirtualClock` two identical runs produce byte-identical dump files —
pinned in tests.  The recorder is pure observation: it never feeds a
value back into scheduling, so enabling it is token-identical.
"""
from __future__ import annotations

import collections
import dataclasses
import os
import signal
import sys
from typing import Deque, List, Optional

from repro.serving.telemetry import dumps_deterministic
from repro.serving.tracing import NULL_TRACER


class FlightRecorder:
    """Bounded ring of per-step engine state with triggered JSON dumps.

    `steps` bounds the ring (and therefore memory); `max_dumps` bounds
    how many dump files one run can write, so a breach storm cannot
    fill a disk.  The engine injects its tracer (`recorder.tracer = ...`)
    so each ring entry carries exactly the trace events its step
    produced."""

    def __init__(self, steps: int = 256, *, out_dir: str = ".",
                 prefix: str = "flight", max_dumps: int = 8):
        if steps < 1:
            raise ValueError(f"ring must hold >= 1 step, got {steps}")
        self.steps = int(steps)
        self.out_dir = out_dir
        self.prefix = prefix
        self.max_dumps = int(max_dumps)
        self.tracer = NULL_TRACER          # engine injects its tracer
        self.dumps: List[str] = []         # paths written, in order
        self.triggers: List[dict] = []     # every trigger, capped or not
        self._ring: Deque[dict] = collections.deque(maxlen=self.steps)
        self._ev_idx = 0                   # tracer.events consumed so far

    # ------------------------------------------------------------ ring
    def record_step(self, step_no: int, record, health: dict) -> None:
        """Append one step to the ring.  `record` is the step's
        `StepRecord`; `health` the engine's `health()` snapshot."""
        entry = {"step": step_no,
                 "record": dataclasses.asdict(record),
                 "health": health}
        if self.tracer.enabled:
            self._ev_idx, tail = self.tracer.events_since(self._ev_idx)
            entry["events"] = list(tail)
        self._ring.append(entry)

    def __len__(self) -> int:
        return len(self._ring)

    # ---------------------------------------------------------- dumps
    def doc(self, reason: str, step: Optional[int] = None,
            **attrs) -> dict:
        return {"version": 1, "reason": reason, "trigger_step": step,
                "attrs": attrs, "n_entries": len(self._ring),
                "ring_steps": self.steps, "entries": list(self._ring)}

    def trigger(self, reason: str, step: Optional[int] = None,
                **attrs) -> Optional[str]:
        """Dump the ring; returns the path written, or None once
        `max_dumps` is reached (the trigger is still logged)."""
        self.triggers.append({"reason": reason, "step": step, **attrs})
        if len(self.dumps) >= self.max_dumps:
            return None
        name = f"{self.prefix}-{len(self.dumps):03d}-{reason}.json"
        path = os.path.join(self.out_dir, name)
        text = dumps_deterministic(self.doc(reason, step, **attrs))
        with open(path, "w", encoding="utf-8") as f:
            f.write(text + "\n")
        self.dumps.append(path)
        if self.tracer.enabled:
            # basename only: keeps traces (and later ring entries, which
            # embed these events) byte-identical across output directories
            self.tracer.instant("flight_dump", reason=reason, file=name)
        return path

    # ------------------------------------------------- process hooks
    def install_signal_handler(self, signum: int = signal.SIGUSR1) -> None:
        """SIGUSR1 -> dump: `kill -USR1 <pid>` snapshots a live replica
        without stopping it.  Main-thread only (signal module rule)."""

        def _on_signal(_sig, _frame):
            self.trigger("sigusr1")

        signal.signal(signum, _on_signal)

    def install_excepthook(self) -> None:
        """Dump on an unhandled exception, then chain to the previous
        excepthook so default traceback printing still happens."""
        prev = sys.excepthook

        def _hook(exc_type, exc, tb):
            try:
                self.trigger("crash", error=repr(exc))
            finally:
                prev(exc_type, exc, tb)

        sys.excepthook = _hook
