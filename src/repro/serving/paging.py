"""Paged KV-cache subsystem: block page tables, prefix sharing, and COW.

The slot arena (`kv_arena.py`) binds every request to a whole-sequence slot,
so `max_seq` is a per-tenant constant and a 3-token request strands a full
`max_seq` KV region.  This module brings the crossbar occupancy-map
discipline of `sim/aras.py` down to *sub-sequence* granularity:

  * `PageAllocator` — a host-side occupancy map over fixed-size KV pages:
    free-list allocation, per-request page tables, refcounted prefix sharing
    (identical token prefixes map to the same physical pages), and
    copy-on-write when a shared page is about to diverge.  A freed page
    keeps its stale device contents until the next occupant overwrites
    them — correctness comes from position masks, exactly like a released
    crossbar row.
  * `PagedKVArena` — the device side: one page-pool cache pytree per tenant
    (the `init_cache` layout with the batch axis reinterpreted as the page
    axis) plus per-row decode state.  Requests address their KV through an
    `(n_rows, n_pages)` page-table array consumed by the paged decode path
    in `nn/attention.py`; a request may span any number of pages, so the
    per-request ceiling is the whole pool, not a per-slot constant.

Device page 0 is reserved as a scratch page: inactive decode rows keep
all-zero page tables, so their (discarded) decode writes land in the
scratch page instead of corrupting a reallocated neighbor.

Prefix-sharing safety argument: a page registered under token prefix `t`
holds valid K/V for every position `< len(t)`; later appends by the owner
only add entries at *higher* positions, which any sharer masks out
(`kpos <= pos`).  Sharing therefore stays sound even when the registered
content grows — but a *write* into a page with refcount > 1 must COW first,
because two requests appending different tokens at the same page offset
would otherwise corrupt each other.

The prefix index is a `RadixPrefixCache` (serving/prefix_cache.py): a
radix tree over token-block edges.  With `retain=True` the tree also
*keeps* pages after their last live holder exits (finished requests donate
their prompt+generated pages instead of freeing them), holding one
refcount per retained page and LRU-evicting on demand when an allocation
would otherwise fail — write-avoidance extended from the weight plane
(§V-C delta installs) to the KV plane.
"""
from __future__ import annotations

import functools
import heapq
from collections import deque
from typing import Any, Dict, FrozenSet, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.nn.config import ModelConfig
from repro.nn.transformer import layer_kind, stack_plan
from repro.serving.prefix_cache import RadixPrefixCache
from repro.serving.tracing import NULL_TRACER


class PageAllocator:
    """Host-side occupancy map over `n_pages` KV pages of `page_size` tokens.

    Physical page ids run 1..n_pages; id 0 is the arena's reserved scratch
    page and is never handed out."""

    # structured-event sink for eviction/COW/donation decisions; the
    # engine swaps in its shared Tracer, standalone use keeps the no-op
    tracer = NULL_TRACER
    # stuck-at fault model (serving/faults.py) + the wear-plane name its
    # checks key on, injected by the engine; None = fault-free allocation
    faults = None
    fault_plane = "kv"
    # wear-plane over this pool's page ids, shared with the owning arena;
    # enable_wear_aware() switches the free structure to coldest-first
    wear = None

    def __init__(self, n_pages: int, page_size: int, *,
                 retain: bool = False, max_cached: Optional[int] = None):
        if n_pages < 1 or page_size < 1:
            raise ValueError("need n_pages >= 1 and page_size >= 1")
        self.n_pages = n_pages
        self.page_size = page_size
        self.retain = retain
        # FIFO free deque by default; enable_wear_aware() rebuilds it as a
        # (writes, page) min-heap so allocation hands out the coldest page
        # first — valid because pages only accrue writes while allocated,
        # so a free page's wear never changes under it
        self._free = deque(range(1, n_pages + 1))
        self.wear_aware = False
        # pages permanently pulled from service after a stuck-at fault —
        # never re-issued (neither free nor referenced)
        self.retired: set = set()
        self.pages_retired = 0
        self.refcount = np.zeros(n_pages + 1, np.int32)
        self.tables: Dict[int, List[int]] = {}      # rid -> physical pages
        # prefix index + retention layer: radix tree over token-block
        # edges; the tree's incremental evictable count watches our
        # refcounts, so every crossing of the ==1 boundary is reported
        # back through note_refcount (see _pin / free_page)
        self.tree = RadixPrefixCache(
            page_size, max_cached=max_cached,
            refcount_of=lambda page: int(self.refcount[page]))
        # lifetime stats
        self.pages_allocated = 0
        self.shared_hits = 0
        self.cow_copies = 0

    # ------------------------------------------------------------- sizing
    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_used(self) -> int:
        return self.n_pages - len(self._free)

    def occupancy(self) -> float:
        return self.n_used / self.n_pages

    def blocks_for(self, n_tokens: int) -> int:
        return max(-(-n_tokens // self.page_size), 1)

    # ---------------------------------------------------------- low level
    def enable_wear_aware(self, plane) -> None:
        """Switch free-page ordering from FIFO to coldest-first, steered by
        `plane` (the pool's WearPlane): the free structure becomes a
        (writes, page) min-heap, so every allocation programs the least-
        worn free page.  Ties break toward the lower page id, keeping the
        order deterministic."""
        self.wear = plane
        self.wear_aware = True
        heap = [(int(plane.writes[p - plane.first]), p) for p in self._free]
        heapq.heapify(heap)
        self._free = heap

    def _free_push(self, page: int) -> None:
        if self.wear_aware:
            heapq.heappush(
                self._free,
                (int(self.wear.writes[page - self.wear.first]), page))
        else:
            self._free.append(page)

    def _free_pop(self) -> int:
        if self.wear_aware:
            return heapq.heappop(self._free)[1]
        return self._free.popleft()

    def _take_page(self) -> Optional[int]:
        """`_alloc_page` behind program-and-verify: pop free pages until one
        takes the program cleanly; a page that faults is retired for good
        (never re-issued), the free list is topped back up via LRU eviction
        when retirement drains it, and None means no healthy page is left —
        the caller unwinds with no side effects and degrades like any other
        pool exhaustion (preempt, resume when pages free up)."""
        while True:
            if not self._free and not self.ensure_free(1):
                return None
            page = self._free_pop()
            if (self.faults is not None
                    and self.faults.check(self.fault_plane, page)):
                self.retired.add(page)
                self.pages_retired += 1
                if self.wear is not None:
                    self.wear.retire(page)
                self.tracer.instant("page_retired", page=page,
                                    plane=self.fault_plane)
                continue
            self.refcount[page] = 1
            self.pages_allocated += 1
            return page

    def free_page(self, page: int) -> None:
        """Drop one reference; the page returns to the free list (contents
        left stale on device) only when the last holder lets go.  A dying
        live page takes its tree node with it, cascading through any
        retained subtree below (whose refs come back through this very
        method — by_page is cleared first, so re-entry is a no-op)."""
        if self.refcount[page] <= 0:
            raise ValueError(f"double free of page {page}")
        self.refcount[page] -= 1
        if self.refcount[page] == 0:
            self.tree.drop_page(page, self.free_page)
            self._free_push(page)
        elif self.refcount[page] == 1:
            # last external holder left a retained page: it just became
            # solely tree-held, i.e. evictable — tell the tree's count
            self.tree.note_refcount(page)

    def _pin(self, page: int) -> None:
        """Take one shared reference on a resident prefix page.  The 1→2
        crossing makes a retained page non-evictable; the tree's
        incremental count hears about it here."""
        self.refcount[page] += 1
        self.shared_hits += 1
        if self.refcount[page] == 2:
            self.tree.note_refcount(page)

    def _sole(self, page: int) -> bool:
        """Nobody but the tree holds this page — the eviction predicate."""
        return self.refcount[page] == 1

    # ------------------------------------------------------ prefix sharing
    def match_prefix(self, tokens: Tuple[int, ...],
                     touch: bool = True) -> List[int]:
        """Longest chain of resident pages whose token prefixes match
        `tokens` block by block — a radix-tree walk, one dict probe per
        block (O(blocks) incremental hashing, not the old O(blocks·len)
        full-prefix tuples).  Full blocks match on block-boundary edges;
        the final partial block matches only an exact-tuple edge (a page
        holding *more* than the lookup key would require mid-page writes
        during prefill, where sharing buys nothing over writing a fresh
        page).  `touch=False` keeps pure capacity probes out of the LRU
        order."""
        return self.tree.match(tuple(tokens), touch=touch)

    def register(self, rid: int, tokens: Tuple[int, ...]) -> None:
        """Publish a freshly installed table's pages under their token
        prefixes so later requests can share them.  First writer wins; a
        page is only ever indexed under one key."""
        self.tree.register(tuple(tokens), self.tables[rid])

    # ----------------------------------------------------------- eviction
    def evictable_pages(self, exclude: FrozenSet[int] = frozenset()) -> int:
        """Pages on-demand eviction could free right now (exact, so
        admission promises only what `ensure_free` can deliver).  O(1)
        plus O(|exclude| chain) — the incrementally maintained count, not
        a tree walk (the scheduling hot path calls this per overflow)."""
        return self.tree.evictable_count(frozenset(exclude))

    def ensure_free(self, need: int) -> bool:
        """LRU-evict retained pages until `need` pages are free.  False
        when the cache cannot cover the shortfall (callers pre-check with
        `evictable_pages` to fail without side effects)."""
        evicted = 0
        while len(self._free) < need:
            if not self.tree.evict_lru(self._sole, self.free_page):
                return False
            evicted += 1
        if evicted:
            self.tracer.instant("kv_evict", n_pages=evicted,
                                cached_left=self.tree.n_cached)
        return True

    # ------------------------------------------------------ request level
    def alloc_table(self, rid: int, tokens: Tuple[int, ...]
                    ) -> Optional[Tuple[List[int], int]]:
        """Build rid's page table over `tokens`: refcount shared prefix
        pages, allocate fresh pages for the rest.  Returns (table,
        n_shared), or None *with no side effects* when the pool cannot
        cover the non-shared tail."""
        if rid in self.tables:
            raise ValueError(f"rid {rid} already holds a table")
        n_blocks = self.blocks_for(len(tokens))
        shared = self.match_prefix(tokens)
        for page in shared:          # pin first: pinned pages never evict
            self._pin(page)
        need = n_blocks - len(shared)
        if need > self.n_free + self.evictable_pages():
            for page in shared:      # unpin — no side effects on failure
                self.free_page(page)
            self.shared_hits -= len(shared)
            return None
        self.ensure_free(need)
        fresh: List[int] = []
        for _ in range(need):
            page = self._take_page()
            if page is None:         # faults drained the pool mid-build
                for p in fresh:
                    self.free_page(p)
                for p in shared:
                    self.free_page(p)
                self.shared_hits -= len(shared)
                return None
            fresh.append(page)
        table = list(shared) + fresh
        self.tables[rid] = table
        return table, len(shared)

    def begin_table(self, rid: int, tokens: Tuple[int, ...]) -> int:
        """Chunked-prefill admission: open rid's table with just the shared
        prefix pages (refcounted now — sharing is checked against the whole
        prompt, which is known up front).  Fresh pages for the non-shared
        tail are reserved chunk by chunk via grow_table — admission still
        gates on the whole footprint being free (lax admission would churn
        reservations without progress), but decode neighbors allocate and
        free pages while the prefill runs, and a reservation that loses
        that race fails cleanly at grow_table instead of corrupting
        anything.  Returns the number of shared pages."""
        if rid in self.tables:
            raise ValueError(f"rid {rid} already holds a table")
        shared = self.match_prefix(tokens)
        for page in shared:
            self._pin(page)
        self.tables[rid] = list(shared)
        return len(shared)

    def grow_table(self, rid: int, n_blocks: int) -> bool:
        """Reserve fresh pages until rid's table covers `n_blocks` blocks
        (one prefill chunk's worth at a time).  False with *no side effects*
        when the pool cannot cover the growth — the caller preempts the
        prefill and resumes it at the last completed chunk once pages free
        up."""
        need = n_blocks - len(self.tables[rid])
        if need <= 0:
            return True
        if need > self.n_free + self.evictable_pages():
            return False
        self.ensure_free(need)
        added: List[int] = []
        for _ in range(need):
            page = self._take_page()
            if page is None:         # faults drained the pool mid-growth
                for p in added:
                    self.free_page(p)
                return False
            added.append(page)
        self.tables[rid].extend(added)
        return True

    def extend(self, rid: int) -> Optional[int]:
        """Append one fresh page to rid's table (decode crossed a page
        boundary), LRU-evicting a retained page if the free list is empty.
        None when the pool is exhausted — the caller preempts."""
        if not self.ensure_free(1):
            return None
        page = self._take_page()
        if page is None:
            return None
        self.tables[rid].append(page)
        return page

    def cow(self, rid: int, block: int) -> Optional[Tuple[int, int]]:
        """Make rid's `block` exclusively owned before a write.  Returns
        (src, dst) when a device page copy is required, (page, page) when
        the page was already exclusive, None when the pool is exhausted
        (after LRU-evicting any retained pages it could)."""
        old = self.tables[rid][block]
        if self.refcount[old] <= 1:
            return old, old
        if not self.ensure_free(1):
            return None
        new = self._take_page()
        if new is None:
            return None
        self.free_page(old)          # our ref only; other holders keep it
        self.tables[rid][block] = new
        self.cow_copies += 1
        self.tracer.instant("kv_cow", rid=rid, block=block, src=old, dst=new)
        return old, new

    def free_table(self, rid: int,
                   donate_tokens: Optional[Tuple[int, ...]] = None) -> None:
        """Release rid's table.  With retention on and `donate_tokens` (the
        token sequence the table holds valid K/V for — prompt + generated
        minus the just-emitted last token), the pages enter the radix tree
        retained instead of returning to the free list: the next request
        sharing the prefix finds them resident."""
        table = self.tables.pop(rid)
        if (self.retain and donate_tokens
                and len(table) == self.blocks_for(len(donate_tokens))):
            ev0 = self.tree.evictions
            gained = self.tree.donate(tuple(donate_tokens), table,
                                      self.free_page)
            # cap-enforcement evictions happen inside donate, not
            # ensure_free — surface them here so the trace accounts for
            # every LRU eviction the summary reports
            self.tracer.instant("kv_donate", rid=rid, n_pages=len(table),
                                retained=gained,
                                cap_evictions=self.tree.evictions - ev0)
        else:
            for page in table:
                self.free_page(page)


# ---------------------------------------------------------------- device
def init_page_pool(cfg: ModelConfig, n_pages: int, page_size: int,
                   dtype=jnp.bfloat16):
    """Page-pool cache pytree: `nn.model.init_cache` with the batch axis
    reinterpreted as the page axis, except windowed layers keep full pages
    (the paged decode path masks the window instead of ring-indexing)."""

    def attn_entry():
        if cfg.attn_type == "mla":
            return {
                "c_kv": jnp.zeros((n_pages, page_size, cfg.kv_lora_rank),
                                  dtype),
                "k_rope": jnp.zeros((n_pages, page_size, cfg.qk_rope_dim),
                                    dtype),
            }
        kv_dt = jnp.int8 if cfg.kv_cache_dtype == "int8" else dtype
        out = {
            "k": jnp.zeros((n_pages, page_size, cfg.n_kv_heads,
                            cfg.head_dim), kv_dt),
            "v": jnp.zeros((n_pages, page_size, cfg.n_kv_heads,
                            cfg.head_dim), kv_dt),
        }
        if cfg.kv_cache_dtype == "int8":
            out["k_scale"] = jnp.zeros(
                (n_pages, page_size, cfg.n_kv_heads), jnp.float32)
            out["v_scale"] = jnp.zeros_like(out["k_scale"])
        return out

    caches = []
    for start, length, scanned in stack_plan(cfg):
        one: Any = {"attn": attn_entry()}
        if scanned:
            one = jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (length,) + a.shape), one)
        caches.append(one)
    return caches


@functools.lru_cache(maxsize=None)
def _cached_page_write(cfg: ModelConfig, page_size: int):
    """Jitted page scatter shared across arenas of one config: copy logical
    block `block` of a batch-1 prefill cache into physical page `page` of
    the pool.  The pool is donated — install() immediately rebinds
    self.caches, so the write is in place."""
    plan = stack_plan(cfg)

    def write(pool, one, block, page):
        out = []
        for seg_pool, seg_one, (_, _, scanned) in zip(pool, one, plan):
            def upd(a, o, scanned=scanned):
                if scanned:  # a (L, P, ps, ...), o (L, 1, Lbuf, ...)
                    chunk = jax.lax.dynamic_slice_in_dim(
                        o[:, 0], block * page_size, page_size, axis=1)
                    return jax.lax.dynamic_update_slice_in_dim(
                        a, chunk[:, None].astype(a.dtype), page, axis=1)
                chunk = jax.lax.dynamic_slice_in_dim(
                    o[0], block * page_size, page_size, axis=0)
                return jax.lax.dynamic_update_slice_in_dim(
                    a, chunk[None].astype(a.dtype), page, axis=0)
            out.append(jax.tree.map(upd, seg_pool, seg_one))
        return out

    return jax.jit(write, donate_argnums=(0,))


@functools.lru_cache(maxsize=None)
def _cached_page_read(cfg: ModelConfig, page_size: int):
    """Jitted pool→staging gather, the inverse of `_cached_page_write`:
    copy physical page `page` of the pool into logical block `block` of a
    batch-1 staging cache.  The chunk-skip warm path uses it to seed the
    staging carry-in from cached prefix pages (bf16 pools round-trip
    bit-exact).  int8 pools are dequantization-aware: the pool holds
    {k, v, k_scale, v_scale} while staging attends raw bf16 {k, v}, so
    the page's codes are dequantized on the way out — the warm prefix
    carries the same quantization error decode attends after install.
    Staging is donated — the caller immediately rebinds it."""
    plan = stack_plan(cfg)
    int8 = cfg.kv_cache_dtype == "int8" and cfg.attn_type != "mla"

    def read(one, pool, block, page):
        out = []
        for seg_one, seg_pool, (_, _, scanned) in zip(one, pool, plan):
            def upd(o, a, scanned=scanned):
                if scanned:  # a (L, P, ps, ...), o (L, 1, Lbuf, ...)
                    chunk = jax.lax.dynamic_slice_in_dim(a, page, 1, axis=1)
                    return jax.lax.dynamic_update_slice_in_dim(
                        o, chunk.astype(o.dtype), block * page_size, axis=2)
                chunk = jax.lax.dynamic_slice_in_dim(a, page, 1, axis=0)
                return jax.lax.dynamic_update_slice_in_dim(
                    o, chunk.astype(o.dtype), block * page_size, axis=1)

            if int8:
                # pool entry {k, v, k_scale, v_scale} → staging {k, v}:
                # dequantize the page's codes with its per-(token, head)
                # scales (codes.f32 * scale == nn.attention._kv_dequant)
                po, pa = seg_one["attn"], seg_pool["attn"]
                ent = {}
                axis = 1 if scanned else 0
                for f in ("k", "v"):
                    c = jax.lax.dynamic_slice_in_dim(pa[f], page, 1,
                                                     axis=axis)
                    s = jax.lax.dynamic_slice_in_dim(pa[f + "_scale"], page,
                                                     1, axis=axis)
                    deq = (c.astype(jnp.float32)
                           * s[..., None]).astype(po[f].dtype)
                    ent[f] = jax.lax.dynamic_update_slice_in_dim(
                        po[f], deq, block * page_size,
                        axis=2 if scanned else 1)
                new_seg = dict(seg_one)
                new_seg["attn"] = ent
                out.append(new_seg)
            else:
                out.append(jax.tree.map(upd, seg_one, seg_pool))
        return out

    return jax.jit(read, donate_argnums=(0,))


@functools.lru_cache(maxsize=None)
def _cached_page_copy(cfg: ModelConfig):
    """Jitted COW page copy: pool page `src` -> pool page `dst`."""
    plan = stack_plan(cfg)

    def copy(pool, src, dst):
        out = []
        for seg, (_, _, scanned) in zip(pool, plan):
            ax = 1 if scanned else 0
            out.append(jax.tree.map(
                lambda a, ax=ax: jax.lax.dynamic_update_slice_in_dim(
                    a, jax.lax.dynamic_slice_in_dim(a, src, 1, axis=ax),
                    dst, axis=ax),
                seg))
        return out

    return jax.jit(copy, donate_argnums=(0,))


class PagedKVArena:
    """Device page pool + per-row decode state for one tenant.

    Rows are decode-batch positions (the jitted decode step always runs
    `n_rows` rows; inactive rows decode discarded garbage against the
    reserved scratch page).  Pages are the storage unit: a request holds
    ceil(len/page_size) of them, up to the whole pool."""

    layout = "paged"
    # structured-event sink (shared with self.allocator); the engine
    # swaps in its Tracer, standalone use keeps the no-op
    tracer = NULL_TRACER
    # wear-telemetry sink, injected like the tracer: the engine's
    # WearPlane over this pool's page ids (1..n_pages; the scratch page 0
    # never takes an accounted write).  Standalone use records nothing.
    wear = None

    def __init__(self, cfg: ModelConfig, n_rows: int, n_pages: int,
                 page_size: int, *, prefix_cache: bool = False,
                 prefix_cache_pages: int = 0):
        for start, _, _ in stack_plan(cfg):
            if layer_kind(cfg, start) != "attn":
                raise ValueError(
                    "paged KV needs a pure-attention stack; "
                    f"layer {start} of {cfg.name} is "
                    f"{layer_kind(cfg, start)!r} (use kv_layout='slot')")
        self.cfg = cfg
        self.n_rows = n_rows
        self.page_size = page_size
        self.prefix_cache = bool(prefix_cache)
        # chunk-skip reloads pool pages into the staging carry-in; int8
        # pools dequantize on the way out (_cached_page_read), so int8
        # tenants skip covered chunks too — the reloaded prefix carries
        # quantization error the cold path's raw bf16 staging did not,
        # which is the same error decode already attends post-install
        self.skip_ok = self.prefix_cache
        self.allocator = PageAllocator(
            n_pages, page_size, retain=self.prefix_cache,
            max_cached=(prefix_cache_pages or None) if prefix_cache
            else None)
        self.caches = init_page_pool(cfg, n_pages + 1, page_size)
        # Device bytes one logical page write programs: a page write
        # scatters this page's slice of EVERY pool leaf (all layers — see
        # _cached_page_write), so per-page bytes = per-leaf page-axis slice
        # summed across leaves.  Feeds the kv write-energy conversion.
        self.page_bytes = int(sum(
            (int(np.prod(leaf.shape)) * np.dtype(leaf.dtype).itemsize)
            // (n_pages + 1)
            for leaf in jax.tree.leaves(self.caches)))
        # write-side accounting: physical page programs (prefill scatter,
        # staged install, COW copies) and the programs retained-page /
        # live-prefix sharing avoided (shared pages an install skipped)
        self.kv_page_writes = 0
        self.kv_bytes_written = 0
        self.kv_page_writes_avoided = 0
        self.owner: List[Optional[int]] = [None] * n_rows
        self.pos = np.zeros(n_rows, np.int32)
        self.last_token = np.zeros(n_rows, np.int32)
        # page-table rows consumed by the decode step; 0 = scratch page
        self.tables_np = np.zeros((n_rows, n_pages), np.int32)
        self._n_shared: Dict[int, int] = {}   # rid -> shared prefix pages
        self._free_rows: deque = deque(range(n_rows))
        self._write = _cached_page_write(cfg, page_size)
        self._read = _cached_page_read(cfg, page_size)
        self._copy = _cached_page_copy(cfg)
        self.evictions = 0

    # ------------------------------------------------------------ sizing
    @property
    def max_tokens(self) -> int:
        """Per-request ceiling: the whole pool (not a per-slot constant)."""
        return self.allocator.n_pages * self.page_size

    @property
    def n_free(self) -> int:
        """Free decode rows (the scheduler's per-tenant admission count)."""
        return len(self._free_rows)

    def blocks_for(self, n_tokens: int) -> int:
        return self.allocator.blocks_for(n_tokens)

    def can_admit(self, tokens: Tuple[int, ...]) -> bool:
        """Enough free pages for the non-shared tail — counting retained
        pages LRU eviction could free on demand — and a free row."""
        if not self._free_rows:
            return False
        a = self.allocator
        need = self.blocks_for(len(tokens))
        if need <= a.n_free:
            return True     # fits even with zero sharing: skip the prefix
            # match + evictability walk on the hot scheduling path
        shared = a.match_prefix(tuple(tokens), touch=False)
        need -= len(shared)
        # matched pages are about to be pinned, not consumed — exclude
        # them from the evictable count so the promise stays exact (an
        # optimistic count would requeue-livelock the engine)
        ok = need <= a.n_free + a.evictable_pages(frozenset(shared))
        if self.tracer.enabled:
            self.tracer.instant("kv_admit", ok=ok, need=need,
                                free=a.n_free, shared=len(shared))
        return ok

    # ------------------------------------------------------------- rows
    def active_slots(self) -> List[int]:
        return [r for r, o in enumerate(self.owner) if o is not None]

    def owner_of(self, row: int) -> Optional[int]:
        return self.owner[row]

    def alloc(self, rid: int, tokens: Tuple[int, ...]) -> Optional[int]:
        """Claim a row and a page table covering `tokens`; None (no side
        effects) when rows or pages are short."""
        if not self._free_rows:
            return None
        got = self.allocator.alloc_table(rid, tuple(tokens))
        if got is None:
            return None
        table, n_shared = got
        row = self._free_rows.popleft()
        self.owner[row] = rid
        self._n_shared[rid] = n_shared
        self.tables_np[row, :] = 0
        self.tables_np[row, :len(table)] = table
        return row

    def evict(self, row: int,
              donate: Optional[Tuple[int, ...]] = None) -> Optional[int]:
        """Release a row (finish or preemption): refcounts drop, pages whose
        last holder left return to the free list with stale contents.
        `donate` (a finished request's prompt + generated tokens) instead
        retains the pages in the prefix cache: the table holds valid K/V
        for the first `pos` of them (the just-emitted last token was never
        written), so that prefix is what enters the tree."""
        rid = self.owner[row]
        if rid is None:
            return None
        tokens = None
        if donate is not None and self.prefix_cache:
            tokens = tuple(donate)[:int(self.pos[row])]
        self.allocator.free_table(rid, donate_tokens=tokens)
        self._n_shared.pop(rid, None)
        self.owner[row] = None
        self.tables_np[row, :] = 0
        self._free_rows.append(row)
        self.evictions += 1
        return rid

    # ------------------------------------------------------------ caches
    def _note_page_write(self, page: int) -> None:
        """One physical page programmed (prefill scatter, staged install,
        or COW copy) — the KV-plane analogue of a weight install."""
        self.kv_page_writes += 1
        self.kv_bytes_written += self.page_bytes
        if self.wear is not None:
            self.wear.record(page)

    def install(self, row: int, one_caches: Any, first_token: int,
                tokens: Tuple[int, ...]) -> None:
        """Scatter a freshly prefilled batch-1 cache into this row's
        non-shared pages (shared prefix pages already hold identical K/V),
        publish the pages for future sharing, and arm decode state."""
        rid = self.owner[row]
        table = self.allocator.tables[rid]
        self.kv_page_writes_avoided += self._n_shared[rid]
        for i in range(self._n_shared[rid], len(table)):
            self.caches = self._write(self.caches, one_caches,
                                      jnp.int32(i), jnp.int32(table[i]))
            self._note_page_write(table[i])
        self.allocator.register(rid, tuple(tokens))
        self.pos[row] = len(tokens)
        self.last_token[row] = first_token

    # --------------------------------------------- chunked-prefill staging
    def stage(self, rid: int, tokens: Tuple[int, ...]) -> Optional[int]:
        """Chunked-prefill admission: claim a decode row and open a
        chunk-granular page reservation (shared prefix pages refcounted now,
        fresh pages reserved per chunk via grow()).  The row's device page
        table stays aimed at the scratch page until finish_stage — the
        batched decode step may write junk through this row meanwhile, and
        it must land in the scratch page, not in reserved real pages."""
        if not self._free_rows:
            return None
        n_shared = self.allocator.begin_table(rid, tuple(tokens))
        row = self._free_rows.popleft()
        self.owner[row] = rid
        self._n_shared[rid] = n_shared
        self.tables_np[row, :] = 0
        self.pos[row] = 0
        self.last_token[row] = 0
        return row

    def grow(self, rid: int, n_blocks: int) -> bool:
        """Reserve pages for the next prefill chunk; False = pool exhausted
        (the engine preempts the prefill, staging intact)."""
        return self.allocator.grow_table(rid, n_blocks)

    # --------------------------------------------------- prefix-cache skip
    def covered_tokens(self, rid: int, n_tokens: int) -> int:
        """Prompt tokens of rid covered by its shared (cached or live)
        prefix pages — the ceiling for chunk-skip.  An exact-tuple tail
        match shares a partial page, so the cover is capped at the prompt
        itself."""
        return min(self._n_shared.get(rid, 0) * self.page_size, n_tokens)

    def load_prefix(self, rid: int, staging: Any, n_tokens: int) -> Any:
        """Seed a staging cache with rid's shared prefix pages covering the
        first `n_tokens` positions: every page overlapping [0, n_tokens)
        is gathered whole.  A sub-page boundary is safe — the tail page is
        either a full shared page or an exact-tuple match of the prompt's
        own tail, so every gathered position < covered holds the donor's
        valid K/V, and positions >= n_tokens are recomputed (overwritten)
        by the next chunk anyway.  Returns the rebound (donated)
        staging."""
        table = self.allocator.tables[rid]
        n_blocks = -(-n_tokens // self.page_size)
        assert n_blocks <= self._n_shared.get(rid, 0), (
            "load_prefix beyond the shared prefix")
        for i in range(n_blocks):
            staging = self._read(staging, self.caches,
                                 jnp.int32(i), jnp.int32(table[i]))
        return staging

    def finish_stage(self, row: int, staging: Any, first_token: int,
                     tokens: Tuple[int, ...]) -> None:
        """Last chunk done: scatter the staged K/V into the reserved
        non-shared pages (shared prefix pages already hold identical
        values), publish the prefix, point the row's device table at the
        real pages, and arm decode state."""
        rid = self.owner[row]
        table = self.allocator.tables[rid]
        assert len(table) == self.blocks_for(len(tokens)), (
            "finish_stage before the table covered the prompt")
        self.kv_page_writes_avoided += self._n_shared[rid]
        for i in range(self._n_shared[rid], len(table)):
            self.caches = self._write(self.caches, staging,
                                      jnp.int32(i), jnp.int32(table[i]))
            self._note_page_write(table[i])
        self.allocator.register(rid, tuple(tokens))
        self.tables_np[row, :] = 0
        self.tables_np[row, :len(table)] = table
        self.pos[row] = len(tokens)
        self.last_token[row] = first_token

    def prepare_decode(self, row: int) -> bool:
        """Before a decode step writes this row's token at `pos`: extend the
        table if `pos` crossed into a new block, and COW the target page if
        it is shared.  False when the pool is exhausted (caller preempts)."""
        rid = self.owner[row]
        table = self.allocator.tables[rid]
        block = int(self.pos[row]) // self.page_size
        if block >= self.tables_np.shape[1]:
            return False               # request outgrew the whole pool
        if block == len(table):
            page = self.allocator.extend(rid)
            if page is None:
                return False
            self.tables_np[row, block] = page
            return True
        got = self.allocator.cow(rid, block)
        if got is None:
            return False
        src, dst = got
        if src != dst:
            self.caches = self._copy(self.caches, jnp.int32(src),
                                     jnp.int32(dst))
            self.tables_np[row, block] = dst
            self._note_page_write(dst)
        return True

    def decode_inputs(self):
        """(tokens (R,), pos (R,), tables (R, n_pages)) covering every row;
        inactive rows carry stale state aimed at the scratch page."""
        return (jnp.asarray(self.last_token), jnp.asarray(self.pos),
                jnp.asarray(self.tables_np))

    def advance(self, row: int, token: int) -> None:
        self.pos[row] += 1
        self.last_token[row] = token

    # ------------------------------------------------------------- stats
    def stats(self) -> Dict[str, float]:
        a = self.allocator
        return {
            "kv_pages_total": float(a.n_pages),
            "kv_pages_used": float(a.n_used),
            "kv_page_occupancy": a.occupancy(),
            "kv_pages_allocated": float(a.pages_allocated),
            "kv_shared_page_hits": float(a.shared_hits),
            "kv_cow_copies": float(a.cow_copies),
            "kv_prefix_cached_pages": float(a.tree.n_cached),
            "kv_prefix_evictions": float(a.tree.evictions),
            "kv_page_writes": float(self.kv_page_writes),
            "kv_bytes_written": float(self.kv_bytes_written),
            "kv_page_writes_avoided": float(self.kv_page_writes_avoided),
            "kv_pages_retired": float(a.pages_retired),
        }
