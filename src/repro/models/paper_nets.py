"""The paper's evaluation set (§VI): VGG-16, ResNet-50, DenseNet-161,
BERT-Base and BERT-Large as ARAS layer graphs.

Only weight-bearing layers occupy crossbars (CONV/FC, Fig 3); pooling,
normalization and non-linearities run on the SFU and are folded into the
producing layer's output.  BERT's activation×activation attention matmuls
(QKᵀ, AV) have no stationary weights and therefore cannot map to ReRAM
crossbars; like prior PUM work the graphs contain the six weight projections
per encoder layer (the paper reports BERT sees no replication speedup —
consistent with an FC-only mapping).

Weights: pretrained checkpoints are not available offline, so INT8 code
distributions are synthesized per layer — a bell-shaped body with a small
outlier tail (which stretches the quantization range and concentrates codes,
as in real post-training-quantized DNNs) and per-layer mean jitter matching
the spread of the paper's Fig 11.  All generators are seeded.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Tuple

import numpy as np

from repro.core.layer_graph import LayerGraph, LayerNode, conv, fc


# ---------------------------------------------------------------- VGG-16
def vgg16() -> LayerGraph:
    cfg = [
        (3, 64, 224), (64, 64, 224),
        (64, 128, 112), (128, 128, 112),
        (128, 256, 56), (256, 256, 56), (256, 256, 56),
        (256, 512, 28), (512, 512, 28), (512, 512, 28),
        (512, 512, 14), (512, 512, 14), (512, 512, 14),
    ]
    layers = [
        conv(f"conv{i+1}", cin, cout, 3, hw) for i, (cin, cout, hw) in enumerate(cfg)
    ]
    layers += [
        fc("fc6", 512 * 7 * 7, 4096),
        fc("fc7", 4096, 4096),
        fc("fc8", 4096, 1000),
    ]
    return LayerGraph("VGG-16", layers)


# ---------------------------------------------------------------- ResNet-50
def resnet50() -> LayerGraph:
    layers: List[LayerNode] = [conv("conv1", 3, 64, 7, 112, stride=2, ih=224, iw=224)]
    stage_cfg = [  # (blocks, mid_channels, out_channels, spatial)
        (3, 64, 256, 56),
        (4, 128, 512, 28),
        (6, 256, 1024, 14),
        (3, 512, 2048, 7),
    ]
    cin = 64
    for si, (blocks, mid, cout, hw) in enumerate(stage_cfg):
        for b in range(blocks):
            p = f"s{si+2}b{b+1}"
            layers.append(conv(f"{p}.c1", cin, mid, 1, hw))
            layers.append(conv(f"{p}.c2", mid, mid, 3, hw))
            layers.append(conv(f"{p}.c3", mid, cout, 1, hw))
            if b == 0:  # projection shortcut
                layers.append(conv(f"{p}.proj", cin, cout, 1, hw))
            cin = cout
    layers.append(fc("fc", 2048, 1000))
    return LayerGraph("ResNet-50", layers)


# ---------------------------------------------------------------- DenseNet-161
def densenet161() -> LayerGraph:
    growth, init = 48, 96
    block_cfg = [(6, 56), (12, 28), (36, 14), (24, 7)]
    layers: List[LayerNode] = [conv("conv0", 3, init, 7, 112, stride=2, ih=224, iw=224)]
    ch = init
    for bi, (reps, hw) in enumerate(block_cfg):
        for r in range(reps):
            p = f"d{bi+1}l{r+1}"
            layers.append(conv(f"{p}.b", ch, 4 * growth, 1, hw))      # bottleneck 1×1
            layers.append(conv(f"{p}.c", 4 * growth, growth, 3, hw))  # 3×3
            ch += growth
        if bi < len(block_cfg) - 1:  # transition: 1×1 halving + pool
            layers.append(conv(f"t{bi+1}", ch, ch // 2, 1, hw))
            ch //= 2
    layers.append(fc("fc", ch, 1000))
    return LayerGraph("DenseNet-161", layers)


# ---------------------------------------------------------------- BERT
def _bert(name: str, n_layers: int, d: int, ff: int, seq: int = 128) -> LayerGraph:
    layers: List[LayerNode] = []
    for i in range(n_layers):
        p = f"L{i}"
        for proj in ("q", "k", "v", "o"):
            layers.append(fc(f"{p}.{proj}", d, d, tokens=seq))
        layers.append(fc(f"{p}.ff1", d, ff, tokens=seq))
        layers.append(fc(f"{p}.ff2", ff, d, tokens=seq))
    layers.append(fc("pooler", d, d, tokens=1))
    return LayerGraph(name, layers)


def bert_base() -> LayerGraph:
    return _bert("BERT-Base", 12, 768, 3072)


def bert_large() -> LayerGraph:
    return _bert("BERT-Large", 24, 1024, 4096)


PAPER_NETS: Dict[str, Callable[[], LayerGraph]] = {
    "vgg16": vgg16,
    "resnet50": resnet50,
    "densenet161": densenet161,
    "bert_base": bert_base,
    "bert_large": bert_large,
}


def build_net(name: str) -> LayerGraph:
    return PAPER_NETS[name]()


def synth_layer_codes(
    graph: LayerGraph,
    seed: int = 0,
    max_samples: int = 1_000_000,
    mean_jitter: float = 0.8,
    outlier_frac: float = 0.005,
    outlier_scale: float = 6.0,
) -> List[Tuple[str, np.ndarray]]:
    """Seeded synthetic INT8 weight codes per layer (see module docstring).

    The simulator consumes code *distributions*; sampling is capped at
    ``max_samples`` per layer, which leaves the per-cell histograms
    statistically indistinguishable from the full tensor.
    """
    rng = np.random.default_rng(seed)
    out: List[Tuple[str, np.ndarray]] = []
    for layer in graph.layers:
        n = min(layer.weights, max_samples)
        sigma = float(np.sqrt(2.0 / layer.kernel_volume))
        mu = float(rng.uniform(-mean_jitter, mean_jitter)) * sigma
        w = rng.normal(mu, sigma, size=n)
        n_out = int(n * outlier_frac)
        if n_out:
            idx = rng.choice(n, size=n_out, replace=False)
            w[idx] = rng.normal(mu, outlier_scale * sigma, size=n_out)
        lo, hi = w.min(), w.max()
        scale = max(hi - lo, 1e-8) / 255.0
        codes = np.clip(np.round((w - lo) / scale), 0, 255).astype(np.uint8)
        out.append((layer.name, codes))
    return out
