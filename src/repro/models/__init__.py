"""Layer graphs for the paper's DNN set and the assigned LM architectures."""
from repro.models.paper_nets import PAPER_NETS, build_net, synth_layer_codes

__all__ = ["PAPER_NETS", "build_net", "synth_layer_codes"]
