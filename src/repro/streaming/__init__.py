"""ARAS-on-TPU: layer-streaming execution with delta-encoded weight installs.

The paper's machine writes layer weights into a limited crossbar pool while
computing earlier layers; here the pool is a device-HBM weight arena and the
writes are host→device DMA of INT8 deltas (DESIGN.md §2, Pillar B).
"""
from repro.streaming.plan import StreamPlan, build_stream_plan, TpuLinkModel
from repro.streaming.delta import QuantizedStore, delta_bytes
from repro.streaming.executor import StreamingExecutor

__all__ = [
    "StreamPlan", "build_stream_plan", "TpuLinkModel",
    "QuantizedStore", "delta_bytes", "StreamingExecutor",
]
