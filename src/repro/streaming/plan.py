"""Static streaming schedule: the ARAS offline scheduler retargeted at a
device-HBM weight arena.

Resource mapping (DESIGN.md §2):
    crossbar rows  → arena slots (fixed-size HBM bins)
    ReRAM row write→ host→device DMA of a layer's INT8 (delta) stream
    write latency  → bytes / dma_bw  (+ fixed launch latency)
    compute latency→ per-layer roofline max(FLOPs/peak, bytes/hbm_bw)

The wave logic is the paper's: compute layer-by-layer; whenever slots free
up, Algorithm 1 (`repro.core.replication.plan_writes`) decides which coming
layers to install, replicated if they are compute-bound relative to the next
wave's install latency WL.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence

from repro.core.replication import LayerCost, plan_writes


@dataclasses.dataclass(frozen=True)
class TpuLinkModel:
    """v5e-class chip for planning purposes."""

    peak_flops: float = 197e12          # bf16 (INT8 via MXU ≈ 2× — conservative)
    hbm_bw: float = 819e9
    dma_bw: float = 100e9               # host→device per chip (PCIe/offload)
    dma_latency_s: float = 50e-6


@dataclasses.dataclass(frozen=True)
class InstallCostModel:
    """Wire bytes → install latency, shared between the static StreamPlan
    (continuous seconds) and the serving engine's InstallPipeline (integer
    ticks — one tick is the DMA work a single decode step can hide).

    The two views model the same link: `install_s` is the StreamPlan's
    bandwidth + fixed-launch-latency cost, `ticks_for` quantizes the same
    stream into per-step budget units so a simulated-time engine can account
    overlap without a device clock."""

    bytes_per_s: float = 100e9
    latency_s: float = 50e-6
    bytes_per_tick: int = 1 << 16

    def install_s(self, wire_bytes: float, replication: int = 1) -> float:
        return wire_bytes * replication / self.bytes_per_s + self.latency_s

    def ticks_for(self, wire_bytes: int) -> int:
        """Whole install ticks for a wire stream (min 1: even a fully
        skipped delta pays the launch latency)."""
        per = max(int(self.bytes_per_tick), 1)
        return max(1, -(-int(wire_bytes) // per))

    @classmethod
    def from_link(cls, link: "TpuLinkModel",
                  bytes_per_tick: int = 1 << 16) -> "InstallCostModel":
        return cls(bytes_per_s=link.dma_bw, latency_s=link.dma_latency_s,
                   bytes_per_tick=bytes_per_tick)


@dataclasses.dataclass(frozen=True)
class StreamLayer:
    name: str
    bytes_int8: int
    flops_per_token: float
    tokens: int

    def compute_s(self, link: TpuLinkModel, replication: int = 1) -> float:
        flops = self.flops_per_token * self.tokens / max(replication, 1)
        return max(flops / link.peak_flops, self.bytes_int8 / link.hbm_bw)


@dataclasses.dataclass(frozen=True)
class StreamEvent:
    kind: str              # 'install' | 'compute'
    layer: int
    t_start: float
    t_end: float
    slots: int
    replication: int = 1


@dataclasses.dataclass
class StreamPlan:
    layers: Sequence[StreamLayer]
    events: List[StreamEvent]
    slot_bytes: int
    n_slots: int
    makespan_s: float
    serial_makespan_s: float     # naive: install → compute → install …

    @property
    def overlap_speedup(self) -> float:
        return self.serial_makespan_s / self.makespan_s

    def installs(self) -> List[StreamEvent]:
        return [e for e in self.events if e.kind == "install"]


def build_stream_plan(
    layers: Sequence[StreamLayer],
    hbm_weight_budget_bytes: int,
    link: TpuLinkModel = TpuLinkModel(),
    slot_bytes: Optional[int] = None,
    replication: bool = True,
    cost_model: Optional[InstallCostModel] = None,
) -> StreamPlan:
    if cost_model is None:
        cost_model = InstallCostModel.from_link(link)
    if slot_bytes is None:
        slot_bytes = max(l.bytes_int8 for l in layers)
        slot_bytes = max(slot_bytes // 4, 1)  # 4 sub-slots of the biggest layer
    n_slots = max(hbm_weight_budget_bytes // slot_bytes, 1)

    def slots_of(l: StreamLayer) -> int:
        return max(math.ceil(l.bytes_int8 / slot_bytes), 1)

    if max(slots_of(l) for l in layers) > n_slots:
        raise ValueError("arena too small for the largest layer; "
                         "increase budget or shard the layer")

    secs = 1e6  # plan in microseconds to keep numbers O(1)
    costs = [
        LayerCost(
            base_rows=slots_of(l),
            compute_cycles=l.compute_s(link) * secs,
            max_replication=8 if replication else 1,
            write_dma_cycles=cost_model.install_s(l.bytes_int8) * secs,
        )
        for l in layers
    ]

    def wl(idx: int) -> float:
        if idx >= len(layers):
            return float("inf")
        return costs[idx].write_dma_cycles

    events: List[StreamEvent] = []
    free = n_slots
    dma_free = 0.0
    ready = {}
    slots_held = {}
    repl = {}
    w = 0
    t = 0.0

    def issue(now: float) -> None:
        nonlocal w, free, dma_free
        while w < len(layers) and free > 0:
            items = plan_writes(free, w, costs, wl, replication_enabled=replication)
            if not items:
                return
            progressed = False
            for it in items:
                if it.fraction < 1.0:
                    return  # partial installs not supported: slot granularity
                l = layers[it.layer_idx]
                start = max(now, dma_free)
                dur = cost_model.install_s(l.bytes_int8, it.replication)
                end = start + dur
                dma_free = end
                free -= it.rows
                ready[it.layer_idx] = end
                slots_held[it.layer_idx] = it.rows
                repl[it.layer_idx] = it.replication
                events.append(StreamEvent("install", it.layer_idx, start, end,
                                          it.rows, it.replication))
                w = it.layer_idx + 1
                progressed = True
            if not progressed:
                return

    issue(0.0)
    for i, l in enumerate(layers):
        if i not in ready:
            issue(t)
        if i not in ready:
            raise RuntimeError(f"streaming deadlock at layer {i}")
        start = max(t, ready[i])
        dur = l.compute_s(link, repl.get(i, 1))
        end = start + dur
        events.append(StreamEvent("compute", i, start, end, slots_held[i],
                                  repl.get(i, 1)))
        free += slots_held[i]
        t = end
        issue(t)

    makespan = t
    # Naive (Fig 7) reference: strictly serial install → compute.
    serial = 0.0
    for l in layers:
        serial += cost_model.install_s(l.bytes_int8)
        serial += l.compute_s(link)
    return StreamPlan(layers, events, slot_bytes, n_slots, makespan, serial)
