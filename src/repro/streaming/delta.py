"""Delta-encoded INT8 weight installs with §V-C mean-centering.

Host side keeps every layer's weights as uint8 codes (plus dequant params).
Installing layer Y into an arena slot holding layer X ships
``delta = codes_Y - codes_X`` (int16 host-side, int8 stream after the cell
decomposition); cells whose 2-bit planes are equal are skipped entirely via
a run-length skip list, so bytes-on-wire track the paper's pulse counts.

The §V-C re-encoding (shift every layer's code mean to a common Center,
compensated through the zero point — `repro.core.weight_reuse`) maximizes
equal MSB cells across layers and therefore the skip ratio.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.weight_reuse import encode_network
from repro.xbar.cells import CELLS_PER_WEIGHT


def _cells(codes: np.ndarray) -> np.ndarray:
    c = codes.astype(np.int16).reshape(-1, 1)
    shifts = np.arange(CELLS_PER_WEIGHT) * 2
    return (c >> shifts) & 0x3


def flip_counts(old: Optional[np.ndarray], new: np.ndarray, *,
                skip_equal: bool = True) -> Tuple[int, int]:
    """(cells programmed, programming pulses) to put `new` codes into a
    region holding `old` (None = erased region: every cell at level 0; a
    `new` longer than `old` programs its tail from erased too).

    skip_equal=True is the §V-C device write: 2-bit planes whose level is
    unchanged are skipped entirely (0 pulses), and a changed cell costs
    |Δ level| incremental SET/RESET pulses (`repro.xbar.cells.pulse_count`,
    the paper's Fig. 13 writing-activity metric).  skip_equal=False is the
    baseline programmer: every cell is rewritten, an unchanged level still
    costing its one write/verify pulse."""
    cn = _cells(new)
    if old is None or old.size == 0:
        d = cn
    else:
        n = min(old.size, new.size)
        d = cn.copy()
        d[:n] -= _cells(old[:n])
    d = np.abs(d)
    if skip_equal:
        return int(np.count_nonzero(d)), int(d.sum())
    return int(d.size), int(np.maximum(d, 1).sum())


def delta_bytes(old: np.ndarray, new: np.ndarray) -> Tuple[int, float]:
    """Bytes-on-wire for an entropy-coded cell-delta stream + skip ratio.

    The install ships per-cell level deltas in [-3, 3].  A range coder on
    that stream achieves the empirical entropy H(Δ) bits/cell (+ a 16-byte
    frequency table); mean-centering (§V-C) concentrates Δ at 0, which is
    exactly what shrinks H — the information-theoretic counterpart of
    skipped ReRAM pulses.  RLE framing was measured strictly worse on
    fragmented skip patterns (isolated equal cells cost a run token each);
    see EXPERIMENTS.md §Perf iteration 3."""
    co, cn = _cells(old), _cells(new)
    delta = (cn - co).reshape(-1)
    n = delta.size
    counts = np.bincount(delta + 3, minlength=7).astype(np.float64)
    probs = counts[counts > 0] / n
    entropy_bits = float(-(probs * np.log2(probs)).sum())
    payload = int(np.ceil(n * entropy_bits / 8.0)) + 16
    skip = float(counts[3] / n)  # Δ == 0
    return payload, skip


@dataclasses.dataclass
class LayerWeights:
    """One layer's quantized tensors, flattened into a single code vector for
    transfer accounting plus per-tensor views for compute."""

    name: str
    codes: np.ndarray                 # uint8, concatenated
    shapes: List[Tuple[int, ...]]
    sizes: List[int]
    scales: List[np.ndarray]
    zero_points: List[np.ndarray]     # offset-compensated (Eq. 7)
    offset: int = 0

    def tensor(self, i: int) -> np.ndarray:
        start = sum(self.sizes[:i])
        return self.codes[start:start + self.sizes[i]].reshape(self.shapes[i])

    def dequant(self, i: int) -> np.ndarray:
        t = self.tensor(i).astype(np.float32)
        return (t - self.zero_points[i]) * self.scales[i]


class QuantizedStore:
    """Host-resident quantized model with cross-layer re-encoding.

    ``offset_groups`` (optional, one label per layer) pools the §V-C offset
    decision: all layers sharing a label get ONE offset computed from their
    pooled codes.  A multi-tenant store groups aligned layers of model
    variants this way — per-layer offsets would shift near-identical tenant
    copies by slightly different amounts (their code means differ by
    rounding), turning a near-zero delta stream into a uniform ±1 shift of
    every cell and destroying the cross-tenant reuse it exists to enable.
    """

    def __init__(self, layers: Sequence[Tuple[str, List[np.ndarray]]],
                 reuse: bool = True, max_clip_rate: float = 4e-3,
                 offset_groups: Optional[Sequence[object]] = None):
        # Quantize each tensor per-tensor (uint8 affine).
        self.layers: List[LayerWeights] = []
        concat_codes = []
        for name, tensors in layers:
            codes, shapes, sizes, scales, zps = [], [], [], [], []
            for w in tensors:
                lo, hi = float(w.min()), float(w.max())
                scale = max(hi - lo, 1e-8) / 255.0
                zp = -lo / scale
                c = np.clip(np.round(w / scale + zp), 0, 255).astype(np.uint8)
                codes.append(c.reshape(-1))
                shapes.append(w.shape)
                sizes.append(w.size)
                scales.append(np.float32(scale))
                zps.append(np.float32(zp))
            cat = np.concatenate(codes) if codes else np.zeros(0, np.uint8)
            self.layers.append(LayerWeights(name, cat, shapes, sizes, scales, zps))
            concat_codes.append((name, cat))

        self.center: Optional[int] = None
        if reuse:
            if offset_groups is None:
                encs, center = encode_network(concat_codes, enabled=True,
                                              max_clip_rate=max_clip_rate)
                offsets = [e.offset for e in encs]
            else:
                assert len(offset_groups) == len(self.layers)
                groups = list(dict.fromkeys(offset_groups))  # stable order
                # Subsample members before pooling: offsets only need group
                # means/histograms (which converge long before 256k samples)
                # and a full concatenation would transiently duplicate the
                # whole multi-tenant code store.
                cap = 1 << 18
                pooled = []
                for g in groups:
                    member = [cat[::max(1, cat.size // cap)]
                              for (_, cat), gg in zip(concat_codes,
                                                      offset_groups)
                              if gg == g and cat.size]
                    pooled.append((str(g), np.concatenate(member)
                                   if member else np.zeros(1, np.uint8)))
                encs, center = encode_network(pooled, enabled=True,
                                              max_clip_rate=max_clip_rate)
                off_of = {g: e.offset for g, e in zip(groups, encs)}
                # Per-member accuracy guard: encode_network only checked the
                # pooled clip rate; a member sitting near the code extremes
                # could clip far above it.  Zero the WHOLE group's offset
                # (not just the member) so aligned tenants stay aligned.
                worst = {g: 0.0 for g in groups}
                for (_, cat), g in zip(concat_codes, offset_groups):
                    off = off_of[g]
                    if cat.size and off:
                        clipped = (np.count_nonzero(cat > 255 - off)
                                   if off > 0 else
                                   np.count_nonzero(cat < -off))
                        worst[g] = max(worst[g], clipped / cat.size)
                off_of = {g: (0 if worst[g] > max_clip_rate else o)
                          for g, o in off_of.items()}
                offsets = [off_of[g] for g in offset_groups]
            self.center = center
            for lw, off in zip(self.layers, offsets):
                if off:
                    shifted = np.clip(lw.codes.astype(np.int32) + off,
                                      0, 255).astype(np.uint8)
                    lw.codes = shifted
                    lw.offset = off
                    # Eq. 7: compensate through the zero point.
                    lw.zero_points = [zp + off for zp in lw.zero_points]

    def install_cost(self, resident: Optional[int], incoming: int
                     ) -> Tuple[int, float]:
        """(bytes-on-wire, skip ratio) to put layer `incoming` into a slot
        currently holding `resident` (None = cold slot → full stream)."""
        new = self.layers[incoming].codes
        if resident is None:
            return new.size, 0.0
        old = self.layers[resident].codes
        n = min(old.size, new.size)
        if n == 0:
            return new.size, 0.0
        b, skip = delta_bytes(old[:n], new[:n])
        return b + (new.size - n), skip

    def install_flips(self, resident: Optional[int], incoming: int, *,
                      skip_equal: bool = True) -> Tuple[int, int]:
        """(cells programmed, programming pulses) the DEVICE spends putting
        layer `incoming` into a slot holding `resident` (None = cold slot,
        programmed from erased).  This is the physical-write counterpart of
        `install_cost` and is independent of the wire encoding: even when
        the raw code stream ships (delta entropy exceeded 2 bits/cell), a
        skip_equal programmer still read-verifies and skips equal 2-bit
        planes.  skip_equal=False models the no-reuse baseline that
        rewrites every cell."""
        new = self.layers[incoming].codes
        old = None if resident is None else self.layers[resident].codes
        return flip_counts(old, new, skip_equal=skip_equal)
