"""Layer-streaming executor: run a model whose weights do not fit the device
weight arena, overlapping each layer's compute with the delta-encoded
install of upcoming layers (paper Fig 8, DMA edition).

Mechanics:
  * big tensors (ndim ≥ 2) of each block are quantized to uint8 codes in a
    host `QuantizedStore` (with §V-C re-encoding); small tensors (norm
    scales, biases) stay fp32 and permanently device-resident;
  * a slot's occupant is updated by shipping ``delta = (new − old) mod 256``
    — one byte per weight on the demo path, while the *accounted* wire bytes
    use the 2-bit-cell skip-list stream (`delta.delta_bytes`), the TPU
    analogue of skipped ReRAM pulses;
  * installs are issued ahead of use (`jax.device_put` is async), compute of
    layer i runs while layers i+1… transfer — the double-buffering the
    static `StreamPlan` prescribes;
  * every compute is a jitted per-layer function that dequantizes the code
    vector (Eq. 7 zero-point compensation folded in) and applies the block.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.nn.config import ModelConfig
from repro.nn.layers import rmsnorm, unembed
from repro.nn.transformer import apply_block
from repro.streaming.delta import QuantizedStore
from repro.streaming.plan import (InstallCostModel, StreamLayer, StreamPlan,
                                  TpuLinkModel, build_stream_plan)

QUANT_MIN_SIZE = 1024  # tensors smaller than this stay fp32-resident


def _split_block_params(bp: Any) -> Tuple[List[np.ndarray], Any, List[bool]]:
    """Flatten a block's params into (big tensors, treedef, is_quantized)."""
    leaves, treedef = jax.tree_util.tree_flatten(bp)
    big = [np.asarray(l, np.float32) for l in leaves
           if l.ndim >= 2 and l.size >= QUANT_MIN_SIZE]
    flags = [l.ndim >= 2 and l.size >= QUANT_MIN_SIZE for l in leaves]
    return big, treedef, flags


@dataclasses.dataclass
class InstallStats:
    raw_bytes: int = 0
    wire_bytes: int = 0
    installs: int = 0
    skips: float = 0.0
    modeled_s: float = 0.0   # cost-model install time (the latency overlap hides)

    @property
    def mean_skip(self) -> float:
        return self.skips / max(self.installs, 1)


class StreamingExecutor:
    def __init__(self, params: Any, cfg: ModelConfig, *,
                 arena_slots: int = 2, reuse: bool = True,
                 link: TpuLinkModel = TpuLinkModel(), plan_tokens: int = 1):
        from repro.nn.transformer import stack_plan
        blocks = []
        for seg_params, (start, length, scanned) in zip(
                params["stack"]["segments"], stack_plan(cfg)):
            if scanned:
                blocks.extend(
                    jax.tree.map(lambda a, i=i: np.asarray(a[i]), seg_params)
                    for i in range(length))
            else:
                blocks.append(seg_params)
        self.cfg = cfg
        self.n_layers = len(blocks)
        self.arena_slots = arena_slots

        self.treedefs, self.flags, self.small, metas = [], [], [], []
        store_input = []
        for i, bp in enumerate(blocks):
            big, treedef, flags = _split_block_params(bp)
            leaves = jax.tree_util.tree_flatten(bp)[0]
            small = [jnp.asarray(l) for l, f in zip(leaves, flags) if not f]
            self.treedefs.append(treedef)
            self.flags.append(flags)
            self.small.append(small)
            store_input.append((f"L{i}", big))
        self.store = QuantizedStore(store_input, reuse=reuse)

        # resident fp32 top-level params
        self.embedding = jax.tree.map(jnp.asarray, params["embedding"])
        self.final_norm = jax.tree.map(jnp.asarray, params["final_norm"])

        # device arena: slot -> (layer_id | None, device uint8 codes)
        self.slots: List[Tuple[Optional[int], Optional[jax.Array]]] = [
            (None, None) for _ in range(arena_slots)]
        self.layer_slot: Dict[int, int] = {}
        self.stats = InstallStats()

        # plan
        tokens = plan_tokens
        stream_layers = [
            StreamLayer(
                name=f"L{i}",
                bytes_int8=max(int(self.store.layers[i].codes.size), 1),
                flops_per_token=2.0 * float(self.store.layers[i].codes.size),
                tokens=tokens)
            for i in range(self.n_layers)
        ]
        slot_bytes = max(l.bytes_int8 for l in stream_layers)
        self.cost_model = InstallCostModel.from_link(link)
        self.plan: StreamPlan = build_stream_plan(
            stream_layers, hbm_weight_budget_bytes=arena_slots * slot_bytes,
            link=link, slot_bytes=slot_bytes, replication=False,
            cost_model=self.cost_model)

        self._compute_fns: Dict[int, Any] = {}

    # ------------------------------------------------------------ install
    def _pick_slot(self, layer: int) -> int:
        for s, (occ, _) in enumerate(self.slots):
            if occ is None:
                return s
        # evict the resident layer furthest in the past (lowest id < layer)
        occupants = [(occ, s) for s, (occ, _) in enumerate(self.slots)]
        return min(occupants)[1]

    def install(self, layer: int) -> None:
        if layer in self.layer_slot:
            return
        s = self._pick_slot(layer)
        occ, codes_dev = self.slots[s]
        new_codes = self.store.layers[layer].codes
        wire, skip = self.store.install_cost(occ, layer)
        self.stats.raw_bytes += new_codes.size
        self.stats.wire_bytes += wire
        self.stats.installs += 1
        self.stats.skips += skip
        self.stats.modeled_s += self.cost_model.install_s(wire)
        if occ is None or codes_dev is None or codes_dev.size != new_codes.size:
            codes_dev = jax.device_put(new_codes)  # cold install: full stream
        else:
            old_codes = self.store.layers[occ].codes
            n = min(old_codes.size, new_codes.size)
            delta = (new_codes[:n].astype(np.int16)
                     - old_codes[:n].astype(np.int16)) % 256
            delta_dev = jax.device_put(delta.astype(np.uint8))
            from repro.kernels.delta_apply.ops import apply_delta
            codes_dev = apply_delta(codes_dev[:n], delta_dev)
            self.layer_slot.pop(occ, None)
        self.slots[s] = (layer, codes_dev)
        self.layer_slot[layer] = s

    # ------------------------------------------------------------ compute
    def _compute_fn(self, layer: int):
        if layer in self._compute_fns:
            return self._compute_fns[layer]
        cfg = self.cfg
        lw = self.store.layers[layer]
        treedef = self.treedefs[layer]
        flags = self.flags[layer]
        sizes, shapes = lw.sizes, lw.shapes
        scales = [float(s) for s in lw.scales]
        zps = [float(z) for z in lw.zero_points]

        def fn(codes: jax.Array, small: List[jax.Array], x: jax.Array):
            tensors = []
            off = 0
            for sz, shp, sc, zp in zip(sizes, shapes, scales, zps):
                c = jax.lax.dynamic_slice_in_dim(codes, off, sz)
                t = (c.astype(jnp.float32) - zp) * sc
                tensors.append(t.reshape(shp).astype(jnp.bfloat16))
                off += sz
            leaves, ti, si = [], 0, 0
            for f in flags:
                if f:
                    leaves.append(tensors[ti]); ti += 1
                else:
                    leaves.append(small[si]); si += 1
            bp = jax.tree_util.tree_unflatten(treedef, leaves)
            y, _, _ = apply_block(bp, x, cfg, layer)
            return y

        jitted = jax.jit(fn)
        self._compute_fns[layer] = jitted
        return jitted

    def forward(self, batch: Dict[str, Any], prefetch: int = 1
                ) -> Tuple[jax.Array, Dict[str, float]]:
        """Full forward pass following the streaming plan."""
        from repro.nn.model import _inputs_to_x
        cfg = self.cfg
        t0 = time.perf_counter()
        x, _ = _inputs_to_x({"embedding": self.embedding}, batch, cfg)
        for i in range(min(prefetch + 1, self.n_layers)):
            self.install(i)
        for i in range(self.n_layers):
            self.install(i)
            codes = self.slots[self.layer_slot[i]][1]
            x = self._compute_fn(i)(codes, self.small[i], x)
            # overlap: kick off upcoming installs while the device computes
            for j in range(i + 1, min(i + 1 + prefetch, self.n_layers)):
                if len(self.layer_slot) < self.arena_slots or j == i + 1:
                    self.install(j)
        x = rmsnorm(self.final_norm, x, cfg.norm_eps)
        logits = unembed(self.embedding, x, cfg)
        logits.block_until_ready()
        wall = time.perf_counter() - t0
        m = {
            "wall_s": wall,
            "raw_bytes": float(self.stats.raw_bytes),
            "wire_bytes": float(self.stats.wire_bytes),
            "mean_skip": self.stats.mean_skip,
            "install_s_model": self.stats.modeled_s,
            "plan_makespan_s": self.plan.makespan_s,
            "plan_serial_s": self.plan.serial_makespan_s,
            "plan_overlap_speedup": self.plan.overlap_speedup,
            "reuse_center": float(self.store.center or 0),
        }
        return logits, m
