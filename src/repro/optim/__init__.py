from repro.optim.adamw import AdamWState, adamw_init, adamw_update
from repro.optim.schedules import cosine, wsd

__all__ = ["AdamWState", "adamw_init", "adamw_update", "cosine", "wsd"]
