"""AdamW, pure JAX, sharded like the parameters (ZeRO: moments inherit the
FSDP/TP sharding of their parameter, so optimizer state is fully sharded).

Parameters may be bf16; moments and the update math are fp32 (no separate
fp32 master copy — the update is computed in fp32 and cast back, which at
these LRs is numerically equivalent and halves optimizer memory)."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class AdamWState:
    step: jax.Array
    mu: Any
    nu: Any


def adamw_init(params: Any) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def adamw_update(
    params: Any,
    grads: Any,
    state: AdamWState,
    lr: jax.Array,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    grad_clip: float = 1.0,
) -> Tuple[Any, AdamWState, Dict[str, jax.Array]]:
    step = state.step + 1
    gnorm = jnp.sqrt(sum(
        jnp.sum(jnp.square(g.astype(jnp.float32)))
        for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-9))

    b1c = 1.0 - b1 ** step.astype(jnp.float32)
    b2c = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v), {"grad_norm": gnorm}
