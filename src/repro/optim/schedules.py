"""LR schedules: cosine and WSD (Warmup-Stable-Decay, MiniCPM §4)."""
from __future__ import annotations

import jax.numpy as jnp


def cosine(step, *, peak_lr: float, warmup: int, total: int, floor: float = 0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = peak_lr * step / jnp.maximum(warmup, 1)
    t = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
    return jnp.where(step < warmup, warm, cos)


def wsd(step, *, peak_lr: float, warmup: int, stable: int, decay: int,
        floor: float = 0.01):
    """MiniCPM's Warmup-Stable-Decay: linear warmup, long flat stage, short
    exponential-ish (here linear-in-log) decay to `floor`·peak."""
    step = jnp.asarray(step, jnp.float32)
    warm = peak_lr * step / jnp.maximum(warmup, 1)
    t = jnp.clip((step - warmup - stable) / jnp.maximum(decay, 1), 0.0, 1.0)
    dec = peak_lr * jnp.exp(jnp.log(floor) * t)
    out = jnp.where(step < warmup, warm,
                    jnp.where(step < warmup + stable, peak_lr, dec))
    return out
