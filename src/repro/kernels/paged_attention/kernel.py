"""Pallas paged-attention decode kernel, TPU-targeted.

Batched single-token decode over a paged KV layout: K/V live in a page
pool (P, page, Hkv, D) and each batch row addresses its sequence through a
page table (B, T) of physical page ids (`repro.serving.paging` builds
both).

Grid: (batch, kv_heads).  Each program holds one row's G grouped query
heads and streams that row's page table with the online-softmax recurrence:
for logical block t it reads the physical page id from the table, gathers
the (page, D) K/V tile out of the pool with a dynamic dslice, masks
positions beyond the row's current position, and folds the tile into the
running (max, denom, acc) — the FlashAttention-2 schedule over a scattered
KV layout.  The fori_loop upper bound is pos // page + 1, so fully-masked
tail blocks are never touched (real work skipping, like the causal bound
in `flash_attention`).

Contract matches `repro.kernels.paged_attention.ref.paged_attention_ref`
(its jnp gather math is the oracle in tests, and mirrors the paged decode
path in `repro.nn.attention`).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1.0e30


def _kernel(q_ref, k_ref, v_ref, tab_ref, pos_ref, o_ref, *, page: int,
            scale: float):
    # q_ref (1, 1, G, D); k/v_ref (P, page, 1, D); tab_ref (1, T);
    # pos_ref (1,); o_ref (1, 1, G, D)
    q = q_ref[0, 0].astype(jnp.float32) * scale          # (G, D)
    G, D = q.shape
    pos = pos_ref[0]
    hi = pos // page + 1                                  # blocks holding
    # positions ≤ pos; everything past is fully masked — skip it.

    def body(t, carry):
        m, l, acc = carry
        pid = tab_ref[0, t]
        # NB: dslice (not a bare int) on the leading axis — interpret-mode
        # discharge rejects scalar int indices in pl.load tuples.
        k = pl.load(k_ref, (pl.dslice(pid, 1), slice(None), pl.dslice(0, 1),
                            slice(None)))[0, :, 0].astype(jnp.float32)
        v = pl.load(v_ref, (pl.dslice(pid, 1), slice(None), pl.dslice(0, 1),
                            slice(None)))[0, :, 0].astype(jnp.float32)
        s = q @ k.T                                       # (G, page)
        kpos = t * page + jax.lax.broadcasted_iota(jnp.int32, (G, page), 1)
        s = jnp.where(kpos <= pos, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[:, None] + p @ v
        return m_new, l_new, acc_new

    m0 = jnp.full((G,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((G,), jnp.float32)
    a0 = jnp.zeros((G, D), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, hi, body, (m0, l0, a0))
    o_ref[0, 0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def paged_attention_pallas(
    q: jax.Array,        # (B, Hkv, G, D) grouped query heads
    k_pool: jax.Array,   # (P, page, Hkv, D)
    v_pool: jax.Array,
    tables: jax.Array,   # (B, T) int32 physical page ids
    pos: jax.Array,      # (B,) int32 current position per row
    *,
    interpret: bool = False,
) -> jax.Array:
    B, Hkv, G, D = q.shape
    P, page = k_pool.shape[:2]
    T = tables.shape[1]
    scale = 1.0 / math.sqrt(D)
    kern = functools.partial(_kernel, page=page, scale=scale)
    return pl.pallas_call(
        kern,
        grid=(B, Hkv),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((P, page, 1, D), lambda b, h: (0, 0, h, 0)),
            pl.BlockSpec((P, page, 1, D), lambda b, h: (0, 0, h, 0)),
            pl.BlockSpec((1, T), lambda b, h: (b, 0)),
            pl.BlockSpec((1,), lambda b, h: (b,)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        interpret=interpret,
    )(q, k_pool, v_pool, tables, pos)
