"""Pure-jnp oracle for paged attention decode (gather + naive softmax,
fp32) — the same math as the paged decode path in `repro.nn.attention`."""
import math

import jax
import jax.numpy as jnp


def paged_attention_ref(q, k_pool, v_pool, tables, pos) -> jax.Array:
    """q (B, H, D); k/v_pool (P, page, Hkv, D); tables (B, T) int32;
    pos (B,) int32.  Returns (B, H, D): one decode step attending over
    positions ≤ pos[b] gathered through each row's page table."""
    B, H, D = q.shape
    P, page, Hkv, _ = k_pool.shape
    T = tables.shape[1]
    G = H // Hkv
    scale = 1.0 / math.sqrt(D)
    kc = k_pool[tables].reshape(B, T * page, Hkv, D)
    vc = v_pool[tables].reshape(B, T * page, Hkv, D)
    qg = q.reshape(B, Hkv, G, D)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg.astype(jnp.float32),
                   kc.astype(jnp.float32)) * scale
    valid = jnp.arange(T * page)[None, :] <= pos[:, None]
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", p, vc.astype(jnp.float32))
    return o.reshape(B, H, D).astype(q.dtype)
