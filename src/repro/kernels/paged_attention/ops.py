"""Wrapper: groups query heads per KV head (GQA stays native — no pool
expansion), with the execution mode plumbed in explicitly.

No `@jax.jit` here: callers (the serving decode step, the kernel tests)
jit the surrounding computation, and `interpret` must stay a trace-time
python constant they control — the old wrapper sniffed
`jax.default_backend()` inside its own jit trace, so an engine could not
pin interpret mode (CI equivalence) or device mode (TPU bench) per
tenant."""
import jax
import jax.numpy as jnp

from repro.kernels.paged_attention.kernel import paged_attention_pallas


def paged_attention(q, k_pool, v_pool, tables, pos, *, interpret=None):
    """q: (B, H, D) one query token per row, heads flat in KV-major order
    (head h serves KV head h // (H/Hkv)); k/v_pool: (P, page, Hkv, D) page
    pools; tables: (B, T) int32 physical page ids; pos: (B,) int32 per-row
    positions.  Returns (B, H, D).

    `interpret=None` resolves to interpret mode off-TPU at call time;
    pass an explicit bool to pin it (the engine's `kernel_interpret`
    knob does).  Raises ValueError instead of silently reshaping on a
    non-divisible head count or accepting a non-int32 page table (a
    float table would truncate physical page ids)."""
    B, H, D = q.shape
    Hkv = k_pool.shape[2]
    if H % Hkv != 0:
        raise ValueError(
            f"paged_attention: {H} query heads are not divisible by "
            f"{Hkv} KV heads — GQA grouping needs H % Hkv == 0")
    if tables.dtype != jnp.int32:
        raise ValueError(
            f"paged_attention: page table dtype {tables.dtype} must be "
            "int32 (physical page ids)")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    qg = q.reshape(B, Hkv, H // Hkv, D)
    o = paged_attention_pallas(qg, k_pool, v_pool, tables,
                               pos.astype(jnp.int32),
                               interpret=bool(interpret))
    return o.reshape(B, H, D)
