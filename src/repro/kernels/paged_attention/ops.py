"""Jitted wrapper: groups query heads per KV head (GQA stays native — no
pool expansion) and picks interpret mode off-TPU."""
import jax
import jax.numpy as jnp

from repro.kernels.paged_attention.kernel import paged_attention_pallas


@jax.jit
def paged_attention(q, k_pool, v_pool, tables, pos):
    """q: (B, H, D) one query token per row; k/v_pool: (P, page, Hkv, D)
    page pools (H a multiple of Hkv); tables: (B, T) int32 physical page
    ids; pos: (B,) int32 per-row positions.  Returns (B, H, D)."""
    B, H, D = q.shape
    Hkv = k_pool.shape[2]
    qg = q.reshape(B, Hkv, H // Hkv, D)
    interpret = jax.default_backend() != "tpu"
    o = paged_attention_pallas(qg, k_pool, v_pool,
                               tables.astype(jnp.int32),
                               pos.astype(jnp.int32), interpret=interpret)
    return o.reshape(B, H, D)
