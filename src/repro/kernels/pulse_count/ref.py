"""Pure-jnp oracle via repro.xbar.cells."""
import jax.numpy as jnp

from repro.xbar.cells import cell_deltas


def pulse_count_ref(old, new):
    d = cell_deltas(old, new)
    return jnp.sum(jnp.abs(d)), jnp.sum((d == 0).astype(jnp.int32))
