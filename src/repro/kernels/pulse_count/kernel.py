"""Pallas kernel: ReRAM writing-activity (pulse) accounting.

For two uint8 code streams (resident, incoming) compute, per block, the
total programming pulses Σ|Δcell| over the four 2-bit cells and the count of
unchanged (skippable) cells.  The offline scheduler uses this to cost
installs; on-device it lets a runtime *measure* the §V-C savings cheaply.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 32 * 1024


def _kernel(old_ref, new_ref, pulses_ref, skips_ref):
    o = old_ref[...].astype(jnp.int32)
    n = new_ref[...].astype(jnp.int32)
    pulses = jnp.zeros((), jnp.int32)
    skips = jnp.zeros((), jnp.int32)
    for c in range(4):
        oc = (o >> (2 * c)) & 0x3
        nc = (n >> (2 * c)) & 0x3
        d = jnp.abs(oc - nc)
        pulses = pulses + jnp.sum(d)
        skips = skips + jnp.sum((d == 0).astype(jnp.int32))
    pulses_ref[0] = pulses
    skips_ref[0] = skips


def pulse_count_pallas(old: jax.Array, new: jax.Array,
                       interpret: bool = False):
    assert old.shape == new.shape and old.dtype == jnp.uint8
    n = old.size
    pad = (-n) % BLOCK
    # Pad both with identical zeros: Δ = 0, counted as skips — corrected below.
    o = jnp.pad(old.reshape(-1), (0, pad))
    w = jnp.pad(new.reshape(-1), (0, pad))
    grid = (o.size // BLOCK,)
    pulses, skips = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((BLOCK,), lambda i: (i,)),
                  pl.BlockSpec((BLOCK,), lambda i: (i,))],
        out_specs=[pl.BlockSpec((1,), lambda i: (i,)),
                   pl.BlockSpec((1,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct(grid, jnp.int32),
                   jax.ShapeDtypeStruct(grid, jnp.int32)],
        interpret=interpret,
    )(o, w)
    total_pulses = jnp.sum(pulses)
    total_skips = jnp.sum(skips) - 4 * pad  # remove padded cells
    return total_pulses, total_skips
