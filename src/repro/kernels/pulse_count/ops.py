import jax

from repro.kernels.pulse_count.kernel import pulse_count_pallas


@jax.jit
def pulse_count(old, new):
    interpret = jax.default_backend() != "tpu"
    return pulse_count_pallas(old, new, interpret=interpret)
