"""Jitted wrapper; folds (B, H) into one grid axis and pads sequences."""
import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_pallas


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk"))
def flash_attention(q, k, v, causal: bool = True, bq: int = 128, bk: int = 128):
    """q: (B, Sq, H, D); k/v: (B, Skv, H, D) (same head count — expand GQA
    before calling).  Returns (B, Sq, H, D)."""
    B, Sq, H, D = q.shape
    Skv = k.shape[1]
    pq, pk = (-Sq) % bq, (-Skv) % bk
    qt = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0))).transpose(0, 2, 1, 3)
    kt = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0))).transpose(0, 2, 1, 3)
    vt = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0))).transpose(0, 2, 1, 3)
    qt = qt.reshape(B * H, Sq + pq, D)
    kt = kt.reshape(B * H, Skv + pk, D)
    vt = vt.reshape(B * H, Skv + pk, D)
    interpret = jax.default_backend() != "tpu"
    o = flash_attention_pallas(qt, kt, vt, causal=causal, bq=bq, bk=bk,
                               interpret=interpret, seq_kv_valid=Skv)
    o = o.reshape(B, H, Sq + pq, D).transpose(0, 2, 1, 3)
    return o[:, :Sq]
