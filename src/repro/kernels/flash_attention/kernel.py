"""Pallas flash attention (causal / full), TPU-targeted.

Grid: (batch·kv_heads·groups, Sq/bq).  Each program streams the KV sequence
in ``bk`` blocks with the online-softmax recurrence, keeping the running
(max, denom, acc) in VMEM — the standard FlashAttention-2 schedule mapped to
MXU tiles.  Causal programs skip KV blocks strictly above the diagonal via
the fori_loop upper bound (real work skipping, unlike the masked XLA path —
this is the kernel's main win at long sequence).

Contract matches `repro.nn.attention.chunked_attention` (its jnp math is the
oracle in tests).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1.0e30


def _kernel(q_ref, k_ref, v_ref, o_ref, *, bq: int, bk: int, causal: bool,
            scale: float, seq_kv: int, seq_kv_valid: int):
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * scale          # (bq, D)
    D = q.shape[-1]

    n_kv = seq_kv // bk
    if causal:
        # process blocks j with j*bk <= (qi+1)*bq - 1
        hi = jnp.minimum(((qi + 1) * bq + bk - 1) // bk, n_kv)
    else:
        hi = n_kv

    def body(j, carry):
        m, l, acc = carry
        # NB: dslice (not a bare int) on the leading axis — interpret-mode
        # discharge rejects scalar int indices in pl.load tuples.
        k = pl.load(k_ref, (pl.dslice(0, 1), pl.dslice(j * bk, bk),
                            slice(None)))[0].astype(jnp.float32)   # (bk, D)
        v = pl.load(v_ref, (pl.dslice(0, 1), pl.dslice(j * bk, bk),
                            slice(None)))[0].astype(jnp.float32)
        s = q @ k.T                                    # (bq, bk)
        qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        ok = kpos < seq_kv_valid
        if causal:
            ok = ok & (kpos <= qpos)
        s = jnp.where(ok, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[:, None] + p @ v
        return m_new, l_new, acc_new

    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    a0 = jnp.zeros((bq, D), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, hi, body, (m0, l0, a0))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,      # (BH, Sq, D)
    k: jax.Array,      # (BH, Skv, D)
    v: jax.Array,
    *,
    causal: bool = True,
    bq: int = 128,
    bk: int = 128,
    interpret: bool = False,
    seq_kv_valid: int = None,
) -> jax.Array:
    BH, Sq, D = q.shape
    Skv = k.shape[1]
    assert Sq % bq == 0 and Skv % bk == 0, "pad sequences to block multiples"
    if seq_kv_valid is None:
        seq_kv_valid = Skv
    scale = 1.0 / math.sqrt(D)
    grid = (BH, Sq // bq)
    kern = functools.partial(_kernel, bq=bq, bk=bk, causal=causal,
                             scale=scale, seq_kv=Skv,
                             seq_kv_valid=seq_kv_valid)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, Skv, D), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, Skv, D), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, D), q.dtype),
        interpret=interpret,
    )(q, k, v)
