"""Pure-jnp oracle for flash attention (naive softmax, fp32)."""
import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, causal: bool = True) -> jax.Array:
    BH, Sq, D = q.shape
    Skv = k.shape[1]
    scale = 1.0 / math.sqrt(D)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.arange(Skv)[None, :] <= jnp.arange(Sq)[:, None]
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
