"""Pure-jnp oracle for delta_apply."""
import jax
import jax.numpy as jnp


def delta_apply_ref(old: jax.Array, delta: jax.Array) -> jax.Array:
    return (old.astype(jnp.int32) + delta.astype(jnp.int32)).astype(jnp.uint8)
