"""Jitted wrapper: Pallas on TPU, interpret mode elsewhere."""
import functools

import jax

from repro.kernels.delta_apply.kernel import delta_apply_pallas


@functools.partial(jax.jit, static_argnames=())
def apply_delta(old: jax.Array, delta: jax.Array) -> jax.Array:
    interpret = jax.default_backend() != "tpu"
    return delta_apply_pallas(old, delta, interpret=interpret)
