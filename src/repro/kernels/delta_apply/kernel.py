"""Pallas kernel: in-place modular delta application to resident INT8 codes.

``new = (old + delta) mod 256`` — the device half of an ARAS weight install
(the ReRAM "pulse train" analogue).  Streaming-friendly: pure elementwise,
one VMEM tile per grid step, unrolled over a flat code vector.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 64 * 1024


def _kernel(old_ref, delta_ref, out_ref):
    # uint8 addition wraps modulo 256 by construction.
    out_ref[...] = old_ref[...] + delta_ref[...]


def delta_apply_pallas(old: jax.Array, delta: jax.Array,
                       interpret: bool = False) -> jax.Array:
    assert old.shape == delta.shape and old.dtype == jnp.uint8
    n = old.size
    pad = (-n) % BLOCK
    o = jnp.pad(old.reshape(-1), (0, pad))
    d = jnp.pad(delta.reshape(-1), (0, pad))
    grid = (o.size // BLOCK,)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((BLOCK,), lambda i: (i,)),
                  pl.BlockSpec((BLOCK,), lambda i: (i,))],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(o.shape, jnp.uint8),
        interpret=interpret,
    )(o, d)
    return out[:n].reshape(old.shape)
