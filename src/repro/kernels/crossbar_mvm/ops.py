"""Jitted wrapper for the crossbar INT8 matmul."""
import functools

import jax

from repro.kernels.crossbar_mvm.kernel import crossbar_mvm_pallas


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def crossbar_mvm(x_codes, w_codes, zp_x, zp_w, scale, bm: int = 128,
                 bn: int = 128):
    interpret = jax.default_backend() != "tpu"
    return crossbar_mvm_pallas(x_codes, w_codes, zp_x, zp_w, scale,
                               bm=bm, bn=bn, interpret=interpret)
