"""Pallas kernel: the crossbar dot-product, TPU-native.

The paper's APU computes ``y = (x_q - zp_x)·(w_q - zp_w)·s_x·s_w`` in the
analog domain with bit-serial activations.  The MXU equivalent is an INT8
matmul with int32 accumulation plus the closed-form zero-point corrections
(Eq. 7) — including the §V-C install Offset, which is folded into ``zp_w``
and therefore costs *nothing* here.

Tiling: grid over (M/bm, N/bn) output tiles with the full K dimension per
tile (our K ≤ 8192 → ≤ 2 MB of VMEM per operand at bm = bn = 128, well
under the ~16 MB VMEM budget and MXU-aligned).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, zpx_ref, zpw_ref, scale_ref, out_ref):
    x = x_ref[...].astype(jnp.int32)           # (bm, K) uint8 codes
    w = w_ref[...].astype(jnp.int32)           # (K, bn)
    acc = jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)
    k = x.shape[1]
    zpx = zpx_ref[0]
    zpw = zpw_ref[0]
    sum_x = jnp.sum(x, axis=1, keepdims=True).astype(jnp.float32)
    sum_w = jnp.sum(w, axis=0, keepdims=True).astype(jnp.float32)
    out = (acc.astype(jnp.float32)
           - zpw * sum_x - zpx * sum_w + k * zpx * zpw) * scale_ref[0]
    out_ref[...] = out


def crossbar_mvm_pallas(
    x_codes: jax.Array,     # (M, K) uint8
    w_codes: jax.Array,     # (K, N) uint8
    zp_x: jax.Array,        # scalar f32
    zp_w: jax.Array,        # scalar f32
    scale: jax.Array,       # scalar f32 = s_x * s_w
    *,
    bm: int = 128,
    bn: int = 128,
    interpret: bool = False,
) -> jax.Array:
    M, K = x_codes.shape
    K2, N = w_codes.shape
    assert K == K2
    pm, pn = (-M) % bm, (-N) % bn
    xp = jnp.pad(x_codes, ((0, pm), (0, 0)))
    wp = jnp.pad(w_codes, ((0, 0), (0, pn)))
    grid = ((M + pm) // bm, (N + pn) // bn)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, K), lambda i, j: (i, 0)),
            pl.BlockSpec((K, bn), lambda i, j: (0, j)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M + pm, N + pn), jnp.float32),
        interpret=interpret,
    )(xp, wp, jnp.atleast_1d(zp_x.astype(jnp.float32)),
      jnp.atleast_1d(zp_w.astype(jnp.float32)),
      jnp.atleast_1d(scale.astype(jnp.float32)))
    return out[:M, :N]
