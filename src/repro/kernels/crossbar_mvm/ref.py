"""Pure-jnp oracle: identical math via repro.xbar.quant.dot_int8."""
import jax
import jax.numpy as jnp

from repro.xbar.quant import QuantParams, dot_int8


def crossbar_mvm_ref(x_codes, w_codes, zp_x, zp_w, scale) -> jax.Array:
    # scale = s_x * s_w; dot_int8 takes them separately — split arbitrarily.
    xq = QuantParams(scale=jnp.asarray(scale, jnp.float32),
                     zero_point=jnp.asarray(zp_x, jnp.float32))
    wq = QuantParams(scale=jnp.asarray(1.0, jnp.float32),
                     zero_point=jnp.asarray(zp_w, jnp.float32))
    return dot_int8(x_codes, w_codes, xq, wq)
