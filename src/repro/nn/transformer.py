"""Block assembly and the layer stack.

Homogeneous stacks (the deep dense/MoE models) run under `jax.lax.scan` over
stacked per-layer parameters with full rematerialization — HLO size stays
O(1) in depth and only block inputs are saved for backward.  Heterogeneous
stacks (hymba's per-layer windows, xlstm's mLSTM/sLSTM mix, deepseek's
leading dense layer) are unrolled; their layer counts are modest.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.nn.attention import attention, attention_specs, init_attention
from repro.nn.config import ModelConfig
from repro.nn.layers import (
    init_mlp,
    init_rmsnorm,
    mlp,
    mlp_specs,
    rmsnorm,
    rmsnorm_specs,
)
from repro.nn.moe import init_moe, moe, moe_specs
from repro.nn.ssm import (
    init_mamba,
    init_mlstm,
    init_slstm,
    mamba,
    mamba_specs,
    mlstm,
    mlstm_specs,
    slstm,
    slstm_specs,
)
from repro.parallel.sharding import shard

Params = Dict[str, Any]


def layer_kind(cfg: ModelConfig, i: int) -> str:
    if cfg.family == "ssm":
        if cfg.slstm_every and (i % cfg.slstm_every == cfg.slstm_every - 1):
            return "slstm"
        return "mlstm"
    if cfg.hybrid_parallel:
        return "hybrid"
    return "attn"


def is_homogeneous(cfg: ModelConfig) -> bool:
    kinds = {layer_kind(cfg, i) for i in range(cfg.n_layers)}
    if len(kinds) > 1:
        return False
    if cfg.sliding_window and cfg.global_layers:
        return False  # static mask structure differs per layer
    moe_flags = set(cfg.layer_is_moe)
    return len(moe_flags) <= 1


# ---------------------------------------------------------------- blocks
def init_block(key, cfg: ModelConfig, i: int) -> Params:
    kind = layer_kind(cfg, i)
    ks = jax.random.split(key, 4)
    p: Params = {"norm1": init_rmsnorm(cfg.d_model)}
    if kind == "mlstm":
        p["cell"] = init_mlstm(ks[0], cfg)
        return p
    if kind == "slstm":
        p["cell"] = init_slstm(ks[0], cfg)
        return p
    p["attn"] = init_attention(ks[0], cfg)
    if kind == "hybrid":
        p["mamba"] = init_mamba(ks[1], cfg)
    p["norm2"] = init_rmsnorm(cfg.d_model)
    if cfg.layer_is_moe[i]:
        p["moe"] = init_moe(ks[2], cfg)
    else:
        p["mlp"] = init_mlp(ks[2], cfg.d_model, cfg.d_ff, cfg.gated)
    return p


def block_specs(cfg: ModelConfig, i: int) -> Params:
    kind = layer_kind(cfg, i)
    s: Params = {"norm1": rmsnorm_specs()}
    if kind == "mlstm":
        s["cell"] = mlstm_specs(cfg)
        return s
    if kind == "slstm":
        s["cell"] = slstm_specs(cfg)
        return s
    s["attn"] = attention_specs(cfg)
    if kind == "hybrid":
        s["mamba"] = mamba_specs(cfg)
    s["norm2"] = rmsnorm_specs()
    if cfg.layer_is_moe[i]:
        s["moe"] = moe_specs(cfg)
    else:
        s["mlp"] = mlp_specs(cfg.gated)
    return s


def apply_block(
    bp: Params,
    x: jax.Array,
    cfg: ModelConfig,
    i: int,
    *,
    positions=None,
    prefix_len: int = 0,
    cache: Optional[Params] = None,
    cache_pos=None,
    make_cache: bool = False,
    cache_len: int = 0,
    page_table=None,
    valid_len=None,
    kernel_backend: str = "xla",
    kernel_interpret: bool = False,
) -> Tuple[jax.Array, Optional[Params], jax.Array]:
    """Returns (x, new_cache, aux_loss).  ``valid_len`` marks how many of a
    chunked-prefill chunk's tokens are real (recurrent layers freeze their
    state past it; attention masks make it irrelevant there)."""
    kind = layer_kind(cfg, i)
    aux = jnp.zeros((), jnp.float32)
    h = rmsnorm(bp["norm1"], x, cfg.norm_eps)

    if kind in ("mlstm", "slstm"):
        fn = mlstm if kind == "mlstm" else slstm
        y, new_cache = fn(bp["cell"], h, cfg, cache=cache,
                          make_cache=make_cache, valid_len=valid_len)
        x = x + y
        x = shard(x, "batch", "sp", None)
        return x, new_cache, aux

    window = cfg.window_for_layer(i)
    attn_cache = cache.get("attn") if cache else None
    y_attn, new_attn_cache = attention(
        bp["attn"], h, cfg, layer_window=window, positions=positions,
        prefix_len=prefix_len, cache=attn_cache, cache_pos=cache_pos,
        make_cache=make_cache, cache_len=cache_len, page_table=page_table,
        kernel_backend=kernel_backend, kernel_interpret=kernel_interpret)

    new_cache: Optional[Params] = None
    if kind == "hybrid":
        mamba_cache = cache.get("mamba") if cache else None
        y_ssm, new_mamba_cache = mamba(bp["mamba"], h, cfg, cache=mamba_cache,
                                       make_cache=make_cache,
                                       valid_len=valid_len)
        # hymba: mean of the two normalized branch outputs
        y = 0.5 * (y_attn + y_ssm)
        if new_attn_cache is not None or new_mamba_cache is not None:
            new_cache = {"attn": new_attn_cache, "mamba": new_mamba_cache}
    else:
        y = y_attn
        if new_attn_cache is not None:
            new_cache = {"attn": new_attn_cache}

    x = x + y
    x = shard(x, "batch", "sp", None)
    h2 = rmsnorm(bp["norm2"], x, cfg.norm_eps)
    if "moe" in bp:
        # multi-token chunked prefill takes the batch routing path (same
        # numerics as the monolithic prefill); only true one-token steps
        # use the replicated-token decode strategy
        y2, aux = moe(bp["moe"], h2, cfg,
                      decode=(cache is not None and x.shape[1] == 1))
    else:
        y2 = mlp(bp["mlp"], h2, cfg.act)
    x = x + y2
    x = shard(x, "batch", "sp", None)
    return x, new_cache, aux


# ------------------------------------------------------------- the stack
def _block_signature(cfg: ModelConfig, i: int):
    """Layers with equal signatures share block structure (and can scan)."""
    return (layer_kind(cfg, i), cfg.window_for_layer(i), cfg.layer_is_moe[i])


def stack_plan(cfg: ModelConfig, min_group: int = 4) -> List[Tuple[int, int, bool]]:
    """Partition layers into (start, length, scanned) segments: maximal runs
    of identical signatures become lax.scan groups (HLO stays O(#segments)),
    singletons/short runs unroll.  hymba → [g, scan·14, g, scan·15, g];
    deepseek → [dense, scan·26]; xlstm → [scan·7, s, scan·7, s, scan·7, s]."""
    if not cfg.scan_layers:
        min_group = max(min_group, 10**9)  # force full unroll if disabled
    segs: List[Tuple[int, int, bool]] = []
    i = 0
    while i < cfg.n_layers:
        j = i
        sig = _block_signature(cfg, i)
        while j < cfg.n_layers and _block_signature(cfg, j) == sig:
            j += 1
        run = j - i
        if run >= min_group:
            segs.append((i, run, True))
        else:
            segs.extend((k, 1, False) for k in range(i, j))
        i = j
    return segs


def init_stack(key, cfg: ModelConfig) -> Params:
    keys = jax.random.split(key, cfg.n_layers)
    segments = []
    for start, length, scanned in stack_plan(cfg):
        if scanned:
            segments.append(jax.vmap(
                lambda k, s=start: init_block(k, cfg, s))(
                    keys[start:start + length]))
        else:
            segments.append(init_block(keys[start], cfg, start))
    return {"segments": segments}


def stack_specs(cfg: ModelConfig) -> Params:
    segments = []
    for start, length, scanned in stack_plan(cfg):
        base = block_specs(cfg, start)
        if scanned:
            base = jax.tree.map(lambda spec: (None,) + tuple(spec), base,
                                is_leaf=lambda x: isinstance(x, tuple))
        segments.append(base)
    return {"segments": segments}


def apply_stack(
    params: Params,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    positions=None,
    prefix_len: int = 0,
    caches: Optional[Any] = None,
    cache_pos=None,
    make_cache: bool = False,
    cache_len: int = 0,
    page_table=None,
    valid_len=None,
    kernel_backend: str = "xla",
    kernel_interpret: bool = False,
) -> Tuple[jax.Array, Optional[Any], jax.Array]:
    aux_total = jnp.zeros((), jnp.float32)
    plan = stack_plan(cfg)
    new_caches: List[Any] = []
    any_cache = False

    for seg_idx, (start, length, scanned) in enumerate(plan):
        seg_params = params["segments"][seg_idx]
        seg_cache = caches[seg_idx] if caches is not None else None
        block = functools.partial(
            apply_block, cfg=cfg, i=start, positions=positions,
            prefix_len=prefix_len, cache_pos=cache_pos,
            make_cache=make_cache, cache_len=cache_len,
            page_table=page_table, valid_len=valid_len,
            kernel_backend=kernel_backend, kernel_interpret=kernel_interpret)

        if not scanned:
            if cfg.remat and seg_cache is None and not make_cache:
                x, nc, a = jax.checkpoint(
                    lambda b, v: block(b, v, cache=None),
                    prevent_cse=False)(seg_params, x)
            else:
                x, nc, a = block(seg_params, x, cache=seg_cache)
            new_caches.append(nc)
            aux_total = aux_total + a
            any_cache = any_cache or nc is not None
            continue

        if seg_cache is None:
            def body(carry, bp):
                xx, aux = carry
                if cfg.remat:
                    fn = jax.checkpoint(lambda b, v: block(b, v, cache=None),
                                        prevent_cse=False)
                    xx_new, nc, a = fn(bp, xx)
                else:
                    xx_new, nc, a = block(bp, xx, cache=None)
                if nc is None:
                    nc = jnp.zeros((), jnp.float32)  # scan needs a leaf
                return (xx_new, aux + a), nc

            (x, aux_total), ncs = jax.lax.scan(body, (x, aux_total),
                                               seg_params)
        else:
            def body(carry, layer_in):
                xx, aux = carry
                bp, layer_cache = layer_in
                if cfg.remat:
                    fn = jax.checkpoint(lambda b, v, c: block(b, v, cache=c),
                                        prevent_cse=False)
                    xx_new, nc, a = fn(bp, xx, layer_cache)
                else:
                    xx_new, nc, a = block(bp, xx, cache=layer_cache)
                if nc is None:
                    nc = jnp.zeros((), jnp.float32)
                return (xx_new, aux + a), nc

            (x, aux_total), ncs = jax.lax.scan(body, (x, aux_total),
                                               (seg_params, seg_cache))
        if seg_cache is None and not make_cache:
            ncs = None
        new_caches.append(ncs)
        any_cache = any_cache or ncs is not None

    if not any_cache:
        new_caches = None
    return x, new_caches, aux_total
