"""Top-level language model: embeddings → stack → norm → logits, plus the
training loss and the prefill/decode entry points used by serving.

Input contract (`batch` dict):
  tokens        (B, S) int32          — LM families
  embeds        (B, S, d_model)       — stubbed modality frontend (hubert)
  patch_embeds  (B, P, d_model)       — stubbed vision frontend (paligemma)
  loss_mask     (B, S) f32 optional   — 1.0 where loss is counted
  targets       (B, S) int32 optional — explicit labels (encoder models)
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.nn.config import ModelConfig
from repro.nn.layers import embed, embedding_specs, init_embedding, init_rmsnorm, rmsnorm, rmsnorm_specs, unembed
from repro.nn.ssm import init_mamba_cache, init_mlstm_cache, init_slstm_cache
from repro.nn.transformer import (
    apply_stack,
    init_stack,
    layer_kind,
    stack_specs,
)
from repro.parallel.sharding import shard

Params = Dict[str, Any]


def init_params(key, cfg: ModelConfig) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "embedding": init_embedding(k1, cfg),
        "stack": init_stack(k2, cfg),
        "final_norm": init_rmsnorm(cfg.d_model),
    }


def param_specs(cfg: ModelConfig) -> Params:
    return {
        "embedding": embedding_specs(cfg),
        "stack": stack_specs(cfg),
        "final_norm": rmsnorm_specs(),
    }


def _inputs_to_x(params, batch: Dict[str, jax.Array], cfg: ModelConfig):
    """Returns (x, prefix_len)."""
    if cfg.input_mode == "embeddings":
        return batch["embeds"].astype(jnp.bfloat16), 0
    if cfg.input_mode == "prefix_vlm" and "patch_embeds" in batch:
        tok = embed(params["embedding"], batch["tokens"], cfg)
        pat = batch["patch_embeds"].astype(tok.dtype)
        x = jnp.concatenate([pat, tok], axis=1)
        return shard(x, "batch", "sp", None), pat.shape[1]
    return embed(params["embedding"], batch["tokens"], cfg), 0


def forward(
    params: Params,
    batch: Dict[str, jax.Array],
    cfg: ModelConfig,
    *,
    caches=None,
    cache_pos=None,
    make_cache: bool = False,
    cache_len: int = 0,
    last_only: bool = False,
    page_table=None,
    kernel_backend: str = "xla",
    kernel_interpret: bool = False,
) -> Tuple[jax.Array, Optional[Any], jax.Array]:
    """Returns (logits, new_caches, aux_loss).  ``last_only`` restricts the
    unembed to the final position (prefill/decode)."""
    x, prefix_len = _inputs_to_x(params, batch, cfg)
    x, new_caches, aux = apply_stack(
        params["stack"], x, cfg, prefix_len=prefix_len, caches=caches,
        cache_pos=cache_pos, make_cache=make_cache, cache_len=cache_len,
        page_table=page_table,
        kernel_backend=kernel_backend, kernel_interpret=kernel_interpret)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if last_only:
        x = x[:, -1:]
    logits = unembed(params["embedding"], x, cfg)
    return logits, new_caches, aux


def _chunked_ce(params, x, targets, loss_mask, cfg: ModelConfig,
                chunk: int = 512) -> Tuple[jax.Array, jax.Array]:
    """Cross-entropy over the (huge, vocab-parallel) logits, computed in
    sequence chunks so the full (B, S, V) tensor never materializes."""
    B, S, _ = x.shape
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)))
        loss_mask = jnp.pad(loss_mask, ((0, 0), (0, pad)))
    n = (S + pad) // chunk
    xs = (x.reshape(B, n, chunk, -1).swapaxes(0, 1),
          targets.reshape(B, n, chunk).swapaxes(0, 1),
          loss_mask.reshape(B, n, chunk).swapaxes(0, 1))

    vocab_ok = jnp.arange(cfg.vocab_padded) < cfg.vocab

    vocab_iota = jnp.arange(cfg.vocab_padded, dtype=jnp.int32)

    def body(carry, blk):
        tot, cnt = carry
        xb, tb, mb = blk
        logits = unembed(params["embedding"], xb, cfg).astype(jnp.float32)
        logits = jnp.where(vocab_ok, logits, -1e30)
        lse = jax.nn.logsumexp(logits, axis=-1)
        # NOT take_along_axis: a dynamic gather over the vocab-sharded axis
        # makes GSPMD all-gather the full logits (GBs); a masked reduction
        # stays sharded and psums a (B, chunk) scalar field instead.
        picked = jnp.sum(
            jnp.where(vocab_iota == tb[..., None], logits, 0.0), axis=-1)
        nll = (lse - picked) * mb
        return (tot + jnp.sum(nll), cnt + jnp.sum(mb)), None

    carry = (jnp.zeros(()), jnp.zeros(()))
    if cfg.unroll_chunks:
        for i in range(n):
            carry, _ = body(carry, jax.tree.map(lambda a, i=i: a[i], xs))
        tot, cnt = carry
    else:
        (tot, cnt), _ = jax.lax.scan(body, carry, xs)
    return tot / jnp.maximum(cnt, 1.0), cnt


def lm_loss(params: Params, batch: Dict[str, jax.Array], cfg: ModelConfig
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    x, prefix_len = _inputs_to_x(params, batch, cfg)
    x, _, aux = apply_stack(params["stack"], x, cfg, prefix_len=prefix_len)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)

    if cfg.is_encoder:
        targets = batch["targets"]
        mask = batch.get("loss_mask",
                         jnp.ones(targets.shape, jnp.float32)).astype(jnp.float32)
        ce, cnt = _chunked_ce(params, x, targets, mask, cfg)
    else:
        tokens = batch["tokens"]
        if cfg.input_mode == "prefix_vlm":
            # loss only over text positions (x includes the image prefix)
            x = x[:, prefix_len:]
        targets = tokens[:, 1:]
        xx = x[:, :-1]
        mask = batch.get("loss_mask", jnp.ones(tokens.shape, jnp.float32))
        mask = mask[:, 1:].astype(jnp.float32)
        ce, cnt = _chunked_ce(params, xx, targets, mask, cfg)

    loss = ce + cfg.router_aux_weight * aux
    return loss, {"ce": ce, "aux": aux, "tokens": cnt}


# ------------------------------------------------------------------ cache
def init_cache(cfg: ModelConfig, batch: int, cache_len: int,
               dtype=jnp.bfloat16, staging: bool = False):
    """Allocate decode caches, mirroring the stack's segment plan:
    scanned segments get stacked (length, ...) caches, singles get dicts.
    ``staging=True`` gives the chunked-prefill staging layout instead:
    sliding-window layers keep full ``cache_len`` buffers (every position
    stored, the window applied in the score mask, the ring produced only at
    arena-install time) and int8 tenants keep raw bf16 K/V (quantization is
    deferred to the install, exactly like the monolithic prefill quantizes
    once after attending in full precision)."""
    from repro.nn.transformer import stack_plan

    def attn_cache(window: int):
        if cfg.attn_type == "mla":
            return {
                "c_kv": jnp.zeros((batch, cache_len, cfg.kv_lora_rank), dtype),
                "k_rope": jnp.zeros((batch, cache_len, cfg.qk_rope_dim), dtype),
            }
        L = min(window, cache_len) if window and not staging else cache_len
        int8 = cfg.kv_cache_dtype == "int8" and not staging
        kv_dt = jnp.int8 if int8 else dtype
        out = {
            "k": shard(jnp.zeros((batch, L, cfg.n_kv_heads, cfg.head_dim), kv_dt),
                       "batch", "sp", None, None),
            "v": shard(jnp.zeros((batch, L, cfg.n_kv_heads, cfg.head_dim), kv_dt),
                       "batch", "sp", None, None),
        }
        if int8:
            out["k_scale"] = shard(
                jnp.zeros((batch, L, cfg.n_kv_heads), jnp.float32),
                "batch", "sp", None)
            out["v_scale"] = shard(
                jnp.zeros((batch, L, cfg.n_kv_heads), jnp.float32),
                "batch", "sp", None)
        return out

    def layer_cache(i: int):
        kind = layer_kind(cfg, i)
        if kind == "mlstm":
            return init_mlstm_cache(cfg, batch)
        if kind == "slstm":
            return init_slstm_cache(cfg, batch)
        if kind == "hybrid":
            return {"attn": attn_cache(cfg.window_for_layer(i)),
                    "mamba": init_mamba_cache(cfg, batch, dtype)}
        return {"attn": attn_cache(cfg.window_for_layer(i))}

    caches = []
    for start, length, scanned in stack_plan(cfg):
        one = layer_cache(start)
        if scanned:
            one = jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (length,) + a.shape), one)
        caches.append(one)
    return caches


def prefill(params: Params, batch: Dict[str, jax.Array], cfg: ModelConfig,
            cache_len: int):
    """Run the prompt through the model, returning (next_token_logits, caches)."""
    logits, caches, _ = forward(params, batch, cfg, make_cache=True,
                                cache_len=cache_len, last_only=True)
    return logits[:, 0], caches


def chunk_prefill(params: Params, tokens: jax.Array, caches, start, n_valid,
                  cfg: ModelConfig):
    """One chunked-prefill step: run ``tokens`` (B, C) at absolute positions
    [start, start+C) against the staging ``caches`` built by earlier chunks
    (``init_cache(..., staging=True)`` zeros for the first chunk).
    Only the first ``n_valid`` tokens are real; the padded tail writes K/V
    the position masks never admit and leaves recurrent state frozen.
    Returns (logits at position start + n_valid - 1, updated caches) — the
    last chunk's logits are the prompt's next-token distribution, exactly
    as ``prefill`` returns it."""
    B, C = tokens.shape
    positions = (start + jnp.arange(C))[None, :]
    x = embed(params["embedding"], tokens, cfg)
    x, new_caches, _ = apply_stack(
        params["stack"], x, cfg, positions=positions, caches=caches,
        cache_pos=start, valid_len=n_valid)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    x = jax.lax.dynamic_slice_in_dim(x, n_valid - 1, 1, axis=1)
    logits = unembed(params["embedding"], x, cfg)
    return logits[:, 0], new_caches


def decode_step(params: Params, token: jax.Array, caches, pos,
                cfg: ModelConfig, page_table=None,
                kernel_backend: str = "xla", kernel_interpret: bool = False):
    """One autoregressive step.  token (B,) int32; pos scalar or (B,) int32.
    With ``page_table`` (B, T), caches are page pools and pos must be the
    per-row (B,) write positions (see serving.paging).  kernel_backend
    routes paged GQA attention through the Pallas kernel (trace-time
    constant; see nn.attention)."""
    batch = {"tokens": token[:, None]}
    logits, new_caches, _ = forward(params, batch, cfg, caches=caches,
                                    cache_pos=pos, last_only=True,
                                    page_table=page_table,
                                    kernel_backend=kernel_backend,
                                    kernel_interpret=kernel_interpret)
    return logits[:, 0], new_caches
