"""Memory-lean chunked attention with a FlashAttention-2 style custom VJP.

Plain AD through an online-softmax scan saves every block's probability
matrix for backward — O(S²/chunk) bytes, ~17 GB per layer at 4k×16 heads.
This implementation saves only (q, k, v, o, lse) and *recomputes* block
probabilities in the backward pass, exactly like the TPU/GPU flash kernels:

  fwd:  scan over kv blocks per q block → o, lse
  bwd:  Δ = rowsum(do ⊙ o); per (kv, q) block: p = exp(qkᵀ − lse);
        dv += pᵀdo; ds = p ⊙ (do vᵀ − Δ); dk += dsᵀq; dq += ds k

GQA-native layout: q (B, Hkv, G, Sq, D) attends k/v (B, Hkv, Skv, D) without
materializing repeated KV heads.  Mask rule: causal / prefix / encoder.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

NEG_INF = -2.0e38


def _block_mask(qpos, kpos, causal: bool, prefix_len: int, skv: int):
    ok = (kpos < skv)[None, :]
    if causal:
        c = kpos[None, :] <= qpos[:, None]
        if prefix_len:
            c = c | (kpos < prefix_len)[None, :]
        ok = ok & c
    return ok


def _attend_fwd(q, k, v, causal, prefix_len, q_chunk, kv_chunk):
    """q: (B,Hkv,G,Sq,D); k: (B,Hkv,Skv,D); v: (B,Hkv,Skv,Dv) → (o, lse).
    Dv may differ from D (MLA)."""
    B, Hk, G, Sq, D = q.shape
    Skv = k.shape[2]
    Dv = v.shape[-1]
    scale = 1.0 / math.sqrt(D)
    nq = -(-Sq // q_chunk)
    nk = -(-Skv // kv_chunk)
    qp = jnp.pad(q, ((0, 0),) * 3 + ((0, nq * q_chunk - Sq), (0, 0)))
    kp = jnp.pad(k, ((0, 0),) * 2 + ((0, nk * kv_chunk - Skv), (0, 0)))
    vp = jnp.pad(v, ((0, 0),) * 2 + ((0, nk * kv_chunk - Skv), (0, 0)))
    kp = kp.reshape(B, Hk, nk, kv_chunk, D)
    vp = vp.reshape(B, Hk, nk, kv_chunk, Dv)

    def per_q(i):
        qb = jax.lax.dynamic_slice_in_dim(qp, i * q_chunk, q_chunk, axis=3)
        qpos = i * q_chunk + jnp.arange(q_chunk)

        def body(carry, j):
            m, l, acc = carry
            kb, vb = kp[:, :, j], vp[:, :, j]
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qb, kb) * scale
            kpos = j * kv_chunk + jnp.arange(kv_chunk)
            ok = _block_mask(qpos, kpos, causal, prefix_len, Skv)
            s = jnp.where(ok[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p, vb)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hk, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hk, G, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, Hk, G, q_chunk, Dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), jnp.arange(nk))
        lse = m + jnp.log(jnp.maximum(l, 1e-30))
        o = acc / jnp.maximum(l, 1e-30)[..., None]
        return o, lse

    os_, lses = jax.lax.map(per_q, jnp.arange(nq))
    o = jnp.moveaxis(os_, 0, 3).reshape(B, Hk, G, nq * q_chunk, Dv)[..., :Sq, :]
    lse = jnp.moveaxis(lses, 0, 3).reshape(B, Hk, G, nq * q_chunk)[..., :Sq]
    return o, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_chunked(q, k, v, causal: bool = True, prefix_len: int = 0,
                  q_chunk: int = 512, kv_chunk: int = 1024):
    """q: (B,Hkv,G,Sq,D); k/v: (B,Hkv,Skv,D); f32.  → (B,Hkv,G,Sq,D)."""
    o, _ = _attend_fwd(q, k, v, causal, prefix_len, q_chunk, kv_chunk)
    return o


def _vjp_fwd(q, k, v, causal, prefix_len, q_chunk, kv_chunk):
    o, lse = _attend_fwd(q, k, v, causal, prefix_len, q_chunk, kv_chunk)
    return o, (q, k, v, o, lse)


def _vjp_bwd(causal, prefix_len, q_chunk, kv_chunk, res, do):
    q, k, v, o, lse = res
    B, Hk, G, Sq, D = q.shape
    Skv = k.shape[2]
    Dv = v.shape[-1]
    scale = 1.0 / math.sqrt(D)
    delta = jnp.sum(do * o, axis=-1)                     # (B,Hk,G,Sq)
    nq = -(-Sq // q_chunk)
    nk = -(-Skv // kv_chunk)
    pq, pk = nq * q_chunk - Sq, nk * kv_chunk - Skv
    qp = jnp.pad(q, ((0, 0),) * 3 + ((0, pq), (0, 0))).reshape(
        B, Hk, G, nq, q_chunk, D)
    dop = jnp.pad(do, ((0, 0),) * 3 + ((0, pq), (0, 0))).reshape(
        B, Hk, G, nq, q_chunk, Dv)
    lsep = jnp.pad(lse, ((0, 0),) * 3 + ((0, pq),),
                   constant_values=1.0).reshape(B, Hk, G, nq, q_chunk)
    dlt = jnp.pad(delta, ((0, 0),) * 3 + ((0, pq),)).reshape(
        B, Hk, G, nq, q_chunk)
    kp = jnp.pad(k, ((0, 0),) * 2 + ((0, pk), (0, 0))).reshape(
        B, Hk, nk, kv_chunk, D)
    vp = jnp.pad(v, ((0, 0),) * 2 + ((0, pk), (0, 0))).reshape(
        B, Hk, nk, kv_chunk, Dv)

    def kv_body(dq_acc, j):
        kb, vb = kp[:, :, j], vp[:, :, j]
        kpos = j * kv_chunk + jnp.arange(kv_chunk)

        def q_body(carry, i):
            dk_acc, dv_acc, dq_all = carry
            qb = qp[:, :, :, i]
            qpos = i * q_chunk + jnp.arange(q_chunk)
            ok = _block_mask(qpos, kpos, causal, prefix_len, Skv)
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qb, kb) * scale
            s = jnp.where(ok[None, None, None], s, NEG_INF)
            p = jnp.exp(s - lsep[:, :, :, i][..., None])
            dob = dop[:, :, :, i]
            dv_acc = dv_acc + jnp.einsum("bhgqk,bhgqd->bhkd", p, dob)
            dp = jnp.einsum("bhgqd,bhkd->bhgqk", dob, vb)
            ds = p * (dp - dlt[:, :, :, i][..., None]) * scale
            dk_acc = dk_acc + jnp.einsum("bhgqk,bhgqd->bhkd", ds, qb)
            dq_blk = jnp.einsum("bhgqk,bhkd->bhgqd", ds, kb)
            dq_all = jax.lax.dynamic_update_slice_in_dim(
                dq_all, dq_blk, i * q_chunk, axis=3)
            return (dk_acc, dv_acc, dq_all), None

        dk0 = jnp.zeros((B, Hk, kv_chunk, D), jnp.float32)
        dv0 = jnp.zeros((B, Hk, kv_chunk, Dv), jnp.float32)
        dq_this = jnp.zeros((B, Hk, G, nq * q_chunk, D), jnp.float32)
        (dk_j, dv_j, dq_this), _ = jax.lax.scan(
            q_body, (dk0, dv0, dq_this), jnp.arange(nq))
        return dq_acc + dq_this, (dk_j, dv_j)

    dq0 = jnp.zeros((B, Hk, G, nq * q_chunk, D), jnp.float32)
    dq, (dk_b, dv_b) = jax.lax.scan(kv_body, dq0, jnp.arange(nk))
    dk = jnp.moveaxis(dk_b, 0, 2).reshape(B, Hk, nk * kv_chunk, D)[:, :, :Skv]
    dv = jnp.moveaxis(dv_b, 0, 2).reshape(B, Hk, nk * kv_chunk, Dv)[:, :, :Skv]
    return dq[..., :Sq, :], dk, dv


flash_chunked.defvjp(_vjp_fwd, _vjp_bwd)


def flash_chunked_unrolled(q, k, v, causal=True, prefix_len=0,
                           q_chunk=2048, kv_chunk=2048):
    """Dry-run cost-probe variant: identical math, python-unrolled loops so
    XLA cost analysis sees every FLOP (plain AD; probes are never executed)."""
    B, Hk, G, Sq, D = q.shape
    Skv = k.shape[2]
    Dv = v.shape[-1]
    scale = 1.0 / math.sqrt(D)
    nq = -(-Sq // q_chunk)
    nk = -(-Skv // kv_chunk)
    qp = jnp.pad(q, ((0, 0),) * 3 + ((0, nq * q_chunk - Sq), (0, 0)))
    kp = jnp.pad(k, ((0, 0),) * 2 + ((0, nk * kv_chunk - Skv), (0, 0)))
    vp = jnp.pad(v, ((0, 0),) * 2 + ((0, nk * kv_chunk - Skv), (0, 0)))
    outs = []
    for i in range(nq):
        qb = qp[:, :, :, i * q_chunk:(i + 1) * q_chunk]
        qpos = i * q_chunk + jnp.arange(q_chunk)
        m = jnp.full((B, Hk, G, q_chunk), NEG_INF, jnp.float32)
        l = jnp.zeros((B, Hk, G, q_chunk), jnp.float32)
        acc = jnp.zeros((B, Hk, G, q_chunk, Dv), jnp.float32)
        for j in range(nk):
            kb = kp[:, :, j * kv_chunk:(j + 1) * kv_chunk]
            vb = vp[:, :, j * kv_chunk:(j + 1) * kv_chunk]
            kpos = j * kv_chunk + jnp.arange(kv_chunk)
            ok = _block_mask(qpos, kpos, causal, prefix_len, Skv)
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qb, kb) * scale
            s = jnp.where(ok[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum("bhgqk,bhkd->bhgqd", p, vb)
            m = m_new
        outs.append(acc / jnp.maximum(l, 1e-30)[..., None])
    o = jnp.concatenate(outs, axis=3)
    return o[..., :Sq, :]
