"""Attention: GQA/MQA/MLA, sliding-window, prefix-LM and encoder masks.

Three execution paths:
  * plain      — full-score softmax, for short sequences and decode;
  * chunked    — double-scan online-softmax ("flash" in XLA; the Pallas TPU
                 kernel in `repro.kernels.flash_attention` implements the
                 same contract), bounded memory at 32k+ sequence lengths;
  * banded     — sliding-window attention via static-size dynamic slices:
                 O(S·w) compute instead of O(S²) masking.

All paths accumulate in fp32 and share a single mask rule:
  valid(i, j) = j <= i + prefix OR not causal, AND i - j < window (if windowed)
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.paged_attention.ops import paged_attention as _pallas_paged
from repro.nn.config import ModelConfig
from repro.nn.layers import _init, apply_rope, init_rmsnorm, rmsnorm, rope_angles
from repro.parallel.sharding import shard

Params = Dict[str, Any]

NEG_INF = -2.0e38


# =================================================================== masks
def _mask(
    qpos: jax.Array, kpos: jax.Array, *, causal: bool, window: int, prefix_len: int
) -> jax.Array:
    """qpos (..., Q), kpos (..., K) -> bool (..., Q, K)."""
    q = qpos[..., :, None]
    k = kpos[..., None, :]
    if causal:
        ok = k <= q
        if prefix_len:
            ok = ok | (k < prefix_len)
    else:
        ok = jnp.ones(jnp.broadcast_shapes(q.shape, k.shape), bool)
    if window > 0:
        ok = ok & (q - k < window)
    return ok


def _softmax_attend(q, k, v, mask, softcap: float) -> jax.Array:
    """q (B,Q,Hkv,G,D), k/v (B,K,Hkv,D), mask (B|1,Q,K) -> (B,Q,Hkv,G,D)."""
    scale = 1.0 / math.sqrt(q.shape[-1])
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s * scale
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))


# ============================================================ chunked path
def chunked_attention(
    q: jax.Array,            # (B, Sq, Hkv, G, D)
    k: jax.Array,            # (B, Skv, Hkv, D)
    v: jax.Array,
    *,
    causal: bool,
    window: int = 0,
    prefix_len: int = 0,
    softcap: float = 0.0,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
    unroll: bool = False,
) -> jax.Array:
    B, Sq, Hkv, G, D = q.shape
    Skv = k.shape[1]
    scale = 1.0 / math.sqrt(D)

    if window > 0:
        return _banded_attention(q, k, v, window=window, softcap=softcap,
                                 q_chunk=q_chunk, unroll=unroll)

    if softcap == 0.0:
        # Flash path: custom-VJP online softmax — backward recomputes blocks
        # instead of saving O(S²/chunk) probabilities (repro.nn.flash).
        from repro.nn.flash import flash_chunked, flash_chunked_unrolled
        qf = q.transpose(0, 2, 3, 1, 4).astype(jnp.float32)  # (B,Hkv,G,Sq,D)
        kf = k.transpose(0, 2, 1, 3).astype(jnp.float32)     # (B,Hkv,Skv,D)
        vf = v.transpose(0, 2, 1, 3).astype(jnp.float32)
        fn = flash_chunked_unrolled if unroll else flash_chunked
        o = fn(qf, kf, vf, causal, prefix_len, q_chunk, kv_chunk)
        return o.transpose(0, 3, 1, 2, 4)                    # (B,Sq,Hkv,G,D)

    nq = math.ceil(Sq / q_chunk)
    nk = math.ceil(Skv / kv_chunk)
    q_pad = nq * q_chunk - Sq
    k_pad = nk * kv_chunk - Skv
    qp = jnp.pad(q, ((0, 0), (0, q_pad), (0, 0), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, k_pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, k_pad), (0, 0), (0, 0)))
    kp = kp.reshape(B, nk, kv_chunk, Hkv, D)
    vp = vp.reshape(B, nk, kv_chunk, Hkv, D)

    def outer(qi, q_blk):
        qpos = qi * q_chunk + jnp.arange(q_chunk)

        def inner(carry, blk):
            m_run, l_run, acc = carry
            kj, k_blk, v_blk = blk
            kpos = kj * kv_chunk + jnp.arange(kv_chunk)
            ok = _mask(qpos, kpos, causal=causal, window=0, prefix_len=prefix_len)
            ok = ok & (kpos < Skv)[None, :]
            s = jnp.einsum("bqhgd,bkhd->bhgqk",
                           q_blk.astype(jnp.float32), k_blk.astype(jnp.float32))
            s = s * scale
            if softcap > 0:
                s = softcap * jnp.tanh(s / softcap)
            s = jnp.where(ok[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_run - m_new)
            l_new = l_run * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p, v_blk.astype(jnp.float32))
            acc = acc * corr[..., None] + pv
            return (m_new, l_new, acc), None

        m0 = jnp.full((B, Hkv, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, q_chunk, D), jnp.float32)
        if unroll:
            carry = (m0, l0, a0)
            for j in range(nk):
                carry, _ = inner(carry, (jnp.int32(j), kp[:, j], vp[:, j]))
            m, l, acc = carry
        else:
            (m, l, acc), _ = jax.lax.scan(
                inner, (m0, l0, a0),
                (jnp.arange(nk), kp.swapaxes(0, 1), vp.swapaxes(0, 1)),
            )
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.transpose(0, 3, 1, 2, 4)  # (B, qc, Hkv, G, D)

    qp = qp.reshape(B, nq, q_chunk, Hkv, G, D)
    if unroll:
        outs = jnp.stack([outer(jnp.int32(i), qp[:, i]) for i in range(nq)], 0)
    else:
        outs = jax.lax.map(lambda args: outer(*args),
                           (jnp.arange(nq), qp.swapaxes(0, 1)))
    out = outs.swapaxes(0, 1).reshape(B, nq * q_chunk, Hkv, G, D)
    return out[:, :Sq]


def _banded_attention(q, k, v, *, window: int, softcap: float, q_chunk: int,
                      unroll: bool = False):
    """Sliding-window attention: each q chunk attends a static-size
    [window + q_chunk] KV band fetched with dynamic_slice — O(S·w)."""
    B, Sq, Hkv, G, D = q.shape
    Skv = k.shape[1]
    scale = 1.0 / math.sqrt(D)
    nq = math.ceil(Sq / q_chunk)
    q_pad = nq * q_chunk - Sq
    qp = jnp.pad(q, ((0, 0), (0, q_pad), (0, 0), (0, 0), (0, 0)))
    band = window + q_chunk
    # Left-pad K/V by `window` so the band slice start is never negative.
    kp = jnp.pad(k, ((0, 0), (window, q_pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (window, q_pad), (0, 0), (0, 0)))

    def outer(qi, q_blk):
        start = qi * q_chunk  # band covers original positions [start-w, start+qc)
        k_blk = jax.lax.dynamic_slice_in_dim(kp, start, band, axis=1)
        v_blk = jax.lax.dynamic_slice_in_dim(vp, start, band, axis=1)
        qpos = start + jnp.arange(q_chunk)
        kpos = start - window + jnp.arange(band)
        ok = _mask(qpos, kpos, causal=True, window=window, prefix_len=0)
        ok = ok & (kpos >= 0)[None, :] & (kpos < Skv)[None, :]
        s = jnp.einsum("bqhgd,bkhd->bhgqk",
                       q_blk.astype(jnp.float32), k_blk.astype(jnp.float32)) * scale
        if softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        s = jnp.where(ok[None, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v_blk.astype(jnp.float32))
        return out

    qp = qp.reshape(B, nq, q_chunk, Hkv, G, D)
    if unroll:
        outs = jnp.stack([outer(jnp.int32(i), qp[:, i]) for i in range(nq)], 0)
    else:
        outs = jax.lax.map(lambda args: outer(*args),
                           (jnp.arange(nq), qp.swapaxes(0, 1)))
    out = outs.swapaxes(0, 1).reshape(B, nq * q_chunk, Hkv, G, D)
    return out[:, :Sq]



# ======================================================== int8 KV cache
def _kv_quant(x: jax.Array):
    """Symmetric per-(token, head) int8 quantization of K/V slices.
    x: (B, S, H, D) -> (codes int8, scale f32 (B, S, H))."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    codes = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                     -127, 127).astype(jnp.int8)
    return codes, scale


def _kv_dequant(codes: jax.Array, scale: jax.Array, dtype=jnp.bfloat16):
    return (codes.astype(jnp.float32) * scale[..., None]).astype(dtype)


# ================================================================== module
def init_attention(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    if cfg.attn_type == "mla":
        p = {
            "w_q": _init(ks[0], (d, cfg.n_heads * (cfg.qk_nope_dim + cfg.qk_rope_dim)), 0),
            "w_dkv": _init(ks[1], (d, cfg.kv_lora_rank), 0),
            "w_kr": _init(ks[2], (d, cfg.qk_rope_dim), 0),
            "w_uk": _init(ks[3], (cfg.kv_lora_rank, cfg.n_heads * cfg.qk_nope_dim), 0),
            "w_uv": _init(ks[4], (cfg.kv_lora_rank, cfg.n_heads * cfg.v_head_dim), 0),
            "w_o": _init(ks[5], (cfg.n_heads * cfg.v_head_dim, d), 0),
            "kv_norm": init_rmsnorm(cfg.kv_lora_rank),
        }
    else:
        p = {
            "w_q": _init(ks[0], (d, cfg.q_dim), 0),
            "w_k": _init(ks[1], (d, cfg.kv_dim), 0),
            "w_v": _init(ks[2], (d, cfg.kv_dim), 0),
            "w_o": _init(ks[3], (cfg.q_dim, d), 0),
        }
        if cfg.qk_norm:
            p["q_norm"] = init_rmsnorm(cfg.head_dim)
            p["k_norm"] = init_rmsnorm(cfg.head_dim)
    return p


def attention_specs(cfg: ModelConfig) -> Params:
    if cfg.attn_type == "mla":
        s = {
            "w_q": ("fsdp", "tp"), "w_dkv": ("fsdp", None), "w_kr": ("fsdp", None),
            "w_uk": ("fsdp", "tp"), "w_uv": ("fsdp", "tp"), "w_o": ("tp", "fsdp"),
            "kv_norm": {"scale": (None,)},
        }
    else:
        s = {"w_q": ("fsdp", "tp"), "w_k": ("fsdp", "tp"),
             "w_v": ("fsdp", "tp"), "w_o": ("tp", "fsdp")}
        if cfg.qk_norm:
            s["q_norm"] = {"scale": (None,)}
            s["k_norm"] = {"scale": (None,)}
    return s


def _gqa_qkv(params, x, cfg: ModelConfig, positions):
    B, S, _ = x.shape
    H, Hkv, D = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = jnp.einsum("btd,dq->btq", x, params["w_q"].astype(x.dtype))
    k = jnp.einsum("btd,dq->btq", x, params["w_k"].astype(x.dtype))
    v = jnp.einsum("btd,dq->btq", x, params["w_v"].astype(x.dtype))
    q = q.reshape(B, S, Hkv, H // Hkv, D)
    k = k.reshape(B, S, Hkv, D)
    v = v.reshape(B, S, Hkv, D)
    if cfg.qk_norm:
        q = rmsnorm(params["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(params["k_norm"], k, cfg.norm_eps)
    cos, sin = rope_angles(positions, D, cfg.rope_theta)
    qf = q.reshape(B, S, Hkv * (H // Hkv), D)
    qf = apply_rope(qf, cos, sin).reshape(B, S, Hkv, H // Hkv, D)
    k = apply_rope(k, cos, sin)
    return qf, k, v


def attention(
    params: Params,
    x: jax.Array,                       # (B, S, d_model)
    cfg: ModelConfig,
    *,
    layer_window: int = 0,
    positions: Optional[jax.Array] = None,
    prefix_len: int = 0,
    cache: Optional[Dict[str, jax.Array]] = None,
    cache_pos: Optional[jax.Array] = None,
    make_cache: bool = False,
    cache_len: int = 0,
    page_table: Optional[jax.Array] = None,
    kernel_backend: str = "xla",
    kernel_interpret: bool = False,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Unified attention entry point.

    * train:   cache=None, make_cache=False
    * prefill: cache=None, make_cache=True (cache_len ≥ S)
    * decode:  cache given, S == 1, cache_pos = current position
    * chunked prefill: cache given, S > 1, cache_pos = scalar chunk start —
      this chunk's K/V land at absolute positions [cache_pos, cache_pos+S)
      of a full-length staging cache (windowed layers store every position
      and mask the window; no ring until arena install)
    * paged decode: cache leaves are page pools (P, page, ...) and
      page_table (B, T) maps each row's logical blocks to physical pages
      (cache_pos must be a per-row (B,) vector)

    kernel_backend="pallas" routes eligible paged GQA decode
    (single-token, no sliding window, no logit softcap) through the
    Pallas paged-attention kernel, which walks only the pages at or
    below each row's position instead of gathering the full table
    width; everything else falls back to the XLA path.
    kernel_interpret pins the kernel's interpret mode (CI equivalence
    off-TPU) — both are trace-time constants.
    """
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)[None, :] if cache_pos is None else (
            cache_pos[:, None] if cache_pos.ndim else
            jnp.full((B, 1), cache_pos)
        )
        if positions.shape[0] == 1 and B > 1:
            positions = jnp.broadcast_to(positions, (B, S))

    if cfg.attn_type == "mla":
        return _mla_attention(params, x, cfg, positions=positions,
                              prefix_len=prefix_len, cache=cache,
                              cache_pos=cache_pos, make_cache=make_cache,
                              cache_len=cache_len, page_table=page_table)

    q, k, v = _gqa_qkv(params, x, cfg, positions)
    new_cache = None
    o = None

    if cache is not None:
        if page_table is not None:
            # Paged decode: scatter the new token's K/V into its physical
            # page, then attend over the row's pages.  SWA layers store
            # full positions and mask the window (no ring buffer).
            use_pallas = (kernel_backend == "pallas" and S == 1
                          and layer_window <= 0 and cfg.logit_softcap <= 0)
            if use_pallas:
                # Pallas kernel walks only pages at/below each row's
                # position — no full-width gather.  Windowed/softcap
                # layers (none in the paged configs today) fall back to
                # the XLA path below.  int8 pools are dequantized
                # elementwise first: identical values to the XLA path's
                # gather-then-dequant.
                new_cache = _paged_scatter_gqa(cache, k, v, cfg,
                                               cache_pos, page_table)
                if cfg.kv_cache_dtype == "int8":
                    kp = _kv_dequant(new_cache["k"], new_cache["k_scale"],
                                     k.dtype)
                    vp = _kv_dequant(new_cache["v"], new_cache["v_scale"],
                                     v.dtype)
                else:
                    kp, vp = new_cache["k"], new_cache["v"]
                H, Hkv, D = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
                o = _pallas_paged(q[:, 0].reshape(B, H, D), kp, vp,
                                  page_table, cache_pos,
                                  interpret=kernel_interpret)
                o = o.reshape(B, 1, Hkv, H // Hkv, D)
            else:
                kc, vc, new_cache = _paged_append_gqa(cache, k, v, cfg,
                                                      cache_pos, page_table)
                Sc = kc.shape[1]
                kpos = jnp.arange(Sc)[None, :]
                cp = cache_pos[:, None]
                valid = kpos <= cp
                if layer_window > 0:
                    valid = valid & (kpos > cp - layer_window)
                valid = valid[:, None, :]                 # (B, 1, Sc)
        else:
            # Decode: append to the ring/full cache then attend over it.
            # SWA layers keep a ring buffer of `window` slots
            # (slot = pos % window); make_cache emits an exactly-window-sized
            # ring once the cache budget reaches the window, so the boundary
            # must accept `==` — a strictly-smaller cache is a full cache the
            # window never binds on.  Chunked prefill (S > 1) instead appends
            # at absolute positions into a full-length staging cache:
            # windowed layers store every position and mask the window in
            # the scores; the ring conversion happens when the staging cache
            # is installed into the serving arena (launch.steps).
            chunked = S > 1
            ring = (layer_window
                    if not chunked and 0 < layer_window <= cache["k"].shape[1]
                    else 0)
            slot = cache_pos % ring if ring else cache_pos
            # int8 tenants chunk-prefill into a *raw* bf16 staging cache
            # (quantization happens once at arena install, matching the
            # monolithic prefill's attend-raw-then-quantize order)
            if cfg.kv_cache_dtype == "int8" and not chunked:
                kq, ks = _kv_quant(k)
                vq, vs = _kv_quant(v)
                kc8 = _dus_batch(cache["k"], kq, slot)
                vc8 = _dus_batch(cache["v"], vq, slot)
                kss = _dus_batch(cache["k_scale"], ks, slot)
                vss = _dus_batch(cache["v_scale"], vs, slot)
                kc8 = shard(kc8, "batch", "sp", None, None)
                vc8 = shard(vc8, "batch", "sp", None, None)
                new_cache = {"k": kc8, "v": vc8, "k_scale": kss,
                             "v_scale": vss}
                kc = _kv_dequant(kc8, kss, k.dtype)
                vc = _kv_dequant(vc8, vss, v.dtype)
            else:
                kc = _dus_batch(cache["k"], k, slot)
                vc = _dus_batch(cache["v"], v, slot)
                kc = shard(kc, "batch", "sp", None, None)
                vc = shard(vc, "batch", "sp", None, None)
                new_cache = {"k": kc, "v": vc}
            Sc = kc.shape[1]
            kpos = jnp.arange(Sc)[None, :]
            if chunked:
                # Per-query causal (+window) mask over the staging cache:
                # query i sits at absolute position cache_pos + i.
                qpos = (cache_pos + jnp.arange(S))[:, None]
                valid = kpos <= qpos
                if layer_window > 0:
                    valid = valid & (qpos - kpos < layer_window)
                valid = valid[None]                       # (1, S, Sc)
            else:
                # cp: (1, 1) scalar broadcast or (B, 1) per-sequence
                # positions — the continuous-batching engine decodes a slot
                # batch where every row sits at a different position.
                cp = cache_pos[:, None] if jnp.ndim(cache_pos) else cache_pos
                if ring:
                    # Absolute position held by slot i: the largest p ≤
                    # cache_pos with p ≡ i (mod ring).
                    abs_pos = cp - ((cp - kpos) % ring)
                    valid = (abs_pos >= 0) & (abs_pos > cp - ring)
                else:
                    valid = kpos <= cp
                valid = valid[:, None, :]                 # (B|1, 1, Sc)
        if o is None:
            scale = 1.0 / math.sqrt(cfg.head_dim)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", q.astype(jnp.float32),
                           kc.astype(jnp.float32)) * scale
            if cfg.logit_softcap > 0:
                s = cfg.logit_softcap * jnp.tanh(s / cfg.logit_softcap)
            s = jnp.where(valid[:, None, None], s, NEG_INF)
            p = jax.nn.softmax(s, axis=-1)
            o = jnp.einsum("bhgqk,bkhd->bqhgd", p, vc.astype(jnp.float32))
    else:
        if S <= 1024:
            mask = _mask(positions, positions, causal=cfg.causal,
                         window=layer_window, prefix_len=prefix_len)
            o = _softmax_attend(q, k, v, mask, cfg.logit_softcap)
        else:
            o = chunked_attention(q, k, v, causal=cfg.causal,
                                  window=layer_window if cfg.causal else 0,
                                  prefix_len=prefix_len,
                                  softcap=cfg.logit_softcap,
                                  q_chunk=cfg.attn_q_chunk,
                                  kv_chunk=cfg.attn_kv_chunk,
                                  unroll=cfg.unroll_chunks)
        if make_cache:
            L = cache_len or S
            ring = layer_window if 0 < layer_window <= L else 0
            Lc = ring if ring else L
            int8 = cfg.kv_cache_dtype == "int8"
            if int8:
                k_st, ks_full = _kv_quant(k)
                v_st, vs_full = _kv_quant(v)
                kc = jnp.zeros((B, Lc, cfg.n_kv_heads, cfg.head_dim), jnp.int8)
                ksc = jnp.zeros((B, Lc, cfg.n_kv_heads), jnp.float32)
                vsc = jnp.zeros_like(ksc)
            else:
                k_st, v_st = k, v
                kc = jnp.zeros((B, Lc, cfg.n_kv_heads, cfg.head_dim), k.dtype)
            vc = jnp.zeros_like(kc)
            if ring:
                # Keep the last `ring` tokens at slot = pos % ring.
                n_keep = min(S, ring)
                keep_pos = jnp.arange(S - n_keep, S)
                slots = keep_pos % ring
                kc = kc.at[:, slots].set(k_st[:, -n_keep:])
                vc = vc.at[:, slots].set(v_st[:, -n_keep:])
                if int8:
                    ksc = ksc.at[:, slots].set(ks_full[:, -n_keep:])
                    vsc = vsc.at[:, slots].set(vs_full[:, -n_keep:])
            else:
                kc = kc.at[:, :S].set(k_st)
                vc = vc.at[:, :S].set(v_st)
                if int8:
                    ksc = ksc.at[:, :S].set(ks_full)
                    vsc = vsc.at[:, :S].set(vs_full)
            kc = shard(kc, "batch", "sp", None, None)
            vc = shard(vc, "batch", "sp", None, None)
            new_cache = {"k": kc, "v": vc}
            if int8:
                new_cache.update({"k_scale": ksc, "v_scale": vsc})

    o = o.astype(x.dtype).reshape(B, S, cfg.q_dim)
    y = jnp.einsum("btq,qd->btd", o, params["w_o"].astype(x.dtype))
    return y, new_cache


def _paged_ops(pool_leaf, cache_pos, page_table):
    """Scatter/gather closures over one page pool shape family.

    Pool leaves carry (P, page, ...); cache_pos (B,) is each row's write
    position and page_table (B, T) its block→physical-page map.  `scatter`
    writes this step's (B, 1, ...) entries at (physical page, offset);
    `gather` rebuilds the row-ordered (B, T·page, ...) view.  Updated pools
    keep the slot path's sharding annotation (page axis in the batch role,
    no-op without a mesh) so sharded serving doesn't silently lose the KV
    constraint.

    Each active row's target page is exclusively owned (the arena COWs
    shared pages before the step), so the scatter rows never collide except
    on the reserved scratch page that inactive rows aim at — whose contents
    are never gathered.
    """
    page = pool_leaf.shape[1]
    B, T = page_table.shape
    block, offset = cache_pos // page, cache_pos % page
    phys = page_table[jnp.arange(B), block]

    def scatter(pool, new):
        pool = pool.at[phys, offset].set(new[:, 0].astype(pool.dtype))
        return shard(pool, "batch", "sp", *((None,) * (pool.ndim - 2)))

    def gather(pool):
        return pool[page_table].reshape((B, T * page) + pool.shape[2:])

    return scatter, gather


def _paged_append_gqa(cache, k, v, cfg: ModelConfig, cache_pos, page_table):
    """Paged decode append + gather for GQA caches: k/v pools
    (P, page, Hkv, D) (+ int8 scales (P, page, Hkv)); k/v are this step's
    (B, 1, Hkv, D) projections.  Returns (kc, vc, new_cache) with kc/vc
    gathered to (B, T·page, Hkv, D) in logical position order."""
    scatter, gather = _paged_ops(cache["k"], cache_pos, page_table)
    if cfg.kv_cache_dtype == "int8":
        kq, ks = _kv_quant(k)
        vq, vs = _kv_quant(v)
        new_cache = {"k": scatter(cache["k"], kq),
                     "v": scatter(cache["v"], vq),
                     "k_scale": scatter(cache["k_scale"], ks),
                     "v_scale": scatter(cache["v_scale"], vs)}
        kc = _kv_dequant(gather(new_cache["k"]), gather(new_cache["k_scale"]),
                         k.dtype)
        vc = _kv_dequant(gather(new_cache["v"]), gather(new_cache["v_scale"]),
                         v.dtype)
    else:
        new_cache = {"k": scatter(cache["k"], k), "v": scatter(cache["v"], v)}
        kc, vc = gather(new_cache["k"]), gather(new_cache["v"])
    return kc, vc, new_cache


def _paged_scatter_gqa(cache, k, v, cfg: ModelConfig, cache_pos, page_table):
    """Scatter-only variant of `_paged_append_gqa` for the Pallas path:
    writes this step's K/V into the pools and returns the updated cache
    without materializing the (B, T·page, ...) gathered view — the kernel
    reads the pools through the page table itself."""
    scatter, _ = _paged_ops(cache["k"], cache_pos, page_table)
    if cfg.kv_cache_dtype == "int8":
        kq, ks = _kv_quant(k)
        vq, vs = _kv_quant(v)
        return {"k": scatter(cache["k"], kq),
                "v": scatter(cache["v"], vq),
                "k_scale": scatter(cache["k_scale"], ks),
                "v_scale": scatter(cache["v_scale"], vs)}
    return {"k": scatter(cache["k"], k), "v": scatter(cache["v"], v)}


def _dus_batch(cache: jax.Array, new: jax.Array, pos) -> jax.Array:
    """dynamic_update_slice along axis 1 at (possibly traced) position."""
    pos = jnp.asarray(pos)
    if pos.ndim == 0:
        return jax.lax.dynamic_update_slice_in_dim(cache, new.astype(cache.dtype),
                                                   pos, axis=1)
    # per-batch positions
    def upd(c, n, p):
        return jax.lax.dynamic_update_slice_in_dim(c, n.astype(c.dtype), p, axis=0)
    return jax.vmap(upd)(cache, new, pos)


# ==================================================================== MLA
def _mla_attention(params, x, cfg: ModelConfig, *, positions, prefix_len,
                   cache, cache_pos, make_cache, cache_len,
                   page_table=None):
    B, S, _ = x.shape
    H = cfg.n_heads
    nope, rope_d, vdim = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim

    q = jnp.einsum("btd,dq->btq", x, params["w_q"].astype(x.dtype))
    q = q.reshape(B, S, H, nope + rope_d)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    cos, sin = rope_angles(positions, rope_d, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)

    c_kv = jnp.einsum("btd,dr->btr", x, params["w_dkv"].astype(x.dtype))
    c_kv = rmsnorm(params["kv_norm"], c_kv, cfg.norm_eps)
    k_rope = jnp.einsum("btd,dr->btr", x, params["w_kr"].astype(x.dtype))
    k_rope = apply_rope(k_rope[:, :, None, :], cos, sin)[:, :, 0]

    new_cache = None
    chunked = cache is not None and S > 1
    if cache is not None and not chunked:
        # ---- absorbed-matmul decode (DeepSeek-V2 §Low-Rank KV) ----
        # Never materialize per-head K/V from the latent cache: fold W_uk
        # into the query and W_uv into the output —
        #   score = (q_nope W_ukᵀ)·c_kv + q_rope·k_rope
        #   out   = W_uv (Σ_s p_s c_kv_s)
        # FLOPs drop from O(S·r·H·(d_nope+d_v)) to O(S·r·H) per step
        # (≈32× here; EXPERIMENTS.md §Perf iteration 6).
        if page_table is not None:
            # Paged latent cache: scatter this token's (c_kv, k_rope) into
            # its physical page, gather the row's pages back into logical
            # order, then run the same absorbed math.
            scatter, gather = _paged_ops(cache["c_kv"], cache_pos,
                                         page_table)
            new_cache = {"c_kv": scatter(cache["c_kv"], c_kv),
                         "k_rope": scatter(cache["k_rope"], k_rope)}
            ckv_c = gather(new_cache["c_kv"])
            kr_c = gather(new_cache["k_rope"])
        else:
            ckv_c = _dus_batch(cache["c_kv"], c_kv, cache_pos)
            kr_c = _dus_batch(cache["k_rope"], k_rope, cache_pos)
            ckv_c = shard(ckv_c, "batch", "sp", None)
            kr_c = shard(kr_c, "batch", "sp", None)
            new_cache = {"c_kv": ckv_c, "k_rope": kr_c}
        Sc = ckv_c.shape[1]
        cp = cache_pos[:, None] if jnp.ndim(cache_pos) else cache_pos
        valid = (jnp.arange(Sc)[None, :] <= cp)[:, None, :]
        w_uk = params["w_uk"].astype(jnp.float32).reshape(
            cfg.kv_lora_rank, H, nope)
        w_uv = params["w_uv"].astype(jnp.float32).reshape(
            cfg.kv_lora_rank, H, vdim)
        q_eff = jnp.einsum("bshn,rhn->bshr", q_nope.astype(jnp.float32), w_uk)
        scale = 1.0 / math.sqrt(nope + rope_d)
        s = jnp.einsum("bshr,bkr->bhsk", q_eff, ckv_c.astype(jnp.float32))
        s = s + jnp.einsum("bshd,bkd->bhsk", q_rope.astype(jnp.float32),
                           kr_c.astype(jnp.float32))
        s = s * scale
        s = jnp.where(valid[:, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        ctx = jnp.einsum("bhsk,bkr->bshr", p, ckv_c.astype(jnp.float32))
        o = jnp.einsum("bshr,rhv->bshv", ctx, w_uv)
        o = o.astype(x.dtype).reshape(B, S, H * vdim)
        y = jnp.einsum("btq,qd->btd", o, params["w_o"].astype(x.dtype))
        return y, new_cache

    if chunked:
        # Chunked prefill: append this chunk's latents into the staging
        # buffer, then materialize per-head K/V from the whole buffer the
        # way the monolithic prefill does — identical numerics per position,
        # so chunked and monolithic prefills agree bitwise.  The
        # absorbed-matmul trick stays decode-only (one token amortizes the
        # re-expansion; a prefill recomputes it anyway).
        ckv_c = _dus_batch(cache["c_kv"], c_kv, cache_pos)
        kr_c = _dus_batch(cache["k_rope"], k_rope, cache_pos)
        ckv_c = shard(ckv_c, "batch", "sp", None)
        kr_c = shard(kr_c, "batch", "sp", None)
        new_cache = {"c_kv": ckv_c, "k_rope": kr_c}
        c_all, kr_all = ckv_c, kr_c
        Sc = c_all.shape[1]
    else:
        c_all, kr_all = c_kv, k_rope
        Sc = S

    k_nope = jnp.einsum("btr,rq->btq", c_all, params["w_uk"].astype(x.dtype))
    k_nope = k_nope.reshape(B, Sc, H, nope)
    vv = jnp.einsum("btr,rq->btq", c_all, params["w_uv"].astype(x.dtype))
    vv = vv.reshape(B, Sc, H, vdim)

    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(kr_all[:, :, None, :], (B, Sc, H, rope_d))],
        axis=-1)
    qq = jnp.concatenate([q_nope, q_rope], axis=-1)[:, :, :, None, :]
    qq = qq.reshape(B, S, H, 1, nope + rope_d)

    if chunked:
        # queries sit at `positions`; keys cover the whole staging buffer
        mask = _mask(positions, jnp.arange(Sc)[None, :], causal=cfg.causal,
                     window=0, prefix_len=prefix_len)
        o = _softmax_attend(qq, k, vv, mask, 0.0)
    elif S <= 1024:
        mask = _mask(positions, positions, causal=cfg.causal, window=0,
                     prefix_len=prefix_len)
        o = _softmax_attend(qq, k, vv, mask, 0.0)
    else:
        o = chunked_attention(qq, k, vv, causal=cfg.causal,
                              prefix_len=prefix_len,
                              q_chunk=cfg.attn_q_chunk,
                              kv_chunk=cfg.attn_kv_chunk,
                              unroll=cfg.unroll_chunks)
    if make_cache:
        L = cache_len or S
        ckv_c = jnp.zeros((B, L, cfg.kv_lora_rank), c_kv.dtype).at[:, :S].set(c_kv)
        kr_c = jnp.zeros((B, L, rope_d), k_rope.dtype).at[:, :S].set(k_rope)
        new_cache = {"c_kv": shard(ckv_c, "batch", "sp", None),
                     "k_rope": shard(kr_c, "batch", "sp", None)}

    o = o.astype(x.dtype).reshape(B, S, H * vdim)
    y = jnp.einsum("btq,qd->btd", o, params["w_o"].astype(x.dtype))
    return y, new_cache
