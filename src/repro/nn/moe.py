"""Mixture-of-Experts with expert parallelism.

Experts are sharded over the 'model' mesh axis (EP); within each expert the
weights are additionally FSDP-sharded over 'data' and all-gathered per layer
(AD turns the gather into reduce-scatter gradients — ZeRO-3 semantics).

Two dispatch strategies, chosen by token count:
  * sorted all-to-all (train/prefill): tokens are seq-sharded over 'model';
    each shard top-k routes its tokens, packs per-destination capacity
    buffers, and exchanges them with a single `all_to_all` (GShard-style,
    capacity factor with drops + load-balance auxiliary loss);
  * replicated-token (decode): tokens are replicated over 'model'; each
    shard runs only its local experts, masked by the routing decision, and
    partial outputs are `psum`-combined.  For one-token decode this costs
    E_local ≈ top-k expert evaluations — no all_to_all latency on the
    critical path.

Without an installed mesh (CPU unit tests) a dense reference path runs the
exact same math serially — it doubles as the oracle for the shard_map path.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.nn.config import ModelConfig
from repro.nn.layers import _act, _init
from repro.parallel.sharding import batch_axes, current_mesh
from jax.sharding import PartitionSpec as P

Params = Dict[str, Any]


def init_moe(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 5)
    d, fe, e = cfg.d_model, cfg.d_ff_expert, cfg.n_experts
    p = {
        "router": _init(ks[0], (d, e), 0),
        "w_gate": _init(ks[1], (e, d, fe), 1),
        "w_up": _init(ks[2], (e, d, fe), 1),
        "w_down": _init(ks[3], (e, fe, d), 1),
    }
    if cfg.n_shared_experts:
        from repro.nn.layers import init_mlp
        p["shared"] = init_mlp(ks[4], d, cfg.n_shared_experts * fe, gated=True)
    return p


def moe_specs(cfg: ModelConfig) -> Params:
    s = {
        "router": (None, None),
        "w_gate": ("tp", "fsdp", None),
        "w_up": ("tp", "fsdp", None),
        "w_down": ("tp", None, "fsdp"),
    }
    if cfg.n_shared_experts:
        from repro.nn.layers import mlp_specs
        s["shared"] = mlp_specs(gated=True)
    return s


def _route(x_f32: jax.Array, router: jax.Array, topk: int):
    """x (T, D) -> probs (T, E), top-k (T, k) values+indices (normalized)."""
    logits = x_f32 @ router
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, topk)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)
    return probs, gate_vals, gate_idx


def _aux_loss(probs: jax.Array, gate_idx: jax.Array, n_experts: int) -> jax.Array:
    """Switch/GShard load-balance loss: E · Σ_e f_e · p̄_e."""
    assign = jax.nn.one_hot(gate_idx[..., 0], n_experts, dtype=jnp.float32)
    f = jnp.mean(assign, axis=0)
    pbar = jnp.mean(probs, axis=0)
    return n_experts * jnp.sum(f * pbar)


def _expert_ffn(w_gate, w_up, w_down, x, act: str) -> jax.Array:
    """Per-expert gated FFN.  x: (E, C, D); weights (E, D, F)/(E, F, D)."""
    g = jnp.einsum("ecd,edf->ecf", x, w_gate)
    u = jnp.einsum("ecd,edf->ecf", x, w_up)
    h = _act(g, act) * u
    return jnp.einsum("ecf,efd->ecd", h, w_down)


# ---------------------------------------------------------------- reference
def moe_reference(params: Params, x: jax.Array, cfg: ModelConfig
                  ) -> Tuple[jax.Array, jax.Array]:
    """Dense single-device MoE (oracle; exact, no capacity drops)."""
    B, S, D = x.shape
    xf = x.reshape(-1, D)
    probs, gate_vals, gate_idx = _route(xf.astype(jnp.float32),
                                        params["router"].astype(jnp.float32),
                                        cfg.moe_topk)
    out = jnp.zeros_like(xf, dtype=jnp.float32)
    for e in range(cfg.n_experts):
        w = (gate_vals * (gate_idx == e)).sum(-1)  # (T,)
        h = _act(xf @ params["w_gate"][e].astype(x.dtype), cfg.act) * (
            xf @ params["w_up"][e].astype(x.dtype))
        y = (h @ params["w_down"][e].astype(x.dtype)).astype(jnp.float32)
        out = out + w[:, None] * y
    aux = _aux_loss(probs, gate_idx, cfg.n_experts)
    y = out.astype(x.dtype).reshape(B, S, D)
    if "shared" in params:
        from repro.nn.layers import mlp
        y = y + mlp(params["shared"], x, cfg.act)
    return y, aux


# ----------------------------------------------------------- sharded paths
def _pack_dispatch(xf, gate_vals, gate_idx, n_experts, capacity):
    """Sort-based capacity dispatch.  Returns (buffer (E, C, D), combine
    indices/weights for the return scatter)."""
    T, D = xf.shape
    k = gate_idx.shape[-1]
    flat_expert = gate_idx.reshape(-1)              # (T*k,)
    flat_token = jnp.repeat(jnp.arange(T), k)
    flat_gate = gate_vals.reshape(-1)
    # Position of each assignment within its expert (rank by arrival).
    onehot = jax.nn.one_hot(flat_expert, n_experts, dtype=jnp.int32)
    pos = (jnp.cumsum(onehot, axis=0) - 1) * onehot
    pos_in_expert = jnp.sum(pos, axis=-1)           # (T*k,)
    keep = pos_in_expert < capacity
    slot = jnp.where(keep, flat_expert * capacity + pos_in_expert, T * 0 - 1)
    buf = jnp.zeros((n_experts * capacity, D), xf.dtype)
    buf = buf.at[jnp.where(keep, slot, n_experts * capacity)].set(
        xf[flat_token], mode="drop")
    return (buf.reshape(n_experts, capacity, D),
            flat_token, slot, jnp.where(keep, flat_gate, 0.0))


def _moe_body_a2a(xb, router, w_gate, w_up, w_down, cfg: ModelConfig,
                  model_size: int):
    """Per-shard body (tokens seq-sharded over 'model')."""
    Bl, Sl, D = xb.shape
    xf = xb.reshape(-1, D)
    T = xf.shape[0]
    probs, gate_vals, gate_idx = _route(xf.astype(jnp.float32),
                                        router.astype(jnp.float32), cfg.moe_topk)
    aux = _aux_loss(probs, gate_idx, cfg.n_experts)
    cap = max(int(T * cfg.moe_topk * cfg.capacity_factor / cfg.n_experts), 4)
    buf, tok_idx, slot, gate = _pack_dispatch(xf, gate_vals, gate_idx,
                                              cfg.n_experts, cap)
    e_loc = cfg.n_experts // model_size
    # (E, C, D) -> (M, E_loc, C, D) -> exchange -> (M, E_loc, C, D) src-major
    buf = buf.reshape(model_size, e_loc, cap, D)
    recv = jax.lax.all_to_all(buf, "model", split_axis=0, concat_axis=0,
                              tiled=False)
    recv = recv.reshape(model_size, e_loc, cap, D)
    toks = recv.transpose(1, 0, 2, 3).reshape(e_loc, model_size * cap, D)
    # FSDP: weights arrive sharded over 'data' on the D (or F) dim.
    w_gate = jax.lax.all_gather(w_gate, "data", axis=1, tiled=True)
    w_up = jax.lax.all_gather(w_up, "data", axis=1, tiled=True)
    w_down = jax.lax.all_gather(w_down, "data", axis=2, tiled=True)
    y = _expert_ffn(w_gate.astype(xb.dtype), w_up.astype(xb.dtype),
                    w_down.astype(xb.dtype), toks, cfg.act)
    y = y.reshape(e_loc, model_size, cap, D).transpose(1, 0, 2, 3)
    back = jax.lax.all_to_all(y, "model", split_axis=0, concat_axis=0,
                              tiled=False)
    back = back.reshape(cfg.n_experts * cap, D)
    gathered = back[jnp.clip(slot, 0, cfg.n_experts * cap - 1)]
    contrib = gathered.astype(jnp.float32) * gate[:, None]
    out = jnp.zeros((T, D), jnp.float32).at[tok_idx].add(contrib)
    return out.astype(xb.dtype).reshape(Bl, Sl, D), aux


def _moe_body_replicated(xb, router, w_gate, w_up, w_down, cfg: ModelConfig,
                         model_size: int, model_idx):
    """Per-shard body (tokens replicated over 'model'; decode path)."""
    Bl, Sl, D = xb.shape
    xf = xb.reshape(-1, D)
    probs, gate_vals, gate_idx = _route(xf.astype(jnp.float32),
                                        router.astype(jnp.float32), cfg.moe_topk)
    aux = _aux_loss(probs, gate_idx, cfg.n_experts)
    e_loc = cfg.n_experts // model_size
    w_gate = jax.lax.all_gather(w_gate, "data", axis=1, tiled=True)
    w_up = jax.lax.all_gather(w_up, "data", axis=1, tiled=True)
    w_down = jax.lax.all_gather(w_down, "data", axis=2, tiled=True)
    # Evaluate every local expert on every token, weight by routing gates.
    xe = jnp.broadcast_to(xf[None], (e_loc,) + xf.shape)
    y = _expert_ffn(w_gate.astype(xb.dtype), w_up.astype(xb.dtype),
                    w_down.astype(xb.dtype), xe, cfg.act)  # (E_loc, T, D)
    local_ids = model_idx * e_loc + jnp.arange(e_loc)
    gates = jnp.sum(
        gate_vals[None] * (gate_idx[None] == local_ids[:, None, None]), -1)
    out = jnp.einsum("et,etd->td", gates.astype(jnp.float32),
                     y.astype(jnp.float32))
    out = jax.lax.psum(out, "model")
    aux = jax.lax.pmean(aux, "model")
    return out.astype(xb.dtype).reshape(Bl, Sl, D), aux


def moe(params: Params, x: jax.Array, cfg: ModelConfig,
        decode: bool = False) -> Tuple[jax.Array, jax.Array]:
    ctx = current_mesh()
    if ctx is None:
        return moe_reference(params, x, cfg)
    mesh = ctx.mesh
    model_size = mesh.shape["model"]
    ba = batch_axes()

    if decode or x.shape[1] == 1:
        def body(xb, router, wg, wu, wd):
            idx = jax.lax.axis_index("model")
            return _moe_body_replicated(xb, router, wg, wu, wd, cfg,
                                        model_size, idx)
        in_specs = (P(ba, None, None), P(None, None),
                    P("model", "data", None), P("model", "data", None),
                    P("model", None, "data"))
        out_specs = (P(ba, None, None), P())
    else:
        def body(xb, router, wg, wu, wd):
            return _moe_body_a2a(xb, router, wg, wu, wd, cfg, model_size)
        in_specs = (P(ba, "model", None), P(None, None),
                    P("model", "data", None), P("model", "data", None),
                    P("model", None, "data"))
        out_specs = (P(ba, "model", None), P())

    if hasattr(jax, "shard_map"):            # jax >= 0.6
        mapped = jax.shard_map(body, mesh=mesh, in_specs=in_specs,
                               out_specs=out_specs, check_vma=False)
    else:                                    # jax <= 0.5: experimental home
        from jax.experimental.shard_map import shard_map as _shard_map
        mapped = _shard_map(body, mesh=mesh, in_specs=in_specs,
                            out_specs=out_specs, check_rep=False)
    y, aux = mapped(
        x, params["router"], params["w_gate"], params["w_up"], params["w_down"])
    aux = jnp.mean(aux)
    if "shared" in params:
        from repro.nn.layers import mlp
        y = y + mlp(params["shared"], x, cfg.act)
    return y, aux
