"""State-space / recurrent blocks: Mamba (hymba) and xLSTM (mLSTM + sLSTM).

Memory discipline on TPU: a naive scan over 4k+ timesteps would save every
per-step state for the backward pass (O(S·state) — hundreds of GB for matrix
states).  Two remedies are used:

  * `chunked_scan` — outer scan over sequence chunks saving only boundary
    states; the inner chunk is rematerialized in the backward pass.  Used for
    Mamba's selective scan and the sLSTM (whose hidden-to-hidden recurrence
    admits no parallel form).
  * chunkwise-parallel mLSTM — the gated-linear-attention identity: within a
    chunk the output is an attention-like masked matmul with cumulative decay
    (all factors exp(c_t − c_s), s ≤ t, bounded ≤ 1 → numerically safe), and
    only O(S/K) boundary matrix states cross chunks.  This is the TPU-native
    adaptation of the mLSTM recurrence (MXU matmuls instead of a serial
    scan).

Deviation from the xLSTM paper (recorded in DESIGN.md): gates use sigmoid
(log-sigmoid cumulative decay) instead of the exp-gate + max-stabilizer
scheme; the paper itself reports sigmoid input gates are competitive, and the
chunkwise factors stay in [0, 1] by construction.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.nn.config import ModelConfig
from repro.nn.layers import _init, init_rmsnorm, rmsnorm

Params = Dict[str, Any]


# ---------------------------------------------------------- chunked scan
def chunked_scan(step, carry, xs, chunk: int, remat: bool = True,
                 unroll_outer: bool = False):
    """lax.scan(step, carry, xs) but with chunk-boundary checkpointing.

    xs leaves have leading dim S (padded to a multiple of ``chunk`` by the
    caller).  Only S/chunk boundary carries are saved for backward; each
    chunk's interior is recomputed.  ``unroll_outer`` unrolls the chunk loop
    (dry-run cost probes).
    """
    S = jax.tree_util.tree_leaves(xs)[0].shape[0]
    assert S % chunk == 0, f"sequence {S} not a multiple of chunk {chunk}"
    n = S // chunk
    xs_c = jax.tree.map(lambda a: a.reshape((n, chunk) + a.shape[1:]), xs)

    def run_chunk(c, x_chunk):
        return jax.lax.scan(step, c, x_chunk)

    if remat:
        run_chunk = jax.checkpoint(run_chunk, prevent_cse=False)

    if unroll_outer and n > 32:
        # cost probes cap the unroll: beyond this the probe compile time
        # explodes while the once-counted remainder (in-chunk cell ops) is
        # ≪1% of the projection FLOPs (EXPERIMENTS.md §Roofline).
        unroll_outer = False
    if unroll_outer:
        ys_list = []
        for i in range(n):
            carry, y = run_chunk(carry, jax.tree.map(lambda a, i=i: a[i], xs_c))
            ys_list.append(y)
        ys = jax.tree.map(lambda *a: jnp.stack(a, 0), *ys_list)
    else:
        carry, ys = jax.lax.scan(run_chunk, carry, xs_c)
    ys = jax.tree.map(lambda a: a.reshape((S,) + a.shape[2:]), ys)
    return carry, ys


# ================================================================== Mamba
def init_mamba(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    N = cfg.ssm_state
    dt_rank = max(d // 16, 8)
    ks = jax.random.split(key, 7)
    return {
        "w_in": _init(ks[0], (d, 2 * di), 0),
        "conv": jax.random.normal(ks[1], (cfg.ssm_conv_width, di)) * 0.1,
        "w_xproj": _init(ks[2], (di, dt_rank + 2 * N), 0),
        "w_dt": _init(ks[3], (dt_rank, di), 0),
        "dt_bias": jnp.zeros((di,)),
        "A_log": jnp.log(jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32), (di, 1))),
        "D": jnp.ones((di,)),
        "w_out": _init(ks[4], (di, d), 0),
    }


def mamba_specs(cfg: ModelConfig) -> Params:
    return {
        "w_in": ("fsdp", "tp"), "conv": (None, "tp"),
        "w_xproj": ("tp", None), "w_dt": (None, "tp"), "dt_bias": ("tp",),
        "A_log": ("tp", None), "D": ("tp",), "w_out": ("tp", "fsdp"),
    }


def _mamba_conv(x: jax.Array, conv_w: jax.Array,
                conv_state: Optional[jax.Array] = None,
                valid_len: Optional[jax.Array] = None):
    """Causal depthwise conv over seq.  x: (B, S, di), conv_w: (W, di).
    With ``valid_len`` (chunked prefill) the carried conv window ends at the
    last *valid* token instead of the padded chunk tail."""
    W = conv_w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    else:
        pad = conv_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * conv_w[i].astype(x.dtype)
              for i in range(W))
    if W <= 1:
        new_state = None
    elif valid_len is None:
        new_state = xp[:, -(W - 1):]
    else:
        new_state = jax.lax.dynamic_slice_in_dim(xp, valid_len, W - 1, axis=1)
    return out, new_state


def mamba(params: Params, x: jax.Array, cfg: ModelConfig,
          cache: Optional[Params] = None, chunk: int = 256,
          make_cache: bool = False, valid_len: Optional[jax.Array] = None
          ) -> Tuple[jax.Array, Optional[Params]]:
    """x: (B, S, d).  cache = {conv, h} for decode (S == 1).  cache with
    S > 1 is a chunked-prefill continuation: the recurrence resumes from the
    carried state, and only the first ``valid_len`` tokens of the chunk
    advance it (the padded tail is a frozen no-op)."""
    B, S, d = x.shape
    di = cfg.ssm_expand * d
    N = cfg.ssm_state
    dt_rank = params["w_dt"].shape[0]
    chunk_mode = cache is not None and S > 1

    u = jnp.einsum("bsd,de->bse", x, params["w_in"].astype(x.dtype))
    x_in, z = jnp.split(u, 2, axis=-1)
    conv_state = cache["conv"] if cache is not None else None
    x_c, new_conv = _mamba_conv(x_in, params["conv"], conv_state,
                                valid_len=valid_len if chunk_mode else None)
    x_c = jax.nn.silu(x_c)

    xdbc = jnp.einsum("bse,ef->bsf", x_c, params["w_xproj"].astype(x.dtype))
    dt_in, Bc, Cc = jnp.split(xdbc, [dt_rank, dt_rank + N], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,re->bse", dt_in, params["w_dt"].astype(x.dtype))
        .astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])  # (di, N)

    def step(h, inp):
        xc_t, dt_t, b_t, c_t = inp  # (B,di),(B,di),(B,N),(B,N)
        dA = jnp.exp(dt_t[..., None] * A)                      # (B,di,N)
        dBx = dt_t[..., None] * b_t[:, None, :] * xc_t[..., None]
        h = dA * h + dBx
        y = jnp.einsum("ben,bn->be", h, c_t)
        return h, y

    if cache is not None and not chunk_mode:
        h0 = cache["h"]
        xs = (x_c[:, 0].astype(jnp.float32), dt[:, 0],
              Bc[:, 0].astype(jnp.float32), Cc[:, 0].astype(jnp.float32))
        h1, y = step(h0, xs)
        y = y[:, None]
        new_cache = {"conv": new_conv, "h": h1}
    else:
        h0 = cache["h"] if chunk_mode else jnp.zeros((B, di, N), jnp.float32)
        if chunk_mode and valid_len is not None:
            # Freeze the recurrence past the chunk's valid tokens: dt = 0
            # makes the state update the identity (dA = 1, dBx = 0), exactly
            # like the zero-padded tail of the monolithic scan below.
            dt = dt * (jnp.arange(S) < valid_len)[None, :, None]
        xs = (x_c.swapaxes(0, 1).astype(jnp.float32), dt.swapaxes(0, 1),
              Bc.swapaxes(0, 1).astype(jnp.float32),
              Cc.swapaxes(0, 1).astype(jnp.float32))
        pad = (-S) % chunk
        if pad:
            xs = jax.tree.map(
                lambda a: jnp.pad(a, ((0, pad),) + ((0, 0),) * (a.ndim - 1)), xs)
        hT, ys = chunked_scan(step, h0, xs, chunk=min(chunk, S + pad),
                              unroll_outer=cfg.unroll_chunks)
        y = ys[:S].swapaxes(0, 1)
        new_cache = None
        if make_cache or chunk_mode:
            # prefill: hand the final recurrent + conv state to decode
            new_cache = {"conv": new_conv, "h": hT}

    y = y.astype(x.dtype) + params["D"].astype(x.dtype) * x_c
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, params["w_out"].astype(x.dtype))
    return out, new_cache


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16) -> Params:
    di = cfg.ssm_expand * cfg.d_model
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, di), dtype),
        "h": jnp.zeros((batch, di, cfg.ssm_state), jnp.float32),
    }


# ================================================================== mLSTM
def init_mlstm(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    ks = jax.random.split(key, 8)
    return {
        "w_in": _init(ks[0], (d, 2 * di), 0),     # -> (x_m, z)
        "w_q": _init(ks[1], (di, di), 0),
        "w_k": _init(ks[2], (di, di), 0),
        "w_v": _init(ks[3], (di, di), 0),
        "w_if": _init(ks[4], (di, 2 * cfg.ssm_heads), 0),
        "if_bias": jnp.concatenate([jnp.zeros((cfg.ssm_heads,)),
                                    3.0 * jnp.ones((cfg.ssm_heads,))]),
        "norm": init_rmsnorm(di),
        "w_out": _init(ks[5], (di, d), 0),
    }


def mlstm_specs(cfg: ModelConfig) -> Params:
    return {
        "w_in": ("fsdp", "tp"), "w_q": ("fsdp", "tp"), "w_k": ("fsdp", "tp"),
        "w_v": ("fsdp", "tp"), "w_if": ("fsdp", None), "if_bias": (None,),
        "norm": {"scale": (None,)}, "w_out": ("tp", "fsdp"),
    }


def _mlstm_chunk(q, k, v, log_f, i_gate, S0, n0):
    """One chunk of the chunkwise-parallel mLSTM.

    q,k,v: (B,H,K,D); log_f,i_gate: (B,H,K); S0: (B,H,D,D); n0: (B,H,D).
    Returns (y (B,H,K,D), S1, n1).  All decay factors are exp of
    *differences* of the cumulative log-forget c, hence ≤ 1.
    """
    c = jnp.cumsum(log_f, axis=-1)                      # (B,H,K)
    c_last = c[..., -1:]
    # Inter-chunk contribution: q_t · S0 scaled by exp(c_t).
    y_inter = jnp.einsum("bhkd,bhde->bhke", q, S0) * jnp.exp(c)[..., None]
    n_inter = jnp.einsum("bhkd,bhd->bhk", q, n0) * jnp.exp(c)
    # Intra-chunk: A[t,s] = exp(c_t - c_s) · i_s  for s ≤ t.
    decay = jnp.exp(c[..., :, None] - c[..., None, :])
    mask = jnp.tril(jnp.ones((q.shape[2], q.shape[2]), bool))
    A = jnp.where(mask, decay * i_gate[..., None, :], 0.0)
    scores = jnp.einsum("bhkd,bhsd->bhks", q, k) * A
    y_intra = jnp.einsum("bhks,bhsd->bhkd", scores, v)
    # n_t = Σ_{s≤t} exp(c_t-c_s) i_s k_s  + exp(c_t) n0 ;  denom = max(|q·n|,1)
    n_vec = jnp.einsum("bhks,bhsd->bhkd", A, k)
    qn = jnp.einsum("bhkd,bhkd->bhk", q, n_vec) + n_inter
    denom = jnp.maximum(jnp.abs(qn), 1.0)[..., None]
    y = (y_inter + y_intra) / denom
    # State update to chunk end.
    w = jnp.exp(c_last - c) * i_gate                    # (B,H,K)
    S1 = jnp.exp(c_last)[..., None] * S0 + jnp.einsum(
        "bhk,bhkd,bhke->bhde", w, k, v)
    n1 = jnp.exp(c_last) * n0 + jnp.einsum("bhk,bhkd->bhd", w, k)
    return y, S1, n1


def mlstm(params: Params, x: jax.Array, cfg: ModelConfig,
          cache: Optional[Params] = None, chunk: int = 256,
          make_cache: bool = False, valid_len: Optional[jax.Array] = None
          ) -> Tuple[jax.Array, Optional[Params]]:
    B, S, d = x.shape
    di = cfg.ssm_expand * d
    H = cfg.ssm_heads
    D = di // H

    u = jnp.einsum("bsd,de->bse", x, params["w_in"].astype(x.dtype))
    xm, z = jnp.split(u, 2, axis=-1)
    q = jnp.einsum("bse,ef->bsf", xm, params["w_q"].astype(x.dtype))
    k = jnp.einsum("bse,ef->bsf", xm, params["w_k"].astype(x.dtype)) / math.sqrt(D)
    v = jnp.einsum("bse,ef->bsf", xm, params["w_v"].astype(x.dtype))
    gates = jnp.einsum("bse,eg->bsg", xm, params["w_if"].astype(x.dtype))
    gates = gates.astype(jnp.float32) + params["if_bias"]
    i_gate = jax.nn.sigmoid(gates[..., :H])            # (B,S,H)
    log_f = jax.nn.log_sigmoid(gates[..., H:])         # (B,S,H) ≤ 0

    def heads(t):  # (B,S,di) -> (B,H,S,D)
        return t.reshape(B, S, H, D).transpose(0, 2, 1, 3).astype(jnp.float32)

    qh, kh, vh = heads(q), heads(k), heads(v)
    i_g = i_gate.transpose(0, 2, 1)
    lf = log_f.transpose(0, 2, 1)

    if cache is not None and S == 1:  # decode: single step, direct recurrence
        S0, n0 = cache["S"], cache["n"]
        f1 = jnp.exp(lf[..., 0])                       # (B,H)
        i1 = i_g[..., 0]
        S1 = f1[..., None, None] * S0 + i1[..., None, None] * jnp.einsum(
            "bhd,bhe->bhde", kh[:, :, 0], vh[:, :, 0])
        n1 = f1[..., None] * n0 + i1[..., None] * kh[:, :, 0]
        qn = jnp.einsum("bhd,bhd->bh", qh[:, :, 0], n1)
        y = jnp.einsum("bhd,bhde->bhe", qh[:, :, 0], S1)
        y = y / jnp.maximum(jnp.abs(qn), 1.0)[..., None]
        y = y[:, :, None, :]                           # (B,H,1,D)
        new_cache = {"S": S1, "n": n1}
    else:
        if cache is not None and valid_len is not None:
            # chunked prefill: zeroed gates make a step the identity
            # (i = 0 adds nothing, log_f = 0 applies no decay) — the padded
            # chunk tail leaves the carried state untouched, matching the
            # zero-padding of the monolithic path below.
            keep = (jnp.arange(S) < valid_len)[None, None, :]
            i_g = i_g * keep
            lf = lf * keep
        pad = (-S) % chunk
        Kc = min(chunk, S + pad)
        nch = (S + pad) // Kc

        def pad_seq(t, axis):
            cfg_pad = [(0, 0)] * t.ndim
            cfg_pad[axis] = (0, pad)
            return jnp.pad(t, cfg_pad)

        qh, kh, vh = (pad_seq(t, 2) for t in (qh, kh, vh))
        i_g, lf = pad_seq(i_g, 2), pad_seq(lf, 2)
        qc = qh.reshape(B, H, nch, Kc, D).transpose(2, 0, 1, 3, 4)
        kc = kh.reshape(B, H, nch, Kc, D).transpose(2, 0, 1, 3, 4)
        vc = vh.reshape(B, H, nch, Kc, D).transpose(2, 0, 1, 3, 4)
        ic = i_g.reshape(B, H, nch, Kc).transpose(2, 0, 1, 3)
        fc = lf.reshape(B, H, nch, Kc).transpose(2, 0, 1, 3)

        def step(carry, xs):
            S0, n0 = carry
            qx, kx, vx, ix, fx = xs
            y, S1, n1 = _mlstm_chunk(qx, kx, vx, fx, ix, S0, n0)
            return (S1, n1), y

        if cache is not None:
            S0, n0 = cache["S"], cache["n"]
        else:
            S0 = jnp.zeros((B, H, D, D), jnp.float32)
            n0 = jnp.zeros((B, H, D), jnp.float32)
        if cfg.unroll_chunks and nch <= 32:  # cost probes (cap: compile time)
            carry, ys_l = (S0, n0), []
            for t in range(nch):
                carry, y = step(carry, (qc[t], kc[t], vc[t], ic[t], fc[t]))
                ys_l.append(y)
            (S1, n1), ys = carry, jnp.stack(ys_l, 0)
        else:
            (S1, n1), ys = jax.lax.scan(step, (S0, n0), (qc, kc, vc, ic, fc))
        y = ys.transpose(1, 2, 0, 3, 4).reshape(B, H, S + pad, D)[:, :, :S]
        new_cache = ({"S": S1, "n": n1}
                     if (make_cache or cache is not None) else None)

    y = y.transpose(0, 2, 1, 3).reshape(B, -1, di).astype(x.dtype)
    y = rmsnorm(params["norm"], y, cfg.norm_eps)
    y = y * jax.nn.silu(z[:, : y.shape[1]])
    out = jnp.einsum("bse,ed->bsd", y, params["w_out"].astype(x.dtype))
    return out, new_cache


def init_mlstm_cache(cfg: ModelConfig, batch: int) -> Params:
    di = cfg.ssm_expand * cfg.d_model
    H = cfg.ssm_heads
    D = di // H
    return {"S": jnp.zeros((batch, H, D, D), jnp.float32),
            "n": jnp.zeros((batch, H, D), jnp.float32)}


# ================================================================== sLSTM
def init_slstm(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    H = cfg.ssm_heads
    D = d // H
    ks = jax.random.split(key, 3)
    return {
        "w_gates": _init(ks[0], (d, 4 * d), 0),        # i, f, z, o pre-acts
        "r_gates": jax.random.normal(ks[1], (H, D, 4 * D)) / math.sqrt(D),
        "gate_bias": jnp.concatenate(
            [jnp.zeros((d,)), 2.0 * jnp.ones((d,)), jnp.zeros((2 * d,))]),
        "norm": init_rmsnorm(d),
        "w_out": _init(ks[2], (d, d), 0),
    }


def slstm_specs(cfg: ModelConfig) -> Params:
    return {"w_gates": ("fsdp", "tp"), "r_gates": (None, None, None),
            "gate_bias": (None,), "norm": {"scale": (None,)},
            "w_out": ("fsdp", "tp")}


def slstm(params: Params, x: jax.Array, cfg: ModelConfig,
          cache: Optional[Params] = None, chunk: int = 128,
          make_cache: bool = False, valid_len: Optional[jax.Array] = None
          ) -> Tuple[jax.Array, Optional[Params]]:
    B, S, d = x.shape
    H = cfg.ssm_heads
    D = d // H
    pre = jnp.einsum("bsd,dg->bsg", x, params["w_gates"].astype(x.dtype))
    pre = pre.astype(jnp.float32) + params["gate_bias"]

    r_g = params["r_gates"]

    def step(carry, inp):
        # The hidden-to-hidden recurrence has no zero-input identity (the
        # gate biases alone move the state), so padded steps carry an
        # explicit keep flag and are skipped via select — the carried state
        # is the state after exactly the valid tokens, for the monolithic
        # scan's chunk padding and the chunked-prefill tail alike.
        p_t, ok = inp
        c, n, h = carry                                 # (B,H,D) each
        rec = jnp.einsum("bhd,hdg->bhg", h, r_g)        # (B,H,4D)
        g = p_t.reshape(B, H, 4 * D) + rec
        i_, f_, z_, o_ = jnp.split(g, 4, axis=-1)
        i_ = jnp.exp(jnp.minimum(i_, 10.0))             # exp input gate, capped
        f_ = jax.nn.sigmoid(f_)
        z_ = jnp.tanh(z_)
        o_ = jax.nn.sigmoid(o_)
        c2 = f_ * c + i_ * z_
        n2 = f_ * n + i_
        h2 = o_ * c2 / jnp.maximum(jnp.abs(n2), 1.0)
        carry = tuple(jnp.where(ok, new, old)
                      for new, old in ((c2, c), (n2, n), (h2, h)))
        return carry, carry[2]

    if cache is not None and S == 1:
        carry = (cache["c"], cache["n"], cache["h"])
        carry, h = step(carry, (pre[:, 0], jnp.bool_(True)))
        y = h[:, None]
        new_cache = dict(zip(("c", "n", "h"), carry))
    else:
        if cache is not None:
            carry0 = (cache["c"], cache["n"], cache["h"])
        else:
            zero = jnp.zeros((B, H, D), jnp.float32)
            carry0 = (zero, zero, zero)
        n_valid = jnp.int32(S) if valid_len is None else valid_len
        pad = (-S) % chunk
        keep = jnp.pad(jnp.arange(S) < n_valid, (0, pad))
        xs = (jnp.pad(pre, ((0, 0), (0, pad), (0, 0))).swapaxes(0, 1), keep)
        carry, ys = chunked_scan(step, carry0, xs,
                                 chunk=min(chunk, S + pad),
                                 unroll_outer=cfg.unroll_chunks)
        y = ys[:S].swapaxes(0, 1)
        new_cache = (dict(zip(("c", "n", "h"), carry))
                     if (make_cache or cache is not None) else None)

    y = y.reshape(B, -1, d).astype(x.dtype)
    y = rmsnorm(params["norm"], y, cfg.norm_eps)
    return jnp.einsum("bsd,de->bse", y, params["w_out"].astype(x.dtype)), new_cache


def init_slstm_cache(cfg: ModelConfig, batch: int) -> Params:
    H = cfg.ssm_heads
    D = cfg.d_model // H
    z = jnp.zeros((batch, H, D), jnp.float32)
    return {"c": z, "n": z, "h": z}
