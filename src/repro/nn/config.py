"""Unified model configuration covering the 10 assigned architectures."""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encoder | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None   # defaults to d_model // n_heads

    # attention
    attn_type: str = "gqa"           # gqa | mla | none
    causal: bool = True
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    sliding_window: int = 0          # 0 = full attention
    global_layers: Tuple[int, ...] = ()  # layers overriding sliding window
    logit_softcap: float = 0.0

    # FFN
    act: str = "silu"                # silu | gelu (gelu → GeGLU when gated)
    gated: bool = True

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    moe_topk: int = 0
    d_ff_expert: int = 0
    first_dense_layers: int = 0      # leading dense layers (deepseek)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01

    # MLA (deepseek)
    kv_lora_rank: int = 0
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128

    # SSM
    ssm_state: int = 0
    ssm_conv_width: int = 4
    ssm_expand: int = 2
    ssm_heads: int = 0               # xlstm heads
    slstm_every: int = 0             # 1-in-N blocks are sLSTM (xlstm)
    hybrid_parallel: bool = False    # hymba: attn ∥ mamba in every block

    # modality frontend stubs
    input_mode: str = "tokens"       # tokens | embeddings | prefix_vlm
    prefix_len: int = 0              # image patches for VLM prefix

    # misc
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    scan_layers: bool = True         # lax.scan over stacked layer params
    remat: bool = True
    # KV cache numerics: 'bf16' or 'int8' (per-token-per-head absmax scales;
    # the paper's INT8-cell storage applied to the KV crossbar — halves the
    # dominant decode HBM footprint).  GQA caches only; MLA's latent cache
    # is already compressed.
    kv_cache_dtype: str = "bf16"
    # Dry-run cost probes only: replace lax.scan/map chunk loops with python
    # loops so XLA cost_analysis (which counts while bodies once) sees every
    # FLOP.  Never enabled on the real execution path.
    unroll_chunks: bool = False
    attn_q_chunk: int = 512
    attn_kv_chunk: int = 1024

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def vocab_padded(self) -> int:
        """Vocab padded to a multiple of 256 so it shards over 16-way TP."""
        return math.ceil(self.vocab / 256) * 256

    @property
    def is_encoder(self) -> bool:
        return not self.causal

    @property
    def layer_is_moe(self) -> Tuple[bool, ...]:
        if self.n_experts == 0:
            return tuple(False for _ in range(self.n_layers))
        return tuple(i >= self.first_dense_layers for i in range(self.n_layers))

    def window_for_layer(self, i: int) -> int:
        if self.sliding_window and i not in self.global_layers:
            return self.sliding_window
        return 0  # full attention

    def param_count(self) -> int:
        """Approximate parameter count (embeddings included once if tied)."""
        d, l = self.d_model, self.n_layers
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        if self.attn_type == "mla":
            attn = d * self.n_heads * (self.qk_nope_dim + self.qk_rope_dim)
            attn += d * (self.kv_lora_rank + self.qk_rope_dim)
            attn += self.kv_lora_rank * self.n_heads * (self.qk_nope_dim + self.v_head_dim)
            attn += self.n_heads * self.v_head_dim * d
        elif self.attn_type == "none":
            attn = 0
        else:
            attn = d * (self.q_dim + 2 * self.kv_dim) + self.q_dim * d
        ff_mult = 3 if self.gated else 2
        dense_ff = ff_mult * d * self.d_ff
        n_moe = sum(self.layer_is_moe)
        n_dense = l - n_moe
        moe_ff = ff_mult * d * self.d_ff_expert * (self.n_experts + self.n_shared_experts)
        total = emb + l * attn + n_dense * dense_ff + n_moe * moe_ff
        if self.hybrid_parallel:
            di = self.ssm_expand * d
            total += l * (2 * d * di + di * d + di * (2 * self.ssm_state + 2))
        if self.family == "ssm":
            # xlstm blocks replace attention entirely; rough estimate
            di = self.ssm_expand * d
            total = emb + l * (2 * d * di + di * d + 4 * di * di // max(self.ssm_heads, 1))
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k + shared only)."""
        if self.n_experts == 0:
            return self.param_count()
        d, l = self.d_model, self.n_layers
        full = self.param_count()
        ff_mult = 3 if self.gated else 2
        moe_all = ff_mult * d * self.d_ff_expert * self.n_experts
        moe_active = ff_mult * d * self.d_ff_expert * self.moe_topk
        n_moe = sum(self.layer_is_moe)
        return int(full - n_moe * (moe_all - moe_active))
