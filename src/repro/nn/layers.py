"""Basic layers: RMSNorm, embeddings, rotary, gated MLP — pure JAX.

Every ``init_*`` has a matching ``*_specs`` returning the same pytree
structure filled with logical PartitionSpec tuples (consumed by
`repro.parallel.sharding.make_spec`).  Weights are FSDP-sharded over 'fsdp'
(= data axis) and tensor-parallel over 'tp' (= model axis).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.nn.config import ModelConfig
from repro.parallel.sharding import shard

Params = Dict[str, Any]
Specs = Dict[str, Any]


def _init(key, shape, scale_axis: int, dtype=jnp.float32):
    fan_in = shape[scale_axis]
    return jax.random.normal(key, shape, dtype) / jnp.sqrt(fan_in)


# ---------------------------------------------------------------- RMSNorm
def init_rmsnorm(d: int) -> Params:
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm_specs() -> Specs:
    return {"scale": (None,)}


def rmsnorm(params: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * params["scale"]).astype(dt)


# ---------------------------------------------------------------- Embedding
def init_embedding(key, cfg: ModelConfig) -> Params:
    p = {"table": jax.random.normal(key, (cfg.vocab_padded, cfg.d_model)) * 0.02}
    if not cfg.tie_embeddings:
        p["head"] = _init(jax.random.fold_in(key, 1),
                          (cfg.d_model, cfg.vocab_padded), 0)
    return p


def embedding_specs(cfg: ModelConfig) -> Specs:
    s = {"table": ("tp", "fsdp")}
    if not cfg.tie_embeddings:
        s["head"] = ("fsdp", "tp")
    return s


def embed(params: Params, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    x = params["table"].astype(jnp.bfloat16)[tokens]
    return shard(x, "batch", "sp", None)


def unembed(params: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    table = params.get("head")
    if table is None:
        table = params["table"].T
    logits = jnp.einsum("btd,dv->btv", x, table.astype(jnp.bfloat16))
    return shard(logits, "batch", None, "tp")


# ---------------------------------------------------------------- Rotary
def rope_angles(positions: jax.Array, dim: int, theta: float) -> Tuple[jax.Array, jax.Array]:
    """positions (...,) -> cos/sin of shape (..., dim//2)."""
    freqs = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (..., seq, heads, dim); cos/sin: (..., seq, dim//2)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    x1, x2 = jnp.split(x, 2, axis=-1)
    c = cos[..., None, :]
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(dt)


# ---------------------------------------------------------------- Gated MLP
def init_mlp(key, d_in: int, d_ff: int, gated: bool) -> Params:
    ks = jax.random.split(key, 3)
    p = {"w_up": _init(ks[0], (d_in, d_ff), 0),
         "w_down": _init(ks[1], (d_ff, d_in), 0)}
    if gated:
        p["w_gate"] = _init(ks[2], (d_in, d_ff), 0)
    return p


def mlp_specs(gated: bool) -> Specs:
    s = {"w_up": ("fsdp", "tp"), "w_down": ("tp", "fsdp")}
    if gated:
        s["w_gate"] = ("fsdp", "tp")
    return s


def _act(x: jax.Array, kind: str) -> jax.Array:
    if kind == "gelu":
        return jax.nn.gelu(x)
    return jax.nn.silu(x)


def mlp(params: Params, x: jax.Array, act: str = "silu") -> jax.Array:
    """x: (..., d).  Hidden activations are TP-sharded over 'tp'."""
    h = jnp.einsum("...d,df->...f", x, params["w_up"].astype(x.dtype))
    if "w_gate" in params:
        g = jnp.einsum("...d,df->...f", x, params["w_gate"].astype(x.dtype))
        h = _act(g, act) * h
    else:
        h = _act(h, act)
    h = shard(h, "batch", None, "tp")
    return jnp.einsum("...f,fd->...d", h, params["w_down"].astype(x.dtype))
