"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch minicpm-2b --smoke \
        --steps 50 --batch 8 --seq 128

Production features wired here: deterministic step-indexed data (resume =
set step), periodic async checkpoints with atomic rename, emergency
checkpoint on watchdog timeout, straggler statistics, elastic restore (a
checkpoint taken on one mesh restores onto another via
`checkpoint.restore_checkpoint(shardings=...)`).
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.configs import ARCHS, get_config
from repro.data.pipeline import DataConfig, make_batch
from repro.ft import StepTimer, Watchdog
from repro.launch.mesh import make_debug_mesh, make_production_mesh
from repro.launch.steps import make_train_step, param_shardings, opt_shardings, batch_shardings
from repro.nn.model import init_params
from repro.optim.adamw import adamw_init
from repro.parallel.sharding import use_mesh


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", choices=ARCHS, default="minicpm-2b")
    p.add_argument("--smoke", action="store_true",
                   help="reduced config (CPU-sized)")
    p.add_argument("--steps", type=int, default=20)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--lr", type=float, default=3e-4)
    p.add_argument("--ckpt-dir", default=None)
    p.add_argument("--ckpt-every", type=int, default=100)
    p.add_argument("--mesh", choices=("none", "debug", "pod", "multipod"),
                   default="none")
    p.add_argument("--watchdog-s", type=float, default=600.0)
    args = p.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    data = DataConfig(seq_len=args.seq, global_batch=args.batch)

    mesh = None
    if args.mesh == "debug":
        mesh = make_debug_mesh(1, 1)
    elif args.mesh == "pod":
        mesh = make_production_mesh(multi_pod=False)
    elif args.mesh == "multipod":
        mesh = make_production_mesh(multi_pod=True)

    with use_mesh(mesh):
        params = init_params(jax.random.PRNGKey(0), cfg)
        params = jax.tree.map(
            lambda a: a.astype(jnp.bfloat16)
            if jnp.issubdtype(a.dtype, jnp.floating) and a.ndim >= 2 else a,
            params)
        opt_state = adamw_init(params)
        start = 0
        if args.ckpt_dir:
            last = latest_step(args.ckpt_dir)
            if last is not None:
                shardings = None
                if mesh is not None:
                    psh = param_shardings(cfg, mesh)
                    shardings = {"params": psh,
                                 "opt": opt_shardings(psh, mesh)}
                state = restore_checkpoint(
                    args.ckpt_dir, last, {"params": params, "opt": opt_state},
                    shardings)
                params, opt_state = state["params"], state["opt"]
                start = last
                print(f"resumed from step {last}")

        step_fn = jax.jit(make_train_step(cfg, peak_lr=args.lr),
                          donate_argnums=(0, 1))

        timer = StepTimer()

        def emergency(step: int) -> None:
            if args.ckpt_dir:
                print(f"WATCHDOG: step {step} hung; emergency checkpoint")
                save_checkpoint(args.ckpt_dir, step,
                                {"params": params, "opt": opt_state})

        wd = Watchdog(args.watchdog_s, on_timeout=emergency)

        for step in range(start, args.steps):
            batch = {k: jnp.asarray(v) for k, v in
                     make_batch(cfg, data, step).items()}
            timer.start()
            with wd.armed(step):
                params, opt_state, metrics = step_fn(
                    params, opt_state, batch, jnp.int32(step))
                metrics = jax.device_get(metrics)
            dt = timer.stop()
            rep = timer.report(step)
            flag = " STRAGGLER" if rep.flagged else ""
            print(f"step {step:5d} loss={metrics['loss']:.4f} "
                  f"ce={metrics['ce']:.4f} gnorm={metrics['grad_norm']:.3f} "
                  f"lr={metrics['lr']:.2e} {dt*1e3:8.1f} ms{flag}",
                  flush=True)
            if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
                save_checkpoint(args.ckpt_dir, step + 1,
                                {"params": params, "opt": opt_state},
                                block=False)
        if args.ckpt_dir:
            save_checkpoint(args.ckpt_dir, args.steps,
                            {"params": params, "opt": opt_state})
        print("done")


if __name__ == "__main__":
    main()
