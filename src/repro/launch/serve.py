"""Serving launcher: batched prefill + decode, the ARAS streaming executor
(weights larger than the device arena), or the continuous-batching engine
(many concurrent requests across multiple tenant models).

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-7b --smoke \
        --batch 4 --prompt-len 32 --gen 16
    PYTHONPATH=src python -m repro.launch.serve --arch minicpm-2b --smoke \
        --streaming --arena-slots 3
    PYTHONPATH=src python -m repro.launch.serve --smoke --engine
"""
from __future__ import annotations

import argparse
import atexit
import json
import signal
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config, supported_shapes
from repro.data.pipeline import DataConfig, make_batch
from repro.launch.steps import cached_prefill_step, cached_serve_step
from repro.nn.model import init_params


def _json_safe(obj):
    """NaN/inf -> None recursively, so metrics dumps are strict JSON."""
    if isinstance(obj, dict):
        return {k: _json_safe(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_json_safe(v) for v in obj]
    if isinstance(obj, float) and not np.isfinite(obj):
        return None
    return obj


def _run_engine(args) -> None:
    """Continuous batching across ≥ 2 tenants on one device budget."""
    from repro.serving import (EngineModel, FlightRecorder, InstallCostModel,
                               PromEndpoint, SchedulerConfig, ServingEngine,
                               SLOConfig, TelemetryConfig, Tracer,
                               VirtualClock, drive_simulated, format_summary,
                               prometheus_text)
    from repro.serving.variants import perturbed_variant

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.prefix_cache and args.kv_layout != "paged":
        raise SystemExit("--prefix-cache needs --kv-layout paged "
                         "(slot arenas have no pages to retain)")
    if args.kernel_backend == "pallas" and args.kv_layout != "paged":
        raise SystemExit("--kernel-backend pallas needs --kv-layout paged "
                         "(the kernel reads a page pool)")
    max_seq = args.prompt_len + args.gen + 8
    base = init_params(jax.random.PRNGKey(0), cfg)
    # tenant-b is a perturbed variant of tenant-a (the co-hosted fine-tune
    # regime where cross-tenant §V-C delta installs have real structure).
    variant = perturbed_variant(base)
    kv = dict(kv_slots=args.kv_slots, max_seq=max_seq,
              kv_layout=args.kv_layout, page_size=args.page_size,
              prefix_cache=args.prefix_cache,
              prefix_cache_pages=args.prefix_cache_pages,
              kernel_backend=args.kernel_backend)
    tenants = [
        EngineModel("tenant-a", base, cfg, **kv),
        EngineModel("tenant-b", variant, cfg, **kv),
    ]
    # A weight arena smaller than both tenants' layer sets forces ARAS-style
    # cross-tenant delta installs when the scheduler switches models.
    weight_slots = (args.weight_slots if args.weight_slots
                    else cfg.n_layers + 1)
    # Structured tracing costs nothing unless asked for: a wall-clock
    # Tracer feeds both the Chrome-trace export and the per-step
    # component_s breakdown in the summary.  --virtual-clock swaps the
    # wall clock for a VirtualClock and drives arrivals in simulated
    # time, so every artifact (trace, health, flight dumps, events) is
    # byte-deterministic — the CI telemetry-validation mode.
    clock = VirtualClock() if args.virtual_clock else time.perf_counter
    tracer = Tracer(clock=clock) if args.trace_out else None

    # Live telemetry plane: declared SLO targets + windowed percentiles
    # (constructing a TelemetryConfig turns the plane on — any exporter
    # or SLO flag implies it), plus the bounded flight recorder dumped
    # on retirement / SLO breach / stall / SIGUSR1 / crash.
    slo = None
    if args.slo_ttft_p95 or args.slo_itl_p95 or args.slo_queue_wait_p95:
        slo = SLOConfig(ttft_p95_s=args.slo_ttft_p95,
                        itl_p95_s=args.slo_itl_p95,
                        queue_wait_p95_s=args.slo_queue_wait_p95)
    telemetry = None
    if (slo is not None or args.events_out or args.prom_out
            or args.prom_port):
        telemetry = TelemetryConfig(window=args.telemetry_window, slo=slo,
                                    events_path=args.events_out)
    recorder = (FlightRecorder(args.flight_recorder_steps,
                               out_dir=args.flight_dir)
                if args.flight_recorder_steps else None)
    eng = ServingEngine(
        tenants, weight_arena_slots=weight_slots, tracer=tracer,
        clock=clock, telemetry=telemetry, recorder=recorder,
        stall_timeout_s=args.stall_timeout_s,
        sched=SchedulerConfig(max_prefill_per_step=4,
                              model_turn_steps=args.turn_steps,
                              policy=args.queue_policy,
                              prefill_token_budget=(
                                  args.prefill_token_budget or None)),
        install_ticks_per_step=args.install_ticks_per_step,
        overlap_installs=args.overlap_installs,
        install_cost=InstallCostModel(
            bytes_per_tick=args.install_bytes_per_tick),
        prefill_chunk=args.prefill_chunk,
        bucket_growth=args.bucket_growth,
        staging_growth=args.staging_growth,
        fuse_sampling=not args.no_fuse_sampling,
        wear_aware=args.wear_aware,
        fault_rate=args.fault_rate,
        fault_seed=args.fault_seed)
    if recorder is not None:
        # live-incident hooks: kill -USR1 <pid> snapshots the ring of a
        # running replica; an unhandled crash dumps it on the way down
        recorder.install_signal_handler()
        recorder.install_excepthook()
    endpoint = None
    if args.prom_port:
        endpoint = PromEndpoint(
            args.prom_port,
            lambda: prometheus_text(eng.metrics.registry, eng.telemetry))
        print("prometheus endpoint on "
              f"http://127.0.0.1:{endpoint.port}/metrics")

    # Artifact flush runs exactly once, whether the run completes, the
    # user hits Ctrl-C (KeyboardInterrupt unwinds to interpreter exit →
    # atexit), or the process is SIGTERMed (handler turns it into a normal
    # exit so atexit still fires) — a half-hour serving run killed early
    # still leaves its trace, metrics, and wear map on disk.
    done = {"flushed": False}

    def flush() -> None:
        if done["flushed"]:
            return
        done["flushed"] = True
        if args.trace_out:
            tracer.export_chrome_trace(args.trace_out)
            print(f"wrote Chrome trace ({len(tracer.events)} events) to "
                  f"{args.trace_out} — load in chrome://tracing or "
                  "https://ui.perfetto.dev")
        if args.metrics_json:
            doc = {"summary": _json_safe(eng.summary()),
                   "metrics": _json_safe(eng.metrics.registry.as_dict())}
            with open(args.metrics_json, "w") as f:
                json.dump(doc, f, indent=2, sort_keys=True)
                f.write("\n")
            print(f"wrote metrics registry + summary to {args.metrics_json}")
        if args.wear_json:
            with open(args.wear_json, "w") as f:
                json.dump(eng.wear.as_json(), f, indent=2, sort_keys=True)
                f.write("\n")
            print(f"wrote wear map ({len(eng.wear.planes)} planes) to "
                  f"{args.wear_json}")
        if args.prom_out:
            with open(args.prom_out, "w") as f:
                f.write(prometheus_text(eng.metrics.registry, eng.telemetry))
            print(f"wrote Prometheus text exposition to {args.prom_out}")
        if eng.telemetry is not None:
            eng.telemetry.close()
        if recorder is not None and recorder.dumps:
            print(f"flight recorder wrote {len(recorder.dumps)} dump(s): "
                  + ", ".join(recorder.dumps))

    if (args.trace_out or args.metrics_json or args.wear_json
            or args.prom_out or args.events_out):
        atexit.register(flush)
        signal.signal(signal.SIGTERM, lambda signum, frame: sys.exit(1))

    rng = np.random.default_rng(0)
    if args.virtual_clock:
        # deterministic Poisson-ish arrivals in simulated time (mean gap
        # 4 ms, step dt 2 ms): the whole run — tokens, health, dumps,
        # events — is byte-reproducible, no device clock involved
        t, vjobs = 0.0, []
        for i in range(args.requests):
            model = tenants[i % len(tenants)].name
            plen = int(rng.integers(max(args.prompt_len // 2, 2),
                                    args.prompt_len + 1))
            prompt = rng.integers(1, cfg.vocab, plen).tolist()
            vjobs.append((t, model, prompt, args.gen))
            t += float(rng.exponential(0.004))
        summary = drive_simulated(eng, clock, vjobs, dt=0.002)
    else:
        for i in range(args.requests):
            model = tenants[i % len(tenants)].name
            plen = int(rng.integers(max(args.prompt_len // 2, 2),
                                    args.prompt_len + 1))
            prompt = rng.integers(1, cfg.vocab, plen).tolist()
            eng.submit(model, prompt, max_new_tokens=args.gen)
        summary = eng.run()
    print(f"engine: {args.requests} requests across {len(tenants)} models, "
          f"{args.kv_slots} KV slots each, weight arena {weight_slots} slots")
    print(format_summary(summary))
    if eng.telemetry is not None:
        print("health:", json.dumps(_json_safe(eng.health()),
                                    sort_keys=True))
    flush()
    if endpoint is not None:
        endpoint.close()


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", choices=ARCHS, default="gemma-7b")
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=32)
    p.add_argument("--gen", type=int, default=16)
    p.add_argument("--streaming", action="store_true",
                   help="serve through the ARAS streaming executor")
    p.add_argument("--arena-slots", type=int, default=3)
    p.add_argument("--engine", action="store_true",
                   help="continuous-batching engine, 2 tenants")
    p.add_argument("--requests", type=int, default=10,
                   help="engine: number of requests to submit")
    p.add_argument("--kv-slots", type=int, default=4,
                   help="engine: KV slots per tenant")
    p.add_argument("--weight-slots", type=int, default=0,
                   help="engine: weight arena slots (0 = n_layers+1)")
    p.add_argument("--turn-steps", type=int, default=8,
                   help="engine: tenant time-slice length in steps")
    p.add_argument("--queue-policy", choices=("fcfs", "sjf"), default="fcfs")
    p.add_argument("--kv-layout", choices=("slot", "paged"), default="slot",
                   help="engine: whole-sequence KV slots, or paged KV with "
                        "prefix sharing (removes the per-request max_seq "
                        "ceiling)")
    p.add_argument("--page-size", type=int, default=8,
                   help="engine: tokens per KV page (kv_layout=paged)")
    p.add_argument("--install-ticks-per-step", type=int, default=0,
                   help="engine: weight-install tick budget per step "
                        "(0 = instant installs at the turn boundary)")
    p.add_argument("--install-bytes-per-tick", type=int, default=1 << 16,
                   help="engine: wire bytes one install tick moves")
    p.add_argument("--overlap-installs", action="store_true",
                   help="engine: pipeline the next tenant's weight installs "
                        "under the current tenant's final decode steps "
                        "(needs --install-ticks-per-step > 0)")
    p.add_argument("--prefill-chunk", type=int, default=0,
                   help="engine: split prompt prefills into chunks of this "
                        "many tokens, spread across steps (0 = monolithic "
                        "per-prompt-length prefill)")
    p.add_argument("--prefill-token-budget", type=int, default=0,
                   help="engine: cap on prompt tokens one step may spend on "
                        "chunked prefill (0 = unbudgeted; needs "
                        "--prefill-chunk > 0 to matter)")
    p.add_argument("--bucket-growth", type=float, default=2.0,
                   help="engine: geometric growth of the prompt-length "
                        "bucket ladder tail chunks are padded to; bounds "
                        "distinct prefill jit traces at the ladder size "
                        "(<= 1 disables bucketing)")
    p.add_argument("--staging-growth", type=float, default=2.0,
                   help="engine: geometric growth of the staging-length "
                        "ladder — each chunked prefill stages into the "
                        "smallest rung covering its prompt instead of one "
                        "max-capacity buffer (<= 1 restores the single "
                        "max-capacity staging length)")
    p.add_argument("--prefix-cache", action="store_true",
                   help="engine: radix-tree prefix cache over KV pages "
                        "(kv_layout=paged): finished requests donate their "
                        "pages, warm requests skip prefill chunks covered "
                        "by cached pages, LRU eviction frees pages on "
                        "demand")
    p.add_argument("--prefix-cache-pages", type=int, default=0,
                   help="engine: cap on retained prefix-cache pages per "
                        "tenant (0 = bounded only by on-demand eviction)")
    p.add_argument("--kernel-backend", choices=("xla", "pallas"),
                   default="xla",
                   help="engine: paged decode attention backend — 'pallas' "
                        "routes GQA decode through the paged-attention "
                        "kernel (skips fully-masked tail pages; interpret "
                        "mode off-TPU), 'xla' keeps the full-width gather "
                        "(needs --kv-layout paged for 'pallas')")
    p.add_argument("--no-fuse-sampling", action="store_true",
                   help="engine: split sampling back out of the jitted "
                        "paged decode step (fused on-device sampling is "
                        "the default — logits never leave the device)")
    p.add_argument("--trace-out", type=str, default="",
                   help="engine: write a Chrome-trace-format JSON of the "
                        "run (per-step component spans + request lifecycle "
                        "spans) to this path; load in chrome://tracing or "
                        "ui.perfetto.dev")
    p.add_argument("--metrics-json", type=str, default="",
                   help="engine: dump the final summary and the typed "
                        "metrics registry (counters/gauges/histograms) as "
                        "JSON to this path")
    p.add_argument("--wear-aware", type=float, nargs="?", const=1.0,
                   default=0.0, metavar="WEIGHT",
                   help="engine: blend install victim picking with per-slot "
                        "write pressure and hand out the coldest free KV "
                        "page first (Hamun-style wear leveling); optional "
                        "value is the blend weight (bare flag = 1.0, "
                        "0 = off, today's placement bit-for-bit)")
    p.add_argument("--fault-rate", type=float, default=0.0,
                   help="engine: seeded stuck-at fault probability per "
                        "physical write (weight slots + KV pages); faulted "
                        "units are retired and remapped with token "
                        "equivalence preserved (0 = no injection)")
    p.add_argument("--fault-seed", type=int, default=0,
                   help="engine: seed for the deterministic fault stream "
                        "(same seed + same schedule = same faults)")
    p.add_argument("--wear-json", type=str, default="",
                   help="engine: dump the per-plane wear map (write / "
                        "cell-flip / pulse counts per weight slot and KV "
                        "page, Gini, hottest-N, histogram) as JSON to this "
                        "path; artifacts also flush on Ctrl-C/SIGTERM")
    p.add_argument("--slo-ttft-p95", type=float, default=0.0,
                   metavar="SECONDS",
                   help="engine: TTFT p95 SLO target in seconds — "
                        "evaluated as short+long burn-rate windows, "
                        "breach/recover transitions emit trace instants "
                        "and flight-recorder dumps (0 = untracked)")
    p.add_argument("--slo-itl-p95", type=float, default=0.0,
                   metavar="SECONDS",
                   help="engine: worst inter-token-gap p95 SLO target in "
                        "seconds (0 = untracked)")
    p.add_argument("--slo-queue-wait-p95", type=float, default=0.0,
                   metavar="SECONDS",
                   help="engine: queue-wait p95 SLO target in seconds "
                        "(0 = untracked)")
    p.add_argument("--telemetry-window", type=int, default=128,
                   help="engine: sliding-window size for live windowed "
                        "percentiles (exact over the last N samples; "
                        "lifetime P² estimators ride along at O(1))")
    p.add_argument("--prom-out", type=str, default="",
                   help="engine: write Prometheus text exposition "
                        "(registry + live windows + SLO status) to this "
                        "path at exit — the textfile-collector mode")
    p.add_argument("--prom-port", type=int, default=0,
                   help="engine: serve /metrics on this localhost port "
                        "via a stdlib http.server daemon thread "
                        "(0 = no endpoint)")
    p.add_argument("--events-out", type=str, default="",
                   help="engine: append-mode JSONL event stream (per-step "
                        "window snapshots, request finishes, SLO "
                        "transitions) to this path")
    p.add_argument("--flight-recorder-steps", type=int, default=0,
                   help="engine: keep a flight-recorder ring of the last "
                        "N steps (StepRecords + trace events + health), "
                        "dumped to JSON on unit retirement, SLO breach, "
                        "suspected stall, SIGUSR1, or crash (0 = off)")
    p.add_argument("--flight-dir", type=str, default=".",
                   help="engine: directory flight-recorder dumps are "
                        "written into")
    p.add_argument("--stall-timeout-s", type=float, default=0.0,
                   help="engine: arm the step watchdog with this deadline "
                        "— a step that overruns it emits stall_suspected "
                        "+ a flight dump; observation only (0 = off)")
    p.add_argument("--virtual-clock", action="store_true",
                   help="engine: run on a VirtualClock with deterministic "
                        "simulated arrivals — every artifact is "
                        "byte-reproducible (the CI telemetry mode)")
    args = p.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    if "decode_32k" not in supported_shapes(args.arch):
        raise SystemExit(f"{args.arch} is encoder-only: no decode path")

    if args.engine:
        _run_engine(args)
        return

    params = init_params(jax.random.PRNGKey(0), cfg)
    data = DataConfig(seq_len=args.prompt_len, global_batch=args.batch)
    batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, data, 0).items()}

    if args.streaming:
        from repro.streaming.executor import StreamingExecutor
        ex = StreamingExecutor(params, cfg, arena_slots=args.arena_slots,
                               plan_tokens=args.batch * args.prompt_len)
        t0 = time.perf_counter()
        logits, m = ex.forward(batch)
        print(f"streaming forward: {m['wall_s']*1e3:.1f} ms, "
              f"wire {m['wire_bytes']/1e6:.2f} MB vs raw "
              f"{m['raw_bytes']/1e6:.2f} MB "
              f"(skip {m['mean_skip']:.1%}, center={int(m['reuse_center'])}); "
              f"plan overlap speedup {m['plan_overlap_speedup']:.2f}×")
        return

    prefix = cfg.prefix_len if cfg.input_mode == "prefix_vlm" else 0
    cache_len = args.prompt_len + prefix + args.gen
    prefill_fn = cached_prefill_step(cfg, cache_len)
    serve_fn = cached_serve_step(cfg)

    t0 = time.perf_counter()
    logits, caches = prefill_fn(params, batch)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0

    tokens = [jnp.argmax(logits, -1).astype(jnp.int32)]
    t0 = time.perf_counter()
    pos = args.prompt_len + prefix
    for i in range(args.gen - 1):
        logits, caches = serve_fn(params, tokens[-1], caches,
                                  jnp.int32(pos + i))
        tokens.append(jnp.argmax(logits, -1).astype(jnp.int32))
    tokens[-1].block_until_ready()
    t_decode = time.perf_counter() - t0

    out = np.stack([np.asarray(t) for t in tokens], 1)
    print(f"prefill {args.batch}×{args.prompt_len}: {t_prefill*1e3:.1f} ms; "
          f"decode {args.gen-1} steps: {t_decode*1e3:.1f} ms "
          f"({(args.gen-1)*args.batch/max(t_decode,1e-9):.1f} tok/s)")
    print("sampled token ids:", out[0, :12], "...")


if __name__ == "__main__":
    main()
