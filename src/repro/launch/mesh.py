"""Production mesh: (data=16, model=16) per pod; (pod=2, data=16, model=16)
across pods.  A function (not a module-level constant) so importing this
module never touches jax device state."""
from __future__ import annotations

import math

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) == n:
        return jax.make_mesh(shape, axes)
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices but only {len(devices)} present; "
            "run under XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{n} (see launch/dryrun.py)")
    # More devices than the mesh needs (e.g. 512 forced, single-pod 256):
    # build the mesh over the leading subset.
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_debug_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Tiny mesh over however many devices exist (tests)."""
    devices = jax.devices()[: data * model]
    return Mesh(np.asarray(devices).reshape(data, model), ("data", "model"))
