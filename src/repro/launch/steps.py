"""Jittable step functions and their sharding trees.

Everything the dry-run lowers comes from here, so the launcher (train.py /
serve.py) and the dry-run exercise the *same* code.
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.data.pipeline import DataConfig, make_batch_specs
from repro.nn.config import ModelConfig
from repro.nn.model import chunk_prefill, decode_step, init_cache, init_params, lm_loss, prefill, param_specs
from repro.nn.transformer import layer_kind
from repro.optim.adamw import AdamWState, adamw_init, adamw_update
from repro.optim.schedules import cosine, wsd
from repro.parallel.sharding import make_spec


# ----------------------------------------------------------------- steps
def make_train_step(cfg: ModelConfig, peak_lr: float = 3e-4,
                    warmup: int = 2000, total: int = 100_000):
    if cfg.name.startswith("minicpm"):
        sched = functools.partial(wsd, peak_lr=peak_lr, warmup=warmup,
                                  stable=int(total * 0.8),
                                  decay=int(total * 0.1))
    else:
        sched = functools.partial(cosine, peak_lr=peak_lr, warmup=warmup,
                                  total=total)

    def train_step(params, opt_state: AdamWState, batch, step):
        def loss_fn(p):
            return lm_loss(p, batch, cfg)

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        lr = sched(step)
        new_params, new_opt, om = adamw_update(params, grads, opt_state, lr)
        out_metrics = {"loss": loss, "lr": lr, **metrics, **om}
        return new_params, new_opt, out_metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, cache_len: int):
    def prefill_step(params, batch):
        return prefill(params, batch, cfg, cache_len)
    return prefill_step


def make_serve_step(cfg: ModelConfig):
    def serve_step(params, token, caches, pos):
        return decode_step(params, token, caches, pos, cfg)
    return serve_step


# Jit-compiled step cache shared by every engine/launcher instance touching
# the same config: ModelConfig is frozen/hashable, so two ServingEngine
# instances (e.g. the reuse-on/reuse-off benchmark arms) compile once.
@functools.lru_cache(maxsize=None)
def cached_prefill_step(cfg: ModelConfig, cache_len: int):
    return jax.jit(make_prefill_step(cfg, cache_len=cache_len))


@functools.lru_cache(maxsize=None)
def cached_serve_step(cfg: ModelConfig):
    """Batched decode step; `pos` may be a scalar or a per-row (B,) vector —
    the vector form is what slot-based continuous batching decodes with."""
    return jax.jit(make_serve_step(cfg), donate_argnums=(2,))


def make_chunk_prefill_step(cfg: ModelConfig, chunk_len: int, cache_len: int):
    def chunk_prefill_step(params, tokens, caches, start, n_valid):
        return chunk_prefill(params, tokens, caches, start, n_valid, cfg)
    return chunk_prefill_step


@functools.lru_cache(maxsize=None)
def cached_chunk_prefill_step(cfg: ModelConfig, chunk_len: int,
                              cache_len: int):
    """One chunked-prefill step: `tokens` (1, chunk_len) land at absolute
    positions [start, start+chunk_len) of a `cache_len` staging cache, with
    only the first `n_valid` real (the engine pads the tail chunk up to a
    bucket-ladder rung).  Keyed on the padded chunk length, so the number of
    LRU misses IS the number of distinct jit traces — with bucketing on it
    is bounded by the ladder size instead of growing with every new prompt
    length (see prefill_cache_info)."""
    return jax.jit(make_chunk_prefill_step(cfg, chunk_len, cache_len),
                   donate_argnums=(2,))


def prefill_cache_info() -> Dict[str, int]:
    """Hit/miss/trace counters over the prefill step caches (process-wide,
    shared by every engine instance of the same config — the compile-count
    tests and the bucketing benchmark read deltas of these)."""
    mono = cached_prefill_step.cache_info()
    chunk = cached_chunk_prefill_step.cache_info()
    return {
        "prefill_hits": mono.hits, "prefill_misses": mono.misses,
        "prefill_traces": mono.currsize,
        "chunk_hits": chunk.hits, "chunk_misses": chunk.misses,
        "chunk_traces": chunk.currsize,
        "hits": mono.hits + chunk.hits,
        "misses": mono.misses + chunk.misses,
        "traces": mono.currsize + chunk.currsize,
    }


# ----------------------------------------- chunked-prefill staging install
def _finalize_attn_entry(cfg: ModelConfig, entry, *, axis: int, window: int,
                         target_len: int, true_len, ring_windows: bool):
    """Convert one attention cache entry from the raw full-length staging
    layout to the serving-arena layout: quantize int8 tenants (staging
    attends in bf16, exactly like monolithic prefill, and quantizes once
    here), ring-gather sliding-window layers down to their window-sized
    ring (slot arenas only — page pools store full positions), and slice
    everything else to the arena length."""
    def shape_to(leaf):
        if ring_windows and 0 < window < target_len:
            # ring slot i holds the largest valid position ≡ i (mod window);
            # slots with no valid position yet gather clipped garbage that
            # the decode mask (abs_pos >= 0) never admits
            last = true_len - 1
            idx = last - ((last - jnp.arange(window)) % window)
            return jnp.take(leaf, idx, axis=axis, mode="clip")
        if leaf.shape[axis] > target_len:
            return jax.lax.slice_in_dim(leaf, 0, target_len, axis=axis)
        return leaf

    if cfg.attn_type == "mla":
        return {k: shape_to(v) for k, v in entry.items()}
    if cfg.kv_cache_dtype == "int8":
        from repro.nn.attention import _kv_quant
        kq, ks = _kv_quant(entry["k"])
        vq, vs = _kv_quant(entry["v"])
        return {"k": shape_to(kq), "v": shape_to(vq),
                "k_scale": shape_to(ks), "v_scale": shape_to(vs)}
    return {"k": shape_to(entry["k"]), "v": shape_to(entry["v"])}


def _make_stage_finalize(cfg: ModelConfig, target_len: int,
                         ring_windows: bool):
    from repro.nn.transformer import stack_plan
    plan = stack_plan(cfg)

    def finalize(staging, true_len):
        out = []
        for seg, (start, _, scanned) in zip(staging, plan):
            if isinstance(seg, dict) and "attn" in seg:
                fixed = dict(seg)
                fixed["attn"] = _finalize_attn_entry(
                    cfg, seg["attn"], axis=2 if scanned else 1,
                    window=cfg.window_for_layer(start),
                    target_len=target_len, true_len=true_len,
                    ring_windows=ring_windows)
                out.append(fixed)
            else:
                out.append(seg)    # pure recurrent state: length-free
        return out

    return finalize


@functools.lru_cache(maxsize=None)
def cached_stage_install(cfg: ModelConfig, staging_len: int, arena_len: int):
    """Staging → slot-arena row: ring windowed layers, slice the rest to
    `arena_len`, quantize int8 tenants.  Not donated: ring/slice outputs
    change leaf shapes, so donated staging buffers would never be reused
    (XLA warns instead)."""
    return jax.jit(_make_stage_finalize(cfg, arena_len, ring_windows=True))


@functools.lru_cache(maxsize=None)
def cached_stage_quantize(cfg: ModelConfig, staging_len: int):
    """Staging → paged-pool install source: page pools keep full positions
    (no ring), so this only quantizes int8 tenants.  NOT donated — the page
    writer slices several blocks out of the same finalized cache."""
    return jax.jit(_make_stage_finalize(cfg, staging_len,
                                        ring_windows=False))


def make_paged_serve_step(cfg: ModelConfig, kernel_backend: str = "xla",
                          interpret: bool = False):
    def paged_serve_step(params, token, caches, pos, page_table):
        return decode_step(params, token, caches, pos, cfg,
                           page_table=page_table,
                           kernel_backend=kernel_backend,
                           kernel_interpret=interpret)
    return paged_serve_step


@functools.lru_cache(maxsize=None)
def cached_paged_serve_step(cfg: ModelConfig, kernel_backend: str = "xla",
                            interpret: bool = False):
    """Decode step over a paged KV arena: caches are page pools, `pos` is
    the per-row (B,) write positions, `page_table` (B, T) maps each row's
    logical blocks to physical pages (serving.paging builds both).
    `kernel_backend`/`interpret` are trace-time constants selecting the
    Pallas paged-attention kernel and its interpret mode (engine knob)."""
    return jax.jit(make_paged_serve_step(cfg, kernel_backend, interpret),
                   donate_argnums=(2,))


@functools.lru_cache(maxsize=None)
def cached_fused_paged_serve_step(cfg: ModelConfig,
                                  kernel_backend: str = "xla",
                                  interpret: bool = False):
    """Paged decode step with sampling fused in: logits never leave the
    device — the jitted fn samples every row (greedy/temperature/top-k,
    `serving.sampling.sample_tokens`) and returns only the (B,) int32
    token ids.  temps (B,) f32, top_ks (B,) int32, keys (B, 2) raw uint32
    per-request PRNG roots, steps (B,) int32 fold_in indices."""
    from repro.serving.sampling import sample_tokens

    def fused_paged_serve_step(params, token, caches, pos, page_table,
                               temps, top_ks, keys, steps):
        logits, new_caches = decode_step(params, token, caches, pos, cfg,
                                         page_table=page_table,
                                         kernel_backend=kernel_backend,
                                         kernel_interpret=interpret)
        toks = sample_tokens(logits, cfg.vocab, temperatures=temps,
                             top_ks=top_ks, keys=keys, steps=steps)
        return toks, new_caches

    return jax.jit(fused_paged_serve_step, donate_argnums=(2,))


@functools.lru_cache(maxsize=None)
def cached_sample_tokens(vocab: int):
    """Batched sampler for the split (non-fused) path: one jitted device
    call per decode batch instead of one host sync per row."""
    from repro.serving.sampling import sample_tokens

    def sample(logits, temps, top_ks, keys, steps):
        return sample_tokens(logits, vocab, temperatures=temps,
                             top_ks=top_ks, keys=keys, steps=steps)

    return jax.jit(sample)


# ------------------------------------------------------------- shardings
def _is_spec_leaf(x) -> bool:
    return isinstance(x, tuple) and all(
        e is None or isinstance(e, (str, tuple)) for e in x)


def param_shardings(cfg: ModelConfig, mesh: Mesh):
    specs = param_specs(cfg)
    return jax.tree.map(
        lambda s: NamedSharding(mesh, make_spec(*s)), specs,
        is_leaf=_is_spec_leaf)


def opt_shardings(param_sh, mesh: Mesh):
    return AdamWState(step=NamedSharding(mesh, P()),
                      mu=param_sh, nu=param_sh)


def batch_shardings(cfg: ModelConfig, mesh: Mesh):
    ba = make_spec("batch")[0]
    out: Dict[str, NamedSharding] = {}
    d = DataConfig(seq_len=8, global_batch=8)  # structure only
    for k in make_batch_specs(cfg, d):
        if k in ("tokens", "targets", "loss_mask"):
            out[k] = NamedSharding(mesh, P(ba, None))
        else:  # embeds / patch_embeds: shard seq over model too
            out[k] = NamedSharding(mesh, P(ba, "model", None))
    return out


def _cache_entry_spec(cfg: ModelConfig, window: int, mesh: Mesh):
    ba = make_spec("batch")[0]
    if cfg.attn_type == "mla":
        return {"c_kv": NamedSharding(mesh, P(ba, "model", None)),
                "k_rope": NamedSharding(mesh, P(ba, "model", None))}
    out = {"k": NamedSharding(mesh, P(ba, "model", None, None)),
           "v": NamedSharding(mesh, P(ba, "model", None, None))}
    if cfg.kv_cache_dtype == "int8":
        out["k_scale"] = NamedSharding(mesh, P(ba, "model", None))
        out["v_scale"] = NamedSharding(mesh, P(ba, "model", None))
    return out


def batch_axis_for(mesh: Mesh, global_batch: int):
    """The batch mesh axes, or None (replicate) when the batch is too small
    to shard (e.g. long_500k's single sequence)."""
    ba = make_spec("batch")[0]
    if ba is None:
        return None
    axes = (ba,) if isinstance(ba, str) else tuple(ba)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return ba if global_batch % n == 0 else None


def cache_shardings(cfg: ModelConfig, mesh: Mesh, global_batch: int = 0):
    """Mirrors nn.model.init_cache structure (segment plan)."""
    from repro.nn.transformer import stack_plan
    ba = make_spec("batch")[0]
    if global_batch:
        ba = batch_axis_for(mesh, global_batch)
    rep = lambda *s: NamedSharding(mesh, P(*s))

    def entry(window):
        if cfg.attn_type == "mla":
            return {"c_kv": rep(ba, "model", None),
                    "k_rope": rep(ba, "model", None)}
        out = {"k": rep(ba, "model", None, None),
               "v": rep(ba, "model", None, None)}
        if cfg.kv_cache_dtype == "int8":
            out["k_scale"] = rep(ba, "model", None)
            out["v_scale"] = rep(ba, "model", None)
        return out

    def layer_spec(i: int):
        kind = layer_kind(cfg, i)
        if kind == "mlstm":
            return {"S": rep(ba, None, None, None), "n": rep(ba, None, None)}
        if kind == "slstm":
            return {"c": rep(ba, None, None), "n": rep(ba, None, None),
                    "h": rep(ba, None, None)}
        if kind == "hybrid":
            return {
                "attn": entry(cfg.window_for_layer(i)),
                "mamba": {"conv": rep(ba, None, "model"),
                          "h": rep(ba, "model", None)},
            }
        return {"attn": entry(cfg.window_for_layer(i))}

    out = []
    for start, length, scanned in stack_plan(cfg):
        one = layer_spec(start)
        if scanned:
            one = jax.tree.map(
                lambda sh: NamedSharding(mesh, P(None, *sh.spec)), one,
                is_leaf=lambda x: hasattr(x, "spec"))
        out.append(one)
    return out


# ------------------------------------------------------- abstract inputs
def _bf16_floats(tree):
    def cast(l):
        if jnp.issubdtype(l.dtype, jnp.floating):
            return jax.ShapeDtypeStruct(l.shape, jnp.bfloat16)
        return jax.ShapeDtypeStruct(l.shape, l.dtype)
    return jax.tree.map(cast, tree)


def abstract_params(cfg: ModelConfig):
    shapes = jax.eval_shape(
        lambda k: init_params(k, cfg), jax.ShapeDtypeStruct((2,), jnp.uint32))
    return _bf16_floats(shapes)


def abstract_opt_state(aparams):
    return jax.eval_shape(adamw_init, aparams)


def abstract_caches(cfg: ModelConfig, batch: int, cache_len: int):
    return jax.eval_shape(
        functools.partial(init_cache, cfg, batch, cache_len))


def input_specs(cfg: ModelConfig, shape_kind: str, seq_len: int,
                global_batch: int) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of a cell."""
    if shape_kind == "train":
        data = DataConfig(seq_len=seq_len, global_batch=global_batch)
        return {
            "params": abstract_params(cfg),
            "opt_state": None,  # filled by caller
            "batch": make_batch_specs(cfg, data),
            "step": jax.ShapeDtypeStruct((), jnp.int32),
        }
    if shape_kind == "prefill":
        data = DataConfig(seq_len=seq_len, global_batch=global_batch)
        return {
            "params": abstract_params(cfg),
            "batch": make_batch_specs(cfg, data),
        }
    # decode: one token, cache of seq_len
    return {
        "params": abstract_params(cfg),
        "token": jax.ShapeDtypeStruct((global_batch,), jnp.int32),
        "caches": abstract_caches(cfg, global_batch, seq_len),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }
