import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × shape × mesh)
cell on placeholder devices; record memory analysis, cost analysis and
collective traffic for the roofline report.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k --multi-pod
    PYTHONPATH=src python -m repro.launch.dryrun --all            # every cell, subprocess each

The two lines above MUST stay the first statements in this file: jax locks
the device count at first initialization.

Roofline methodology note (see EXPERIMENTS.md §Roofline): XLA's
HloCostAnalysis counts a `while` body once, so a scanned-over-layers program
under-reports FLOPs/bytes/collectives by ~n_layers.  Each cell therefore
compiles (a) the REAL scanned program — compile-success proof + honest
memory_analysis — and (b) two small "cost probes" at reduced depth with
every chunk loop unrolled, from which per-layer slopes are fitted and
extrapolated to full depth (exact for depth-linear programs, which these
stacks are).
"""
import argparse
import dataclasses
import json
import subprocess
import sys
import time
import traceback

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, get_config, supported_shapes
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import SHAPES, ShapeSpec
from repro.launch.steps import (
    abstract_caches,
    abstract_opt_state,
    abstract_params,
    batch_shardings,
    cache_shardings,
    make_prefill_step,
    make_serve_step,
    make_train_step,
    opt_shardings,
    param_shardings,
)
from repro.parallel.sharding import make_spec, use_mesh
from repro.roofline.analysis import HW, collective_bytes_from_hlo, roofline_terms

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def _model_flops(cfg, spec: ShapeSpec) -> float:
    n_active = cfg.active_param_count()
    if spec.kind == "train":
        return 6.0 * n_active * spec.seq_len * spec.global_batch
    if spec.kind == "prefill":
        return 2.0 * n_active * spec.seq_len * spec.global_batch
    return 2.0 * n_active * spec.global_batch  # decode: one token per seq


def _build_lowered(cfg, spec: ShapeSpec, mesh):
    """Lower the cell's step function under explicit shardings."""
    from repro.data.pipeline import DataConfig, make_batch_specs
    import jax.numpy as jnp

    psh = param_shardings(cfg, mesh)
    aparams = abstract_params(cfg)
    rep = NamedSharding(mesh, P())
    ba = make_spec("batch")[0]

    if spec.kind == "train":
        aopt = abstract_opt_state(aparams)
        osh = opt_shardings(psh, mesh)
        bsh = batch_shardings(cfg, mesh)
        abatch = make_batch_specs(cfg, DataConfig(spec.seq_len, spec.global_batch))
        fn = make_train_step(cfg)
        jitted = jax.jit(fn, in_shardings=(psh, osh, bsh, rep),
                         out_shardings=(psh, osh, None),
                         donate_argnums=(0, 1))
        return jitted.lower(aparams, aopt, abatch,
                            jax.ShapeDtypeStruct((), jnp.int32))
    if spec.kind == "prefill":
        bsh = batch_shardings(cfg, mesh)
        abatch = make_batch_specs(cfg, DataConfig(spec.seq_len, spec.global_batch))
        fn = make_prefill_step(cfg, cache_len=spec.seq_len + cfg.prefix_len)
        jitted = jax.jit(fn, in_shardings=(psh, bsh))
        return jitted.lower(aparams, abatch)
    from repro.launch.steps import batch_axis_for
    acaches = abstract_caches(cfg, spec.global_batch, spec.seq_len)
    csh = cache_shardings(cfg, mesh, spec.global_batch)
    ba_eff = batch_axis_for(mesh, spec.global_batch)
    atoken = jax.ShapeDtypeStruct((spec.global_batch,), jnp.int32)
    apos = jax.ShapeDtypeStruct((), jnp.int32)
    fn = make_serve_step(cfg)
    jitted = jax.jit(fn, in_shardings=(psh, NamedSharding(mesh, P(ba_eff)),
                                       csh, rep),
                     donate_argnums=(2,))
    return jitted.lower(aparams, atoken, acaches, apos)


def _cost_of(cfg, spec, mesh, chips):
    lowered = _build_lowered(cfg, spec, mesh)
    compiled = lowered.compile()
    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
    except Exception:
        cost = {}
    coll = collective_bytes_from_hlo(compiled.as_text(), default_group=chips)
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)),
            coll["total"], coll)


def _probe_cfg(cfg, depth: int):
    g = tuple(i for i in cfg.global_layers if i < depth) or (
        (0,) if cfg.global_layers else ())
    return dataclasses.replace(
        cfg, n_layers=depth, scan_layers=False, unroll_chunks=True,
        global_layers=g, attn_q_chunk=2048, attn_kv_chunk=2048)


def probe_extrapolated_cost(cfg, spec, mesh, chips):
    """Two reduced-depth probes -> per-layer slope -> full-depth estimate."""
    if cfg.family == "ssm" and cfg.slstm_every:
        depths = (cfg.slstm_every, 2 * cfg.slstm_every)
    elif cfg.n_experts and cfg.first_dense_layers:
        f = cfg.first_dense_layers
        depths = (f + 1, f + 2)
    else:
        depths = (1, 2)
    depths = tuple(min(d, cfg.n_layers) for d in depths)
    if depths[0] == depths[1]:
        c = _probe_cfg(cfg, depths[0])
        f1, b1, l1, coll = _cost_of(c, spec, mesh, chips)
        return {"flops": f1, "bytes": b1, "coll": l1,
                "probe_depths": depths, "collectives": coll}

    c1 = _probe_cfg(cfg, depths[0])
    c2 = _probe_cfg(cfg, depths[1])
    f1, b1, l1, _ = _cost_of(c1, spec, mesh, chips)
    f2, b2, l2, coll2 = _cost_of(c2, spec, mesh, chips)
    dd = depths[1] - depths[0]

    def fit(v1, v2):
        slope = (v2 - v1) / dd
        fixed = v1 - slope * depths[0]
        return fixed + slope * cfg.n_layers

    extra = {}
    if cfg.global_layers and len(cfg.global_layers) > 1:
        # hymba: slope above reflects SWA layers; measure the global-layer
        # premium once and add it for the remaining global layers.
        cg = dataclasses.replace(c1, global_layers=tuple(range(min(2, depths[0]))))
        fg, bg, lg, _ = _cost_of(cg, spec, mesh, chips)
        n_extra = len(cfg.global_layers) - 1
        extra = {"flops": (fg - f1) * n_extra, "bytes": (bg - b1) * n_extra,
                 "coll": (lg - l1) * n_extra}
    return {
        "flops": fit(f1, f2) + extra.get("flops", 0.0),
        "bytes": fit(b1, b2) + extra.get("bytes", 0.0),
        "coll": fit(l1, l2) + extra.get("coll", 0.0),
        "probe_depths": depths,
        "collectives": coll2,
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             smoke: bool = False, skip_probes: bool = False,
             kv_int8: bool = False) -> dict:
    cfg = get_config(arch, smoke=smoke)
    if kv_int8:
        cfg = dataclasses.replace(cfg, kv_cache_dtype="int8")
    spec = SHAPES[shape_name]
    if smoke:
        spec = dataclasses.replace(spec, seq_len=min(spec.seq_len, 128),
                                   global_batch=min(spec.global_batch, 16))
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    chips = mesh.size
    t0 = time.time()

    with use_mesh(mesh):
        lowered = _build_lowered(cfg, spec, mesh)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        mem_d = {}
        if mem is not None:
            for f in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "alias_size_in_bytes"):
                mem_d[f] = getattr(mem, f, None)
        full_coll = collective_bytes_from_hlo(compiled.as_text(),
                                              default_group=chips)
        del compiled, lowered

        # Cost probes (single-pod roofline only; multi-pod run proves sharding)
        probe = None
        if not multi_pod and not skip_probes:
            probe = probe_extrapolated_cost(cfg, spec, mesh, chips)

    report = None
    if probe is not None:
        report = roofline_terms(
            arch=arch, shape=shape_name, mesh_name=mesh_name, chips=chips,
            cost_analysis={"flops": probe["flops"],
                           "bytes accessed": probe["bytes"]},
            hlo_text="",
            model_flops_global=_model_flops(cfg, spec))
        # collective term from the probe-extrapolated wire bytes
        report.collective_bytes_per_device = probe["coll"]
        report.collective_s = probe["coll"] / HW["link_bw"]
        terms = {"compute": report.compute_s, "memory": report.memory_s,
                 "collective": report.collective_s}
        report.dominant = max(terms, key=terms.get)
        report.collectives = probe["collectives"]

    args_b = mem_d.get("argument_size_in_bytes") or 0
    temp_b = mem_d.get("temp_size_in_bytes") or 0
    out_b = mem_d.get("output_size_in_bytes") or 0
    alias_b = mem_d.get("alias_size_in_bytes") or 0
    per_device_bytes = args_b + temp_b + out_b - alias_b
    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "chips": chips, "smoke": smoke,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory_analysis": mem_d,
        "per_device_bytes": per_device_bytes,
        "per_device_gb": round(per_device_bytes / 1024**3, 3),
        "fits_hbm": bool(per_device_bytes <= HW["hbm_bytes"]),
        "collective_ops_full_hlo": {k: v for k, v in full_coll.items()
                                    if k.startswith("n_")},
        "probe": ({k: v for k, v in probe.items() if k != "collectives"}
                  if probe else None),
        "roofline": report.as_dict() if report else None,
    }
    return result


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", choices=ARCHS)
    p.add_argument("--shape", choices=tuple(SHAPES))
    p.add_argument("--multi-pod", action="store_true")
    p.add_argument("--all", action="store_true")
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--skip-probes", action="store_true")
    p.add_argument("--kv-int8", action="store_true",
                   help="int8 KV cache (EXPERIMENTS.md §Perf iteration 9)")
    p.add_argument("--out", default=None)
    p.add_argument("--timeout", type=int, default=5400)
    args = p.parse_args()

    os.makedirs(RESULTS_DIR, exist_ok=True)

    if args.all:
        failures = []
        for arch in ARCHS:
            for shape in supported_shapes(arch):
                for mp in (False, True):
                    mesh_name = "pod2x16x16" if mp else "pod16x16"
                    out = os.path.join(
                        RESULTS_DIR, f"{arch}__{shape}__{mesh_name}.json")
                    if os.path.exists(out):
                        print(f"skip (exists): {out}", flush=True)
                        continue
                    cmd = [sys.executable, "-m", "repro.launch.dryrun",
                           "--arch", arch, "--shape", shape, "--out", out]
                    if mp:
                        cmd.append("--multi-pod")
                    if args.smoke:
                        cmd.append("--smoke")
                    print(f"=== {arch} × {shape} × {mesh_name}", flush=True)
                    t0 = time.time()
                    try:
                        subprocess.run(cmd, check=True, timeout=args.timeout,
                                       stdout=subprocess.DEVNULL)
                        print(f"    ok in {time.time()-t0:.0f}s", flush=True)
                    except Exception as e:  # noqa: BLE001
                        failures.append((arch, shape, mesh_name, repr(e)))
                        print(f"    FAILED after {time.time()-t0:.0f}s: {e}",
                              flush=True)
        print(f"\ndone; {len(failures)} failures")
        for f in failures:
            print("  FAIL:", *f)
        sys.exit(1 if failures else 0)

    assert args.arch and args.shape, "--arch/--shape required (or --all)"
    try:
        result = run_cell(args.arch, args.shape, args.multi_pod, args.smoke,
                          args.skip_probes, args.kv_int8)
    except Exception:
        traceback.print_exc()
        sys.exit(2)
    blob = json.dumps(result, indent=2, default=str)
    print(blob)
    if args.out:
        with open(args.out, "w") as f:
            f.write(blob)


if __name__ == "__main__":
    main()
