"""Assigned input-shape cells (system prompt block).

  train_4k     seq 4,096   × global_batch 256   — train_step
  prefill_32k  seq 32,768  × global_batch 32    — serve prefill
  decode_32k   cache 32,768 × global_batch 128  — serve_step (1 new token)
  long_500k    cache 524,288 × global_batch 1   — long-context decode
"""
from __future__ import annotations

import dataclasses
from typing import Dict


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # 'train' | 'prefill' | 'decode'
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}
