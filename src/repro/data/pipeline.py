"""Deterministic, step-indexed synthetic data pipeline.

Preemption-safe by construction: batch(step) is a pure function of
(seed, step), so resuming from a checkpoint at step N replays the exact
stream with no iterator state to persist.  Batches are generated directly
into their target sharding (each host materializes only its addressable
shard when `jax.make_array_from_callback` is used by the launcher).

Real deployments swap `_synthesize` for a tokenized corpus reader with the
same (seed, step) → batch contract; everything downstream is unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.nn.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    seed: int = 1234


def _tokens(rng: np.random.Generator, shape, vocab: int) -> np.ndarray:
    """Zipfian token stream — more LM-like than uniform, still synthetic."""
    z = rng.zipf(1.3, size=shape).astype(np.int64)
    return (z % vocab).astype(np.int32)


def make_batch(cfg: ModelConfig, data: DataConfig, step: int) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(np.random.SeedSequence([data.seed, step]))
    B, S = data.global_batch, data.seq_len
    if cfg.input_mode == "embeddings":
        embeds = rng.standard_normal((B, S, cfg.d_model), dtype=np.float32)
        targets = _tokens(rng, (B, S), cfg.vocab)
        mask = (rng.random((B, S)) < 0.5).astype(np.float32)  # masked prediction
        return {"embeds": embeds, "targets": targets, "loss_mask": mask}
    if cfg.input_mode == "prefix_vlm":
        return {
            "tokens": _tokens(rng, (B, S), cfg.vocab),
            "patch_embeds": rng.standard_normal(
                (B, cfg.prefix_len, cfg.d_model), dtype=np.float32),
        }
    return {"tokens": _tokens(rng, (B, S), cfg.vocab)}


def make_batch_specs(cfg: ModelConfig, data: DataConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for the dry-run (no allocation)."""
    B, S = data.global_batch, data.seq_len
    f32 = jnp.float32
    if cfg.input_mode == "embeddings":
        return {
            "embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model), f32),
            "targets": jax.ShapeDtypeStruct((B, S), jnp.int32),
            "loss_mask": jax.ShapeDtypeStruct((B, S), f32),
        }
    if cfg.input_mode == "prefix_vlm":
        return {
            "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
            "patch_embeds": jax.ShapeDtypeStruct((B, cfg.prefix_len, cfg.d_model), f32),
        }
    return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
