"""yi-34b [dense]: 60L d=7168 56H (GQA kv=8) d_ff=20480 vocab 64000,
llama arch.  [arXiv:2403.04652; hf]"""
from repro.nn.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="yi-34b", family="dense",
        n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
        d_ff=20480, vocab=64000, rope_theta=5e6,
        scan_layers=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="yi-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
        d_ff=160, vocab=512, scan_layers=True,
    )
