"""deepseek-v2-lite-16b [moe]: 27L d=2048, MLA kv_lora=512, 64 routed
experts top-6 + 2 shared, expert d_ff=1408, first layer dense (d_ff=10944),
vocab 102400.  [arXiv:2405.04434; hf]"""
from repro.nn.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-16b", family="moe",
        n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=10944, vocab=102400, attn_type="mla",
        kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
        head_dim=192,  # nope + rope
        n_experts=64, n_shared_experts=2, moe_topk=6, d_ff_expert=1408,
        first_dense_layers=1,
        scan_layers=True,  # grouped scan: [dense, scan·26]
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=512, attn_type="mla",
        kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
        head_dim=24,
        n_experts=8, n_shared_experts=2, moe_topk=2, d_ff_expert=48,
        first_dense_layers=1, scan_layers=False,
    )
