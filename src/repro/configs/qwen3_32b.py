"""qwen3-32b [dense]: 64L d=5120 64H (GQA kv=8) d_ff=25600 vocab 151936,
qk_norm, head_dim=128.  [hf:Qwen/Qwen3-8B; hf]"""
from repro.nn.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen3-32b", family="dense",
        n_layers=64, d_model=5120, n_heads=64, n_kv_heads=8, head_dim=128,
        d_ff=25600, vocab=151936, qk_norm=True, rope_theta=1e6,
        scan_layers=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen3-32b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=192, vocab=512, qk_norm=True, scan_layers=True,
    )
