"""qwen3-moe-235b-a22b [moe]: 94L d=4096 64H (GQA kv=4) MoE 128e top-8,
expert d_ff=1536, vocab 151936, qk_norm.  [hf:Qwen/Qwen3-30B-A3B; hf]"""
from repro.nn.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-235b-a22b", family="moe",
        n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, head_dim=128,
        d_ff=1536, vocab=151936, qk_norm=True, rope_theta=1e6,
        n_experts=128, moe_topk=8, d_ff_expert=1536,
        scan_layers=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=96, vocab=512, qk_norm=True,
        n_experts=8, moe_topk=2, d_ff_expert=96,
        scan_layers=True,
    )
