"""paligemma-3b [vlm]: gemma-2B-class decoder 18L d=2048 8H (MQA kv=1)
GeGLU d_ff=16384, head_dim=256, vocab 257216; SigLIP frontend is a stub
(precomputed patch embeddings, 256 patches, prefix attention).
[arXiv:2407.07726; hf]"""
from repro.nn.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="paligemma-3b", family="vlm",
        n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, head_dim=256,
        d_ff=16384, vocab=257216, act="gelu",
        input_mode="prefix_vlm", prefix_len=256, tie_embeddings=True,
        scan_layers=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="paligemma-smoke", family="vlm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
        d_ff=128, vocab=512, act="gelu",
        input_mode="prefix_vlm", prefix_len=4, tie_embeddings=True,
        scan_layers=True,
    )
