"""hubert-xlarge [audio]: 48L encoder-only d=1280 16H d_ff=5120 vocab 504
(masked-prediction codebook).  Modality frontend is a stub: input_specs
provides precomputed frame embeddings.  [arXiv:2106.07447; unverified]"""
from repro.nn.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge", family="encoder",
        n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16,
        d_ff=5120, vocab=504, causal=False, gated=False, act="gelu",
        input_mode="embeddings", scan_layers=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="hubert-smoke", family="encoder",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=96, causal=False, gated=False, act="gelu",
        input_mode="embeddings", scan_layers=True,
    )
