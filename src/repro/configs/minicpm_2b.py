"""minicpm-2b [dense]: 40L d=2304 36H (MHA) d_ff=5760 vocab 122753,
llama-like, tied embeddings, WSD schedule (repro.optim.schedules.wsd).
[arXiv:2404.06395; hf]"""
from repro.nn.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="minicpm-2b", family="dense",
        n_layers=40, d_model=2304, n_heads=36, n_kv_heads=36, head_dim=64,
        d_ff=5760, vocab=122753, tie_embeddings=True,
        scan_layers=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="minicpm-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab=512, tie_embeddings=True, scan_layers=True,
    )
