"""xlstm-350m [ssm]: 24 blocks d=1024 4 heads, mLSTM + sLSTM (1-in-8),
vocab 50304, no FFN blocks (d_ff=0; mLSTM carries the 2x up-projection).
[arXiv:2405.04517; unverified]"""
from repro.nn.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="xlstm-350m", family="ssm",
        n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4, head_dim=256,
        d_ff=0, vocab=50304, attn_type="none",
        ssm_heads=4, ssm_expand=2, slstm_every=8,
        scan_layers=True,  # grouped scan: (scan·7 mLSTM + sLSTM) × 3
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="xlstm-smoke", family="ssm",
        n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
        d_ff=0, vocab=512, attn_type="none",
        ssm_heads=2, ssm_expand=2, slstm_every=2,
        scan_layers=False,
    )
