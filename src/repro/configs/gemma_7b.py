"""gemma-7b [dense]: 28L d=3072 16H (MHA kv=16) GeGLU d_ff=24576,
head_dim=256, vocab 256000, tied embeddings.  [arXiv:2403.08295; hf]"""
from repro.nn.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="gemma-7b", family="dense",
        n_layers=28, d_model=3072, n_heads=16, n_kv_heads=16, head_dim=256,
        d_ff=24576, vocab=256000, act="gelu", tie_embeddings=True,
        scan_layers=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="gemma-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=32,
        d_ff=192, vocab=512, act="gelu", tie_embeddings=True,
        scan_layers=True,
    )
