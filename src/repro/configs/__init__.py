"""Architecture registry: the 10 assigned architectures (+ paper nets).

Each module exposes ``full()`` (the exact published config) and ``smoke()``
(a reduced same-family config for CPU tests).  Select with ``--arch <id>``.
"""
from __future__ import annotations

from typing import Tuple

from repro.nn.config import ModelConfig

from repro.configs import (
    qwen3_moe_235b_a22b,
    deepseek_v2_lite_16b,
    hubert_xlarge,
    hymba_1_5b,
    paligemma_3b,
    minicpm_2b,
    qwen3_32b,
    yi_34b,
    gemma_7b,
    xlstm_350m,
)

_MODULES = {
    "qwen3-moe-235b-a22b": qwen3_moe_235b_a22b,
    "deepseek-v2-lite-16b": deepseek_v2_lite_16b,
    "hubert-xlarge": hubert_xlarge,
    "hymba-1.5b": hymba_1_5b,
    "paligemma-3b": paligemma_3b,
    "minicpm-2b": minicpm_2b,
    "qwen3-32b": qwen3_32b,
    "yi-34b": yi_34b,
    "gemma-7b": gemma_7b,
    "xlstm-350m": xlstm_350m,
}

ARCHS: Tuple[str, ...] = tuple(_MODULES)


def get_config(name: str, smoke: bool = False) -> ModelConfig:
    mod = _MODULES[name]
    return mod.smoke() if smoke else mod.full()


#: Shapes each arch supports (see DESIGN.md §5).  long_500k needs
#: sub-quadratic attention; encoder-only archs have no decode step.
def supported_shapes(name: str) -> Tuple[str, ...]:
    cfg = get_config(name)
    shapes = ["train_4k", "prefill_32k"]
    if not cfg.is_encoder:
        shapes.append("decode_32k")
        sub_quadratic = cfg.family in ("ssm",) or (
            cfg.sliding_window > 0 or cfg.hybrid_parallel)
        if sub_quadratic:
            shapes.append("long_500k")
    return tuple(shapes)
