"""hymba-1.5b [hybrid]: 32L d=1600 25H (GQA kv=5) d_ff=5504 vocab 32001,
parallel attention + mamba heads per block, SWA (1k) everywhere except
3 global-attention layers, ssm_state=16.  Meta-tokens omitted (orthogonal
to backbone compute; DESIGN.md §5).  [arXiv:2411.13676; hf]"""
from repro.nn.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="hymba-1.5b", family="hybrid",
        n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, head_dim=64,
        d_ff=5504, vocab=32001,
        sliding_window=1024, global_layers=(0, 15, 31),
        ssm_state=16, ssm_expand=2, hybrid_parallel=True,
        scan_layers=True,  # grouped scan: [global, scan·14, global, scan·15, global]
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="hymba-smoke", family="hybrid",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512,
        sliding_window=16, global_layers=(0,),
        ssm_state=4, ssm_expand=2, hybrid_parallel=True,
        scan_layers=False,
    )
