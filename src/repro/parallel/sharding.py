"""Logical-axis sharding rules over the production mesh.

Mesh axes: ``("data", "model")`` single pod, ``("pod", "data", "model")``
multi-pod.  The ``pod`` axis is pure data parallelism; ``data`` carries both
batch sharding and FSDP weight sharding; ``model`` carries tensor/expert/
sequence parallelism.

Logical axes used throughout the model code:

  batch   -> ("pod", "data")      activations' batch dim
  fsdp    -> "data"               weight shards (ZeRO-3 style)
  tp      -> "model"              heads / mlp / vocab / expert dims
  sp      -> "model"              sequence dim of the residual stream &
                                  KV caches (sequence parallelism)

On a single CPU device (tests, smoke runs) no mesh is installed and every
constraint is a no-op, so the same model code runs everywhere.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Optional, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = threading.local()

Axis = Union[None, str, Tuple[str, ...]]


@dataclasses.dataclass
class MeshCtx:
    mesh: Mesh

    @property
    def axis_names(self) -> Tuple[str, ...]:
        return tuple(self.mesh.axis_names)

    @property
    def has_pod(self) -> bool:
        return "pod" in self.axis_names


def set_mesh(mesh: Optional[Mesh]) -> None:
    _STATE.ctx = MeshCtx(mesh) if mesh is not None else None


def current_mesh() -> Optional[MeshCtx]:
    return getattr(_STATE, "ctx", None)


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh]):
    """Install `mesh` in this package's registry.  All sharding constraints
    and in/out_shardings are explicit NamedShardings built from it, so no
    jax-global mesh context is required."""
    prev = current_mesh()
    set_mesh(mesh)
    try:
        yield
    finally:
        _STATE.ctx = prev


def batch_axes() -> Axis:
    ctx = current_mesh()
    if ctx is None:
        return None
    return ("pod", "data") if ctx.has_pod else ("data",)


_LOGICAL = {
    "fsdp": "data",
    "tp": "model",
    "sp": "model",
}


def _resolve(axis: Axis) -> Axis:
    if axis == "batch":
        return batch_axes()
    if isinstance(axis, str):
        return _LOGICAL.get(axis, axis)
    return axis


def make_spec(*axes: Axis) -> P:
    """Build a PartitionSpec from logical axis names ('batch', 'fsdp', 'tp',
    'sp', None).  Unknown names pass through as raw mesh axes."""
    return P(*[_resolve(a) for a in axes])


def shard(x: jax.Array, *axes: Axis) -> jax.Array:
    """with_sharding_constraint under the installed mesh; no-op without one."""
    ctx = current_mesh()
    if ctx is None:
        return x
    spec = make_spec(*axes)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, spec)
    )


def named_sharding(*axes: Axis) -> Optional[NamedSharding]:
    ctx = current_mesh()
    if ctx is None:
        return None
    return NamedSharding(ctx.mesh, make_spec(*axes))
