"""INT8 error-feedback gradient compression for the cross-pod all-reduce.

The pod axis is the slow link (data-center network / optical ICI between
pods).  Gradients crossing it are quantized to int8 with per-block scales;
the quantization residual is carried in an error-feedback buffer added to
the next step's gradient, so the compression is unbiased over time (SGD with
error feedback converges at the uncompressed rate).

Applied *only* to the 'pod' axis: the intra-pod reduce runs full-precision
(ICI is fast), then the int8 stream crosses pods — a 4× wire-byte cut on
the slowest hop.  This mirrors the paper's theme: reduce the bytes of the
expensive "write" path, keep the math exact via compensation (§V-C's zero
point ↔ the error-feedback buffer).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

BLOCK = 2048


def _quantize_blockwise(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    flat = x.reshape(-1)
    pad = (-flat.size) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize_blockwise(q: jax.Array, scale: jax.Array, shape, size) -> jax.Array:
    out = (q.astype(jnp.float32) * scale).reshape(-1)[:size]
    return out.reshape(shape)


def compressed_psum_pod(grads: Any, error: Optional[Any], axis: str = "pod"
                        ) -> Tuple[Any, Any]:
    """Per-leaf: g' = psum_int8(g + e);  e' = (g + e) - dequant(quant(g + e)).

    Must run inside shard_map/pmap context where ``axis`` is bound.  Returns
    (reduced grads, new error buffers).
    """
    if error is None:
        error = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def one(g, e):
        total = g.astype(jnp.float32) + e
        q, scale = _quantize_blockwise(total)
        deq = _dequantize_blockwise(q, scale, total.shape, total.size)
        new_e = total - deq
        # int8 payload crosses the pod link; sum in fp32 after dequant.
        reduced = jax.lax.psum(deq, axis)
        return reduced.astype(g.dtype), new_e

    flat_g, td = jax.tree_util.tree_flatten(grads)
    flat_e = td.flatten_up_to(error)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return td.unflatten([o[0] for o in out]), td.unflatten([o[1] for o in out])


def compression_ratio_bytes(grads: Any) -> Tuple[int, int]:
    """(uncompressed, compressed) bytes per cross-pod reduce."""
    raw = sum(g.size * 4 for g in jax.tree.leaves(grads))
    comp = sum(g.size * 1 + (g.size // BLOCK + 1) * 4
               for g in jax.tree.leaves(grads))
    return raw, comp
