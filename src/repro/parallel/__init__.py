"""Distribution substrate: mesh-aware sharding rules and collectives."""
from repro.parallel.sharding import (
    MeshCtx,
    batch_axes,
    current_mesh,
    make_spec,
    set_mesh,
    shard,
    use_mesh,
)

__all__ = [
    "MeshCtx", "batch_axes", "current_mesh", "make_spec", "set_mesh",
    "shard", "use_mesh",
]
