"""Paged KV-cache subsystem: allocator invariants (hypothesis), paged
decode equivalence with the contiguous cache path, and end-to-end paged
engine behavior — token-for-token against the sequential oracle, requests
beyond the old per-slot max_seq, prefix sharing with COW, and preemption
under pool exhaustion."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.steps import cached_prefill_step, cached_serve_step
from repro.nn.model import decode_step, init_params, prefill
from repro.serving import (EngineModel, PageAllocator, PagedKVArena,
                           SchedulerConfig, ServingEngine)
from repro.serving.paging import _cached_page_write, init_page_pool
from repro.serving.request import RequestStatus

CFG = get_config("gemma-7b", smoke=True)
PARAMS = init_params(jax.random.PRNGKey(0), CFG)
PAGE, N_PAGES = 4, 16
POOL_TOKENS = PAGE * N_PAGES


# ------------------------------------------------------------- allocator
def _check_invariants(a: PageAllocator):
    """The occupancy-map conservation laws: every page is either free or
    referenced, refcounts equal table membership, and the free list never
    holds a live page."""
    counts = np.zeros(a.n_pages + 1, np.int64)
    for table in a.tables.values():
        for page in table:
            counts[page] += 1
    free = set(a._free)
    assert len(free) == len(a._free), "free list holds duplicates"
    for page in range(1, a.n_pages + 1):
        assert a.refcount[page] == counts[page], (
            f"page {page}: refcount {a.refcount[page]} != "
            f"{counts[page]} table refs")
        assert (page in free) == (a.refcount[page] == 0)
    assert a.n_free + int((a.refcount[1:] > 0).sum()) == a.n_pages


def test_allocator_double_free_raises():
    a = PageAllocator(4, 2)
    table, _ = a.alloc_table(0, (1, 2, 3))
    a.free_table(0)
    with pytest.raises(ValueError):
        a.free_page(table[0])


def test_allocator_rejects_oversize_atomically():
    a = PageAllocator(4, 2)
    a.alloc_table(0, (1, 2, 3))          # 2 pages
    assert a.alloc_table(1, tuple(range(10))) is None   # needs 5 > 2 free
    assert a.n_free == 2                  # no leak from the failed alloc
    _check_invariants(a)


def test_allocator_prefix_sharing_refcounts():
    a = PageAllocator(8, 4)
    prompt = (5, 6, 7, 8, 9, 10)          # 1 full + 1 partial page
    t0, s0 = a.alloc_table(0, prompt)
    assert s0 == 0 and len(t0) == 2
    a.register(0, prompt)
    t1, s1 = a.alloc_table(1, prompt)     # identical → both pages shared
    assert s1 == 2 and t1 == t0
    assert a.refcount[t0[0]] == 2
    # shared pages are only freed when the last holder lets go
    a.free_table(0)
    assert a.refcount[t1[0]] == 1 and a.n_free == 6
    a.free_table(1)
    assert a.n_free == 8
    _check_invariants(a)


def test_allocator_cow_keeps_parent_pages():
    a = PageAllocator(8, 4)
    prompt = (1, 2, 3, 4, 5)
    t0, _ = a.alloc_table(0, prompt)
    a.register(0, prompt)
    t1, s1 = a.alloc_table(1, prompt)
    assert s1 == 2
    src, dst = a.cow(1, 1)                # diverge on the partial page
    assert src == t0[1] and dst != src
    assert a.tables[0] == t0, "COW must not touch the parent's table"
    assert a.refcount[src] == 1 and a.refcount[dst] == 1
    _check_invariants(a)
    # an exclusive page COWs to itself (no copy, no allocation): after the
    # divergence above, block 1 of table 0 is singly held again
    before = a.n_free
    assert a.cow(0, 1) == (t0[1], t0[1]) and a.n_free == before


def test_allocator_property_random_ops():
    """Hypothesis sweep over alloc/register/extend/cow/free sequences: the
    conservation laws hold after every operation, and oversized requests
    fail atomically."""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    ops = st.lists(
        st.one_of(
            st.tuples(st.just("new"), st.integers(0, 5), st.integers(1, 14)),
            st.tuples(st.just("finish"), st.integers(0, 7), st.just(0)),
            st.tuples(st.just("extend"), st.integers(0, 7), st.just(0)),
            st.tuples(st.just("cow"), st.integers(0, 7), st.integers(0, 3)),
        ),
        min_size=1, max_size=60)

    @settings(max_examples=120, deadline=None)
    @given(ops=ops)
    def run(ops):
        a = PageAllocator(6, 2)
        live = []
        next_rid = 0
        for op, x, y in ops:
            if op == "new":
                # small alphabet + shared prefix lengths → real sharing
                prompt = tuple([7] * min(x + 1, 4)) + tuple(
                    range(max(y - min(x + 1, 4), 0)))
                got = a.alloc_table(next_rid, prompt)
                if got is not None:
                    a.register(next_rid, prompt)
                    live.append(next_rid)
                next_rid += 1
            elif live:
                rid = live[x % len(live)]
                if op == "finish":
                    a.free_table(rid)
                    live.remove(rid)
                elif op == "extend":
                    a.extend(rid)
                elif op == "cow":
                    a.cow(rid, y % len(a.tables[rid]))
            _check_invariants(a)
        for rid in live:
            a.free_table(rid)
        assert a.n_free == a.n_pages
        _check_invariants(a)

    run()


# ------------------------------------------------- nn-level paged decode
@pytest.mark.parametrize("arch", ["gemma-7b", "deepseek-v2-lite-16b"])
def test_paged_decode_matches_contiguous(arch):
    """decode_step over a page pool (scattered physical pages) must equal
    decode_step over the contiguous cache — GQA and MLA latent caches."""
    cfg = get_config(arch, smoke=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    ps, n_pages, plen = 4, 8, 6
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, plen), 0,
                              cfg.vocab).astype(jnp.int32)
    L = n_pages * ps
    logits, caches_c = prefill(params, {"tokens": toks}, cfg, cache_len=L)
    _, one = prefill(params, {"tokens": toks}, cfg, cache_len=2 * ps)
    pool = init_page_pool(cfg, n_pages + 1, ps)
    write = _cached_page_write(cfg, ps)
    table = [3, 5, 7]                     # scattered, out of order
    for i in range(2):
        pool = write(pool, one, jnp.int32(i), jnp.int32(table[i]))
    tables = np.zeros((1, n_pages), np.int32)
    tables[0, :3] = table
    tok = jnp.argmax(logits[:, :cfg.vocab], -1).astype(jnp.int32)
    pos = jnp.full((1,), plen, jnp.int32)
    for _ in range(4):                    # crosses into block 2 at pos 8
        ld_c, caches_c = decode_step(params, tok, caches_c, pos, cfg)
        ld_p, pool = decode_step(params, tok, pool, pos, cfg,
                                 page_table=jnp.asarray(tables))
        np.testing.assert_array_equal(np.asarray(ld_c, np.float32),
                                      np.asarray(ld_p, np.float32))
        tok = jnp.argmax(ld_c[:, :cfg.vocab], -1).astype(jnp.int32)
        pos = pos + 1


# ------------------------------------------------------- engine, paged
def sequential_tokens(prompt, n_new, cache_len=POOL_TOKENS):
    """Oracle: batch-1 prefill + decode loop at the paged gather length."""
    prefill_fn = cached_prefill_step(CFG, cache_len)
    decode = cached_serve_step(CFG)
    logits, caches = prefill_fn(
        PARAMS, {"tokens": jnp.asarray(prompt, jnp.int32)[None]})
    toks = [int(jnp.argmax(logits[0, :CFG.vocab]))]
    for i in range(n_new - 1):
        logits, caches = decode(PARAMS, jnp.asarray([toks[-1]], jnp.int32),
                                caches, jnp.int32(len(prompt) + i))
        toks.append(int(jnp.argmax(logits[0, :CFG.vocab])))
    return toks


def paged_engine(n_pages=N_PAGES, rows=3, **kw):
    kw.setdefault("sched", SchedulerConfig(max_prefill_per_step=2))
    return ServingEngine(
        [EngineModel("a", PARAMS, CFG, kv_slots=rows, max_seq=16,
                     kv_layout="paged", page_size=PAGE, n_pages=n_pages)],
        **kw)


def test_paged_engine_matches_sequential_token_for_token():
    eng = paged_engine()
    rng = np.random.default_rng(0)
    reqs = []
    for _ in range(6):
        plen = int(rng.integers(3, 12))
        prompt = rng.integers(1, CFG.vocab, plen).tolist()
        reqs.append(eng.submit("a", prompt, max_new_tokens=6))
    s = eng.run()
    assert s["requests_finished"] == 6
    assert s["max_concurrent"] >= 2
    for r in reqs:
        assert r.generated == sequential_tokens(list(r.prompt),
                                                r.max_new_tokens), r.rid


def test_paged_request_exceeds_slot_max_seq():
    """The per-slot ceiling is gone: a single request may span any number
    of pages, up to the whole pool."""
    eng = paged_engine()
    rng = np.random.default_rng(1)
    prompt = rng.integers(1, CFG.vocab, 20).tolist()
    req = eng.submit("a", prompt, max_new_tokens=24)   # 44 tokens > 16
    eng.run()
    assert req.status is RequestStatus.FINISHED
    assert req.generated == sequential_tokens(prompt, 24)
    # but the pool itself still bounds admission
    too_big = eng.submit("a", prompt, max_new_tokens=POOL_TOKENS)
    assert too_big.status is RequestStatus.REJECTED


def test_paged_prefix_sharing_and_cow_are_exact():
    """An identical prompt arriving mid-decode shares the first request's
    pages (the partial tail page included) and COWs on divergence; both
    decodes must still match the oracle exactly, and the pool must drain
    to empty when both finish."""
    eng = paged_engine(sched=SchedulerConfig(max_prefill_per_step=1))
    prompt = [7, 3, 9, 2, 5, 8, 1, 4, 6, 2]      # 2 full pages + partial
    r1 = eng.submit("a", prompt, max_new_tokens=8)
    eng.step()
    eng.step()
    r2 = eng.submit("a", prompt, max_new_tokens=8)
    eng.run()
    alloc = eng.arenas["a"].allocator
    assert alloc.shared_hits >= 3
    assert alloc.cow_copies >= 1
    ref = sequential_tokens(prompt, 8)
    assert r1.generated == ref
    assert r2.generated == ref
    assert alloc.n_free == alloc.n_pages and not alloc.tables
    s = eng.summary()
    assert s["kv_shared_page_hits"] >= 3 and s["kv_cow_copies"] >= 1


def test_paged_pool_exhaustion_preempts_and_recovers():
    """When decode outgrows the pool, the loser is preempted (pages freed,
    request requeued) and re-prefilled once pages free up — every request
    still finishes with oracle-exact tokens."""
    eng = paged_engine(n_pages=8, rows=2,
                       sched=SchedulerConfig(max_prefill_per_step=2))
    rng = np.random.default_rng(2)
    p1 = rng.integers(1, CFG.vocab, 10).tolist()
    p2 = rng.integers(1, CFG.vocab, 10).tolist()
    # each needs ceil((10+16)/4) = 7 pages to finish; the pool holds 8, so
    # the two cannot coexist to completion
    r1 = eng.submit("a", p1, max_new_tokens=16)
    r2 = eng.submit("a", p2, max_new_tokens=16)
    s = eng.run()
    assert s["requests_finished"] == 2
    assert s["preemptions"] >= 1
    assert r1.generated == sequential_tokens(p1, 16, cache_len=8 * PAGE)
    assert r2.generated == sequential_tokens(p2, 16, cache_len=8 * PAGE)


def test_paged_arena_rejects_non_attention_stack():
    with pytest.raises(ValueError):
        PagedKVArena(get_config("hymba-1.5b", smoke=True), 2, 8, 4)
