"""Continuous-batching serving engine: continuous-batched decode must match
the sequential prefill + make_serve_step path token-for-token, KV-slot
eviction must never corrupt an in-flight request, and the multi-tenant
weight residency must account installs sanely."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.steps import cached_prefill_step, cached_serve_step
from repro.nn.model import init_params
from repro.serving import (EngineModel, KVArena, SchedulerConfig,
                           ServingEngine, StepScheduler, WeightResidencyManager)
from repro.serving.request import Request, RequestStatus

MAX_SEQ = 32
CFG = get_config("gemma-7b", smoke=True)
PARAMS_A = init_params(jax.random.PRNGKey(0), CFG)
PARAMS_B = init_params(jax.random.PRNGKey(1), CFG)


def sequential_tokens(params, cfg, prompt, n_new):
    """Oracle: the plain serve.py path — batch-1 prefill, then
    make_serve_step one token at a time, same cache length as the engine."""
    prefill = cached_prefill_step(cfg, MAX_SEQ)
    decode = cached_serve_step(cfg)
    logits, caches = prefill(
        params, {"tokens": jnp.asarray(prompt, jnp.int32)[None]})
    toks = [int(jnp.argmax(logits[0, :cfg.vocab]))]
    pos = len(prompt)
    for i in range(n_new - 1):
        logits, caches = decode(params, jnp.asarray([toks[-1]], jnp.int32),
                                caches, jnp.int32(pos + i))
        toks.append(int(jnp.argmax(logits[0, :cfg.vocab])))
    return toks


def make_engine(**kw):
    kw.setdefault("sched", SchedulerConfig(max_prefill_per_step=2))
    return ServingEngine(
        [EngineModel("a", PARAMS_A, CFG, kv_slots=3, max_seq=MAX_SEQ),
         EngineModel("b", PARAMS_B, CFG, kv_slots=3, max_seq=MAX_SEQ)],
        weight_arena_slots=CFG.n_layers + 1, **kw)


def submit_mixed(eng, n, seed=0, gen=6):
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        plen = int(rng.integers(3, 12))
        prompt = rng.integers(1, CFG.vocab, plen).tolist()
        reqs.append(eng.submit("a" if i % 2 == 0 else "b", prompt,
                               max_new_tokens=gen))
    return reqs


def test_engine_matches_sequential_decode_token_for_token():
    eng = make_engine()
    reqs = submit_mixed(eng, 8)
    s = eng.run()
    assert s["requests_finished"] == 8
    assert s["max_concurrent"] >= 4  # genuinely continuous-batched
    for r in reqs:
        params = PARAMS_A if r.model == "a" else PARAMS_B
        ref = sequential_tokens(params, CFG, list(r.prompt), r.max_new_tokens)
        assert r.generated == ref, f"rid {r.rid} diverged from sequential"


def test_requests_join_and_leave_between_steps():
    eng = make_engine()
    short = eng.submit("a", [5, 6, 7], max_new_tokens=2)
    long = eng.submit("a", [8, 9, 10, 11], max_new_tokens=10)
    eng.step()
    late = eng.submit("a", [1, 2, 3, 4, 5], max_new_tokens=3)
    eng.run()
    # the short request left the batch while the long one kept decoding,
    # and the late arrival joined mid-flight — no head-of-line blocking
    assert short.status is RequestStatus.FINISHED
    assert late.status is RequestStatus.FINISHED
    assert long.status is RequestStatus.FINISHED
    for r in (short, long, late):
        params = PARAMS_A
        assert r.generated == sequential_tokens(params, CFG, list(r.prompt),
                                                r.max_new_tokens)


def test_eviction_never_corrupts_inflight_requests():
    eng = make_engine()
    reqs = submit_mixed(eng, 6, seed=3, gen=8)
    # run until everything is admitted and mid-decode
    for _ in range(4):
        eng.step()
    victim = next(r for r in reqs if r.status is RequestStatus.RUNNING)
    survivors = [r for r in reqs if r is not victim]
    eng.preempt(victim.rid)
    assert victim.status is RequestStatus.PREEMPTED
    eng.run()
    assert victim.status is RequestStatus.FINISHED
    assert victim.preemptions == 1
    # every request — the preempted one included — matches the oracle
    for r in reqs:
        params = PARAMS_A if r.model == "a" else PARAMS_B
        ref = sequential_tokens(params, CFG, list(r.prompt), r.max_new_tokens)
        assert r.generated == ref, (
            f"rid {r.rid} corrupted (preempted={r is victim})")


def test_slot_reuse_after_eviction_is_isolated():
    """A freed slot keeps stale KV codes (the _Occupancy discipline); a new
    occupant prefilled over it must decode as if the arena were fresh."""
    eng = ServingEngine(
        [EngineModel("a", PARAMS_A, CFG, kv_slots=1, max_seq=MAX_SEQ)],
        sched=SchedulerConfig(max_prefill_per_step=1))
    first = eng.submit("a", [9, 8, 7, 6, 5, 4, 3], max_new_tokens=5)
    eng.run()
    second = eng.submit("a", [3, 1, 4], max_new_tokens=5)  # same slot 0
    eng.run()
    assert first.generated == sequential_tokens(PARAMS_A, CFG,
                                                list(first.prompt), 5)
    assert second.generated == sequential_tokens(PARAMS_A, CFG,
                                                 list(second.prompt), 5)


def test_admission_control_rejects():
    eng = make_engine(sched=SchedulerConfig(max_queue=2))
    with pytest.raises(ValueError):
        eng.submit("a", [1, 2], max_new_tokens=0)
    too_long = eng.submit("a", list(range(1, MAX_SEQ)), max_new_tokens=8)
    assert too_long.status is RequestStatus.REJECTED
    eng.submit("a", [1], max_new_tokens=1)
    eng.submit("a", [2], max_new_tokens=1)
    overflow = eng.submit("a", [3], max_new_tokens=1)
    assert overflow.status is RequestStatus.REJECTED
    s = eng.run()
    assert s["requests_rejected"] == 2
    assert s["requests_finished"] == 2


def test_turn_never_lands_on_budget_blocked_tenant():
    """Regression: with a global max_active budget exhausted by tenant a,
    the time-slice must not rotate onto queued-only tenant b (which can
    neither decode nor admit) — that livelocked the engine."""
    eng = make_engine(sched=SchedulerConfig(max_active=2,
                                            max_prefill_per_step=2,
                                            model_turn_steps=4))
    r1 = eng.submit("a", [1, 2, 3], max_new_tokens=20)
    r2 = eng.submit("a", [4, 5, 6], max_new_tokens=20)
    r3 = eng.submit("b", [7, 8, 9], max_new_tokens=4)
    s = eng.run()
    assert s["requests_finished"] == 3
    for r in (r1, r2, r3):
        params = PARAMS_A if r.model == "a" else PARAMS_B
        assert r.generated == sequential_tokens(params, CFG, list(r.prompt),
                                                r.max_new_tokens)


def test_duplicate_tenant_names_rejected():
    with pytest.raises(ValueError):
        ServingEngine([
            EngineModel("a", PARAMS_A, CFG, kv_slots=2, max_seq=MAX_SEQ),
            EngineModel("a", PARAMS_B, CFG, kv_slots=2, max_seq=MAX_SEQ)])


def test_kv_arena_slot_bookkeeping():
    arena = KVArena(CFG, n_slots=3, max_seq=8)
    s0, s1 = arena.alloc(10), arena.alloc(11)
    assert {s0, s1} == {0, 1} and arena.n_free == 1
    assert arena.owner_of(s0) == 10
    arena.evict(s0)
    assert arena.n_free == 2 and arena.owner_of(s0) is None
    # freed slot is reallocated last (FIFO free list)
    assert arena.alloc(12) == 2
    assert arena.alloc(13) == s0
    assert arena.alloc(14) is None  # full


def test_scheduler_policies():
    sched = StepScheduler(SchedulerConfig(policy="sjf",
                                          max_prefill_per_step=8))
    reqs = [Request(rid=i, model="a", prompt=tuple(range(n)),
                    max_new_tokens=1, arrival_t=0.0)
            for i, n in enumerate([5, 2, 9])]
    for r in reqs:
        sched.submit(r)
    admits = sched.next_admits({"a": 3}, 0)
    assert [r.rid for r in admits] == [1, 0, 2]  # shortest first

    # a preempted request outranks shorter fresh arrivals under sjf
    preempted = Request(rid=9, model="a", prompt=tuple(range(20)),
                        max_new_tokens=4, arrival_t=0.0)
    for r in admits:
        sched.submit(r)
    sched.requeue(preempted)
    assert sched.next_admits({"a": 1}, 0) == [preempted]


def test_residency_cross_tenant_reuse_accounting():
    models = {"a": (PARAMS_A, CFG), "b": (PARAMS_B, CFG)}
    res = WeightResidencyManager(models, CFG.n_layers + 1, reuse=True)
    assert not res.fits(["a", "b"])
    w1 = res.ensure("a", step=0)
    assert res.resident_fraction("a") == 1.0
    w2 = res.ensure("b", step=1)   # evicts a's layers via delta installs
    assert res.resident_fraction("b") == 1.0
    assert res.stats.cross_tenant_installs >= 1
    assert 0 <= res.stats.savings <= 1
    assert res.ensure("b", step=2) == 0  # already resident


def test_residency_variant_tenant_delta_is_cheap():
    """An identical second tenant must install over the first almost for
    free — the pooled §V-C offsets keep aligned tenants code-identical."""
    models = {"base": (PARAMS_A, CFG), "copy": (PARAMS_A, CFG)}
    res = WeightResidencyManager(models, CFG.n_layers, reuse=True)
    cold_wire = res.ensure("base", step=0)      # cold installs ship raw
    copy_wire = res.ensure("copy", step=1)      # delta over identical codes
    # identical codes -> delta stream is just the entropy-coder table
    assert copy_wire < 0.05 * cold_wire


def test_residency_arena_too_small_raises():
    with pytest.raises(ValueError):
        WeightResidencyManager({"a": (PARAMS_A, CFG)}, CFG.n_layers - 1)


def test_sampling_greedy_default_and_top1_match_argmax():
    from repro.serving import request_key, sample_token
    logits = jnp.asarray([0.1, 2.0, -1.0, 1.9, 0.0, 5.0])  # padded vocab 6
    # greedy ignores the padded tail beyond vocab
    assert sample_token(logits, vocab=4) == 1
    key = request_key(seed=123, rid=0)
    # top-1 sampling degenerates to argmax at any temperature
    assert sample_token(logits, vocab=4, temperature=2.0, top_k=1,
                        key=key) == 1


def test_sampling_is_seed_deterministic_and_top_k_bounded():
    from repro.serving import request_key, sample_token
    logits = jnp.asarray(np.linspace(-1.0, 1.0, 16), jnp.float32)
    key = request_key(seed=7, rid=99)
    draws = [sample_token(logits, vocab=16, temperature=1.5, top_k=4,
                          key=key, step=s) for s in range(32)]
    again = [sample_token(logits, vocab=16, temperature=1.5, top_k=4,
                          key=request_key(seed=7, rid=0), step=s)
             for s in range(32)]
    assert draws == again            # seed (not rid) drives the stream
    assert set(draws) <= {12, 13, 14, 15}   # top-4 of ascending logits
    assert len(set(draws)) > 1       # genuinely stochastic at T=1.5


# ------------------------------------------------------ metrics math
def _finished_request(rid, arrival, first, finish, max_itl=None):
    r = Request(rid=rid, model="a", prompt=(1,), max_new_tokens=1,
                arrival_t=arrival)
    r.first_token_t = first
    r.finish_t = finish
    r.max_itl = max_itl
    return r


def test_metrics_quantiles_on_known_distribution():
    """p50/p95 of latency/ttft/itl on a known uniform grid must match
    numpy's linear-interpolation percentiles exactly."""
    from repro.serving import EngineMetrics
    m = EngineMetrics()
    for i in range(1, 101):   # latencies 1..100s, ttft 0.1..10s, itl i/200
        m.record_finish(_finished_request(i, 0.0, i / 10.0, float(i),
                                          max_itl=i / 200.0))
    s = m.summary(wall_s=10.0)
    assert s["latency_p50_s"] == pytest.approx(50.5)
    assert s["latency_p95_s"] == pytest.approx(95.05)
    assert s["ttft_p50_s"] == pytest.approx(5.05)
    assert s["ttft_p95_s"] == pytest.approx(9.505)
    assert s["itl_max_p50_s"] == pytest.approx(50.5 / 200.0)
    assert s["itl_max_p95_s"] == pytest.approx(95.05 / 200.0)
    assert s["requests_finished"] == 100


def test_metrics_empty_window_edge_cases():
    """No finished requests / no steps: percentiles are NaN (not a crash,
    not a misleading zero), counters and rates are zero."""
    import math

    from repro.serving import EngineMetrics
    s = EngineMetrics().summary(wall_s=0.0)
    for k in ("latency_p50_s", "latency_p95_s", "ttft_p50_s", "ttft_p95_s",
              "itl_max_p50_s", "itl_max_p95_s"):
        assert math.isnan(s[k]), k
    assert s["tokens_generated"] == 0
    assert s["tokens_per_s"] == 0
    assert s["queue_depth_mean"] == 0.0
    assert s["queue_depth_max"] == 0.0
    assert s["install_stall_steps"] == 0.0
    assert s["overlap_hidden_bytes"] == 0.0

    # a request that never got a first token contributes latency but no ttft
    m = EngineMetrics()
    m.record_finish(_finished_request(0, 0.0, None, 2.0))
    s = m.summary(wall_s=1.0)
    assert s["latency_p50_s"] == 2.0
    assert math.isnan(s["ttft_p50_s"])
    assert math.isnan(s["itl_max_p95_s"])   # single token: no gap


def test_metrics_single_sample_percentiles_degenerate():
    from repro.serving import EngineMetrics
    m = EngineMetrics()
    m.record_finish(_finished_request(0, 1.0, 2.5, 4.0, max_itl=0.25))
    s = m.summary(wall_s=1.0)
    assert s["latency_p50_s"] == s["latency_p95_s"] == 3.0
    assert s["ttft_p50_s"] == s["ttft_p95_s"] == 1.5
    assert s["itl_max_p50_s"] == s["itl_max_p95_s"] == 0.25


def test_request_max_itl_tracks_worst_gap():
    r = Request(rid=0, model="a", prompt=(1,), max_new_tokens=4,
                arrival_t=0.0)
    for t in (1.0, 2.0, 5.5, 6.0):
        r.note_token(t)
    assert r.max_itl == pytest.approx(3.5)
    assert r.last_token_t == 6.0


# -------------------------------------- residency property tests (hypothesis)
def test_residency_victim_selection_invariants():
    """Any ensure() sequence preserves the §V-C arena invariants: a pinned
    (still-decoding) tenant never loses a resident layer, wire bytes never
    exceed raw bytes, and the slot<->layer maps stay mutually consistent."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    ops_st = st.lists(st.tuples(st.sampled_from(["a", "b"]), st.booleans()),
                      min_size=1, max_size=10)

    @settings(max_examples=15, deadline=None)
    @given(ops=ops_st, spare=st.integers(min_value=0, max_value=2))
    def prop(ops, spare):
        res = WeightResidencyManager(
            {"a": (PARAMS_A, CFG), "b": (PARAMS_B, CFG)},
            CFG.n_layers + spare, reuse=True)
        for step, (model, pin_other) in enumerate(ops):
            other = "b" if model == "a" else "a"
            pinned = {model, other} if pin_other else {model}
            other_was_resident = res.is_resident(other)
            try:
                res.ensure(model, step, pinned=pinned)
            except RuntimeError:
                # infeasible only when the pinned pair exceeds the arena —
                # and the failed call must not have touched residency
                assert pin_other and not res.fits({"a", "b"})
                assert res.is_resident(other) == other_was_resident
                continue
            assert res.is_resident(model)
            # never evicts a layer still needed by the pinned decode tenant
            if pin_other and other_was_resident:
                assert res.is_resident(other)
            # slot <-> layer maps agree, one slot per layer
            for layer, slot in res.resident.items():
                assert res.slots[slot] == layer
            occupants = [l for l in res.slots if l is not None]
            assert len(occupants) == len(set(occupants))
            assert len(occupants) == len(res.resident)
            # stats invariants: the delta stream never ships more than raw,
            # skip fractions stay within [0, 1] per install
            assert 0 <= res.stats.wire_bytes <= res.stats.raw_bytes
            assert 0.0 <= res.stats.skips <= res.stats.installs
            assert res.stats.cold_installs <= res.stats.installs
            assert 0.0 <= res.stats.savings <= 1.0

    prop()


def test_residency_reuse_off_ships_raw():
    """With reuse disabled every install ships the full code stream: wire
    bytes == raw bytes and no cell is ever skipped."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=10, deadline=None)
    @given(ops=st.lists(st.sampled_from(["a", "b"]), min_size=1,
                        max_size=10))
    def prop(ops):
        res = WeightResidencyManager(
            {"a": (PARAMS_A, CFG), "b": (PARAMS_B, CFG)},
            CFG.n_layers + 1, reuse=False)
        for step, model in enumerate(ops):
            res.ensure(model, step)
        assert res.stats.wire_bytes == res.stats.raw_bytes
        assert res.stats.skips == 0.0

    prop()


def test_engine_sampled_requests_are_reproducible():
    """Same seed → same continuation, across engine instances; greedy
    requests in the same batch stay oracle-exact."""
    outs = []
    for _ in range(2):
        eng = make_engine()
        sampled = eng.submit("a", [5, 6, 7, 8], max_new_tokens=6,
                             temperature=0.9, top_k=8, seed=42)
        greedy = eng.submit("a", [5, 6, 7, 8], max_new_tokens=6)
        eng.run()
        assert greedy.generated == sequential_tokens(
            PARAMS_A, CFG, list(greedy.prompt), 6)
        outs.append(list(sampled.generated))
    assert outs[0] == outs[1]
