"""Optimizer, schedules, data determinism, checkpoint round-trips."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.checkpoint.checkpoint import wait_for_saves
from repro.configs import get_config
from repro.data.pipeline import DataConfig, make_batch
from repro.optim.adamw import adamw_init, adamw_update
from repro.optim.schedules import cosine, wsd


def test_adamw_minimizes_quadratic():
    params = {"w": jnp.asarray([3.0, -2.0, 1.5])}
    opt = adamw_init(params)
    loss = lambda p: jnp.sum(jnp.square(p["w"]))
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, opt, _ = adamw_update(params, g, opt, jnp.float32(5e-2),
                                      weight_decay=0.0)
    assert float(loss(params)) < 1e-2


def test_grad_clip_bounds_update():
    params = {"w": jnp.zeros(4)}
    opt = adamw_init(params)
    g = {"w": jnp.full((4,), 1e9)}
    p2, _, m = adamw_update(params, g, opt, jnp.float32(1e-2), grad_clip=1.0,
                            weight_decay=0.0)
    assert float(m["grad_norm"]) > 1e8        # reported pre-clip
    assert float(jnp.max(jnp.abs(p2["w"]))) < 1.0


def test_wsd_schedule_phases():
    lr = lambda s: float(wsd(s, peak_lr=1.0, warmup=10, stable=100, decay=50))
    assert lr(0) == 0.0
    assert lr(10) == pytest.approx(1.0)
    assert lr(60) == pytest.approx(1.0)
    assert lr(110) == pytest.approx(1.0)
    assert lr(160) == pytest.approx(0.01, rel=1e-3)
    assert lr(135) < 1.0


def test_cosine_schedule():
    assert float(cosine(0, peak_lr=1.0, warmup=5, total=100)) == 0.0
    assert float(cosine(5, peak_lr=1.0, warmup=5, total=100)) == pytest.approx(1.0)
    assert float(cosine(100, peak_lr=1.0, warmup=5, total=100)) == pytest.approx(0.1)


def test_data_pipeline_deterministic_and_step_indexed():
    cfg = get_config("minicpm-2b", smoke=True)
    data = DataConfig(seq_len=32, global_batch=4, seed=7)
    a = make_batch(cfg, data, 3)
    b = make_batch(cfg, data, 3)
    c = make_batch(cfg, data, 4)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert not np.array_equal(a["tokens"], c["tokens"])
    assert a["tokens"].max() < cfg.vocab


def test_checkpoint_roundtrip_and_atomicity(tmp_path):
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.int32)}}
    d = str(tmp_path)
    save_checkpoint(d, 10, tree)
    save_checkpoint(d, 20, tree, block=False)
    wait_for_saves()
    assert latest_step(d) == 20
    assert not any(p.endswith(".tmp") for p in os.listdir(d))
    target = jax.tree.map(jnp.zeros_like, tree)
    out = restore_checkpoint(d, 10, target)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
    np.testing.assert_array_equal(np.asarray(out["b"]["c"]),
                                  np.asarray(tree["b"]["c"]))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 1, {"a": jnp.ones((2, 2))})
    with pytest.raises(ValueError):
        restore_checkpoint(d, 1, {"a": jnp.ones((3, 3))})


def test_elastic_restore_resharding(tmp_path):
    """A checkpoint restores under a different sharding (mesh change)."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    d = str(tmp_path)
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    save_checkpoint(d, 1, tree)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    sh = {"w": NamedSharding(mesh, P("data", None))}
    out = restore_checkpoint(d, 1, tree, sh)
    assert out["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(tree["w"]))
