"""Overlapped cross-tenant weight installs, on a deterministic
simulated-time harness.

The engine runs on a `VirtualClock` with a budgeted install pipeline (one
tick per step, tick sized so a tenant switch spans multiple steps), so
every stall step, hidden byte, and latency percentile is exactly
reproducible without a device.  The core claims:

  * overlapped installs are token-for-token identical to synchronous ones
    (and to the unbudgeted instant-`ensure` baseline);
  * under a two-tenant Poisson workload, install stall steps strictly drop
    with overlap on;
  * with overlap on, install work lands DURING decode steps (hidden under
    compute); synchronously it only ever lands BETWEEN them.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.nn.model import init_params
from repro.serving import (EngineModel, InstallCostModel, InstallPipeline,
                           SchedulerConfig, ServingEngine, VirtualClock,
                           WeightResidencyManager, drive_simulated)

MAX_SEQ = 32
TURN_STEPS = 4
CFG = get_config("gemma-7b", smoke=True)
# independent inits (not a perturbed variant): cross-tenant deltas stay
# expensive, so a switch genuinely spans multiple install ticks
PARAMS_A = init_params(jax.random.PRNGKey(0), CFG)
PARAMS_B = init_params(jax.random.PRNGKey(1), CFG)


# --------------------------------------------------------------- harness
def poisson_jobs(seed=0, n=12, rate=0.5):
    """Two-tenant Poisson arrivals in virtual time units (1.0 = one step)."""
    rng = np.random.default_rng(seed)
    t, jobs = 0.0, []
    for i in range(n):
        t += float(rng.exponential(1.0 / rate))
        plen = int(rng.integers(3, 10))
        jobs.append((t, "a" if i % 2 == 0 else "b",
                     rng.integers(1, CFG.vocab, plen).tolist(),
                     int(rng.integers(6, 12))))
    return jobs


def make_engine(*, overlap=False, ticks=1, bytes_per_tick=1 << 30,
                clock=None):
    clock = clock or VirtualClock()
    eng = ServingEngine(
        [EngineModel("a", PARAMS_A, CFG, kv_slots=3, max_seq=MAX_SEQ),
         EngineModel("b", PARAMS_B, CFG, kv_slots=3, max_seq=MAX_SEQ)],
        weight_arena_slots=CFG.n_layers + 1,   # can't co-host: turn switches
        sched=SchedulerConfig(max_prefill_per_step=2,
                              model_turn_steps=TURN_STEPS),
        clock=clock, install_ticks_per_step=ticks, overlap_installs=overlap,
        install_cost=InstallCostModel(bytes_per_tick=bytes_per_tick))
    return eng, clock


def run_arm(jobs, **kw):
    eng, clock = make_engine(**kw)
    summary = drive_simulated(eng, clock, jobs, max_steps=10_000)
    tokens = {r.rid: list(r.generated) for r in eng.requests.values()}
    return eng, summary, tokens


# ----------------------------------------------------------------- tests
def test_overlap_token_for_token_and_strictly_fewer_stalls():
    jobs = poisson_jobs()
    _, sync_s, sync_tok = run_arm(jobs, overlap=False)
    eng, over_s, over_tok = run_arm(jobs, overlap=True)
    _, base_s, base_tok = run_arm(jobs, ticks=0)   # unbudgeted ensure()

    assert sync_tok == base_tok, "tick budgeting changed decoded tokens"
    assert over_tok == sync_tok, "overlap changed decoded tokens"
    assert sync_s["requests_finished"] == len(jobs)
    assert over_s["requests_finished"] == len(jobs)

    # the sync arm pays for every switch; the overlap arm must pay strictly
    # less, having hidden install stream under the outgoing tenant's decode
    assert sync_s["install_stall_steps"] > 0
    assert over_s["install_stall_steps"] < sync_s["install_stall_steps"]
    assert over_s["overlap_hidden_bytes"] > 0
    assert sync_s["overlap_hidden_bytes"] == 0
    # both arms move real install streams (how many switches each pays for
    # can differ — hiding installs shortens the episode and its rotations)
    assert over_s["install_work_bytes"] > 0
    assert sync_s["install_work_bytes"] > 0
    # hiding installs shortens the whole episode and the worst per-request
    # inter-token gap (the stall lands exactly at the tenant boundary)
    assert over_s["steps"] < sync_s["steps"]
    assert over_s["itl_max_p95_s"] <= sync_s["itl_max_p95_s"]


def test_installs_land_during_not_between_decode_steps():
    jobs = poisson_jobs(seed=1)
    sync_eng, _, _ = run_arm(jobs, overlap=False)
    over_eng, _, _ = run_arm(jobs, overlap=True)

    def work_steps(eng):
        return [s for s in eng.metrics.steps if s.install_work_bytes > 0]

    # synchronous: install work only ever happens on token-less stall steps
    for s in work_steps(sync_eng):
        assert s.n_decoded + s.n_prefills == 0
        assert s.install_stall
        assert s.overlap_hidden_bytes == 0
    # overlapped: some install work lands on steps that also decoded —
    # the transfer ran during, not between, decode steps
    hidden = [s for s in work_steps(over_eng) if s.n_decoded > 0]
    assert hidden, "no install work was hidden under decode"
    for s in hidden:
        assert s.overlap_hidden_bytes == s.install_work_bytes
        assert not s.install_stall


def test_virtual_clock_harness_is_deterministic():
    jobs = poisson_jobs(seed=2)
    _, s1, tok1 = run_arm(jobs, overlap=True)
    _, s2, tok2 = run_arm(jobs, overlap=True)
    assert tok1 == tok2
    assert s1 == s2   # every latency/stall metric, bit-for-bit


def test_partial_install_spans_steps_and_commits_once():
    """With a tick budget smaller than one layer's stream, installs span
    several steps: stats commit exactly once per layer, at completion."""
    # sizing needs the quantized store only, not a whole engine
    probe = WeightResidencyManager({"a": (PARAMS_A, CFG)}, CFG.n_layers)
    per_layer = max(lw.codes.size for lw in probe.store.layers)
    eng, clock = make_engine(overlap=False, ticks=1,
                             bytes_per_tick=max(per_layer // 3, 1))
    eng.submit("a", [5, 6, 7], max_new_tokens=2)
    installs_seen = []
    for _ in range(40):
        if not eng.has_work():
            break
        eng.step()
        clock.advance(1.0)
        installs_seen.append(eng.residency.stats.installs)
    assert eng.residency.stats.installs == CFG.n_layers
    # cold install of layer streams takes >= 3 ticks each -> the install
    # count climbs over multiple steps instead of jumping in one
    first_commit_step = next(i for i, n in enumerate(installs_seen) if n)
    assert first_commit_step >= 2
    assert eng.residency.stats.wire_bytes <= eng.residency.stats.raw_bytes


def test_pipeline_never_evicts_pinned_tenant_layers():
    """Mid-turn prefetch may only take free slots; the decoding tenant's
    layers are stolen no earlier than its final slice step."""
    jobs = poisson_jobs(seed=3)
    eng, clock = make_engine(overlap=True)
    pre = {}
    resident_ok = []

    def before_step(e):
        decoding = [n for n, a in e.arenas.items() if a.active_slots()]
        pre["resident"] = {n: e.residency.is_resident(n) for n in decoding}
        pre["holder"] = e.scheduler.current_turn_model
        # the upcoming step is the holder's final slice step when its
        # remaining budget is about to hit zero (pick_models decrements)
        pre["will_be_final"] = e.scheduler.turn_steps_left <= 1

    def after_step(e):
        # a tenant that was resident and decoding stays resident through
        # the step unless that step was its final slice step
        for n, was in pre["resident"].items():
            if was and not pre["will_be_final"] and n == pre["holder"]:
                resident_ok.append(e.residency.is_resident(n))

    drive_simulated(eng, clock, jobs, max_steps=10_000,
                    before_step=before_step, after_step=after_step)
    assert resident_ok and all(resident_ok)


def test_overlap_requires_tick_budget():
    with pytest.raises(ValueError):
        make_engine(overlap=True, ticks=0)


def test_install_pipeline_unit_greedy_and_abort():
    """Pipeline-level unit test: begin/pump respect pins, commit greedily
    min-delta, and abort in-flight work when the victim is re-pinned."""
    res = WeightResidencyManager(
        {"a": (PARAMS_A, CFG), "b": (PARAMS_B, CFG)},
        CFG.n_layers, reuse=True)   # exactly one tenant fits: no spare slot
    res.ensure("a", step=0)
    pipe = InstallPipeline(res, InstallCostModel(bytes_per_tick=1 << 30))
    pipe.begin("b", step=1)
    # everything pinned: no evictable slot, no progress, no crash
    wire, work = pipe.pump(4, {"a", "b"}, step=1)
    assert (wire, work) == (0, 0) and not res.is_resident("b")
    # unpin a: one tick per layer suffices at this tick size
    wire, work = pipe.pump(CFG.n_layers + 1, {"b"}, step=2)
    assert res.is_resident("b") and wire > 0 and work >= wire
    assert pipe.idle
    # in-flight abort: big layer, tiny tick -> partial install, then re-pin
    pipe2 = InstallPipeline(res, InstallCostModel(bytes_per_tick=8))
    pipe2.begin("a", step=3)
    pipe2.pump(2, {"a"}, step=3)          # 2 ticks of a many-tick stream
    assert pipe2.aborts == 0
    pipe2.pump(2, {"a", "b"}, step=4)     # victim re-pinned mid-flight
    assert pipe2.aborts == 1
