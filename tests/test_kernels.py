"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU), with
shape/dtype sweeps as required for every kernel."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.crossbar_mvm.ops import crossbar_mvm
from repro.kernels.crossbar_mvm.ref import crossbar_mvm_ref
from repro.kernels.delta_apply.ops import apply_delta
from repro.kernels.delta_apply.ref import delta_apply_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.paged_attention.ops import paged_attention
from repro.kernels.paged_attention.ref import paged_attention_ref
from repro.kernels.pulse_count.ops import pulse_count
from repro.kernels.pulse_count.ref import pulse_count_ref


@pytest.mark.parametrize("n", [17, 4096, 70_001])
def test_delta_apply_sweep(n):
    rng = np.random.default_rng(n)
    old = jnp.asarray(rng.integers(0, 256, n, dtype=np.uint8))
    new = jnp.asarray(rng.integers(0, 256, n, dtype=np.uint8))
    delta = ((new.astype(jnp.int32) - old.astype(jnp.int32)) % 256).astype(jnp.uint8)
    out = apply_delta(old, delta)
    assert (out == delta_apply_ref(old, delta)).all()
    assert (out == new).all()


@pytest.mark.parametrize("n", [100, 33_000])
def test_pulse_count_sweep(n):
    rng = np.random.default_rng(n)
    old = jnp.asarray(rng.integers(0, 256, n, dtype=np.uint8))
    new = jnp.asarray(rng.integers(0, 256, n, dtype=np.uint8))
    p, s = pulse_count(old, new)
    pr, sr = pulse_count_ref(old, new)
    assert int(p) == int(pr) and int(s) == int(sr)


@pytest.mark.parametrize("m,k,n", [(8, 64, 8), (70, 300, 90), (128, 128, 128),
                                   (200, 1000, 64)])
def test_crossbar_mvm_sweep(m, k, n):
    rng = np.random.default_rng(m * k + n)
    x = jnp.asarray(rng.integers(0, 256, (m, k), dtype=np.uint8))
    w = jnp.asarray(rng.integers(0, 256, (k, n), dtype=np.uint8))
    zx = jnp.float32(rng.uniform(0, 255))
    zw = jnp.float32(rng.uniform(0, 255))
    sc = jnp.float32(10 ** rng.uniform(-5, -2))
    a = crossbar_mvm(x, w, zx, zw, sc)
    b = crossbar_mvm_ref(x, w, zx, zw, sc)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-2)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("s,d,causal", [(128, 32, True), (200, 64, False),
                                        (256, 16, True)])
def test_flash_attention_kernel_sweep(s, d, causal, dtype):
    key = jax.random.PRNGKey(s + d)
    B, H = 2, 3
    q = jax.random.normal(key, (B, s, H, d), dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, s, H, d), dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, s, H, d), dtype)
    o = flash_attention(q, k, v, causal=causal, bq=64, bk=64)
    qt = q.transpose(0, 2, 1, 3).reshape(B * H, s, d)
    kt = k.transpose(0, 2, 1, 3).reshape(B * H, s, d)
    vt = v.transpose(0, 2, 1, 3).reshape(B * H, s, d)
    r = flash_attention_ref(qt, kt, vt, causal=causal)
    r = r.reshape(B, H, s, d).transpose(0, 2, 1, 3)
    tol = 2e-2 if dtype == jnp.bfloat16 else 3e-4
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,hkv,d,pool,page,t",
                         [(3, 4, 2, 32, 9, 4, 6),    # GQA, odd pool
                          (2, 6, 6, 16, 5, 8, 4),    # MHA
                          (1, 8, 2, 64, 12, 16, 8)])  # single row, big page
def test_paged_attention_kernel_sweep(b, h, hkv, d, pool, page, t, dtype):
    """Decode through scattered page tables must match the gather oracle,
    including rows whose position sits mid-page (masked tail)."""
    rng = np.random.default_rng(b * pool + page)
    q = jnp.asarray(rng.standard_normal((b, h, d)), dtype)
    kp = jnp.asarray(rng.standard_normal((pool, page, hkv, d)), dtype)
    vp = jnp.asarray(rng.standard_normal((pool, page, hkv, d)), dtype)
    tables = jnp.asarray(
        np.stack([rng.choice(pool, t, replace=False) for _ in range(b)]),
        jnp.int32)
    pos = jnp.asarray(rng.integers(0, t * page, b), jnp.int32)
    o = paged_attention(q, kp, vp, tables, pos)
    r = paged_attention_ref(q, kp, vp, tables, pos)
    tol = 2e-2 if dtype == jnp.bfloat16 else 3e-4
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32), rtol=tol, atol=tol)


def test_paged_attention_masks_stale_pages():
    """Pages past a row's position may hold arbitrary stale garbage (the
    freed-page occupancy discipline) without perturbing the output."""
    rng = np.random.default_rng(7)
    b, h, hkv, d, pool, page, t = 2, 4, 2, 16, 10, 4, 5
    q = jnp.asarray(rng.standard_normal((b, h, d)), jnp.float32)
    kp = np.asarray(rng.standard_normal((pool, page, hkv, d)), np.float32)
    vp = np.asarray(rng.standard_normal((pool, page, hkv, d)), np.float32)
    # disjoint tables: a page stale for one row must not be live in another
    tables = rng.permutation(pool).reshape(b, t)
    pos = np.asarray([5, 9])
    base = paged_attention(q, jnp.asarray(kp), jnp.asarray(vp),
                           jnp.asarray(tables, np.int32),
                           jnp.asarray(pos, np.int32))
    for row in range(b):
        for blk in range(pos[row] // page + 1, t):
            kp[tables[row, blk]] = 1e4 * rng.standard_normal((page, hkv, d))
            vp[tables[row, blk]] = 1e4
    out = paged_attention(q, jnp.asarray(kp), jnp.asarray(vp),
                          jnp.asarray(tables, np.int32),
                          jnp.asarray(pos, np.int32))
    np.testing.assert_array_equal(np.asarray(base), np.asarray(out))
