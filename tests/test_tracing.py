"""Structured tracing: a simulated two-tenant run must produce a valid,
deterministic Chrome-trace document with balanced request lifecycles; the
per-step component breakdown must account (exactly, under virtual time)
for step wall time; the typed metrics registry must reproduce the legacy
EngineMetrics quantile behaviour; and the disabled-tracer path must stay
allocation-free so instrumentation is safe to leave in hot loops."""
import json
import math
import time

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.nn.model import init_params
from repro.serving import (EngineMetrics, EngineModel, InstallCostModel,
                           MetricsRegistry, NULL_TRACER, NullTracer,
                           SchedulerConfig, ServingEngine, Tracer,
                           VirtualClock, WeightResidencyManager,
                           drive_simulated)
from repro.serving.tracing import (_NULL_SPAN, REQUEST_PHASES,
                                   TRACE_COMPONENTS)

MAX_SEQ = 32
CFG = get_config("gemma-7b", smoke=True)
PARAMS_A = init_params(jax.random.PRNGKey(0), CFG)
PARAMS_B = init_params(jax.random.PRNGKey(1), CFG)
N_JOBS = 8


def two_tenant_jobs(seed=0, n=N_JOBS):
    rng = np.random.default_rng(seed)
    t, jobs = 0.0, []
    for i in range(n):
        t += float(rng.exponential(1.5))
        plen = int(rng.integers(3, 10))
        jobs.append((t, "a" if i % 2 == 0 else "b",
                     rng.integers(1, CFG.vocab, plen).tolist(),
                     int(rng.integers(4, 8))))
    return jobs


def make_engine(tracer=None, clock=None):
    clock = clock or VirtualClock()
    eng = ServingEngine(
        [EngineModel("a", PARAMS_A, CFG, kv_slots=3, max_seq=MAX_SEQ),
         EngineModel("b", PARAMS_B, CFG, kv_slots=3, max_seq=MAX_SEQ)],
        weight_arena_slots=CFG.n_layers + 1,   # can't co-host: turn switches
        sched=SchedulerConfig(max_prefill_per_step=2),
        clock=clock, tracer=tracer)
    return eng, clock


def traced_run(seed=0):
    """Two-tenant simulated run with the tracer on the same VirtualClock."""
    clock = VirtualClock()
    tracer = Tracer(clock=clock)
    eng, _ = make_engine(tracer=tracer, clock=clock)
    summary = drive_simulated(eng, clock, two_tenant_jobs(seed),
                              max_steps=10_000)
    return eng, tracer, summary


# ------------------------------------------------------------ trace schema
def test_trace_schema_and_balanced_request_lifecycles():
    eng, tracer, summary = traced_run()
    assert summary["requests_finished"] == N_JOBS
    assert not tracer._open_phase, "a lifecycle span was left open"

    doc = tracer.chrome_trace_doc()
    assert doc["displayTimeUnit"] == "ms"
    evs = doc["traceEvents"]
    meta = [e for e in evs if e["ph"] == "M"]
    body = [e for e in evs if e["ph"] != "M"]
    assert body, "trace is empty"

    # process/thread metadata: both pids named, every tid used is named
    pnames = {(e["pid"], e["args"]["name"])
              for e in meta if e["name"] == "process_name"}
    assert (0, "engine") in pnames and (1, "requests") in pnames
    named = {(e["pid"], e["tid"]) for e in meta if e["name"] == "thread_name"}
    for e in body:
        assert e["ph"] in ("X", "i", "C")
        assert isinstance(e["ts"], (int, float))
        if e["ph"] == "X":
            assert e["dur"] >= 0
        if e["ph"] == "i":
            assert e["s"] in ("g", "t")
        if "tid" in e:
            assert isinstance(e["tid"], int), "Chrome tids must be integers"
            assert (e["pid"], e["tid"]) in named, f"unnamed tid in {e}"

    # component spans live on pid 0 under canonical component names
    comp_spans = [e for e in body if e["pid"] == 0 and e["ph"] == "X"]
    assert comp_spans
    tid_names = {(e["pid"], e["tid"]): e["args"]["name"]
                 for e in meta if e["name"] == "thread_name"}
    for e in comp_spans:
        assert tid_names[(0, e["tid"])] in TRACE_COMPONENTS

    # request lifecycles: every request starts queued and ends finished,
    # with only known phases in between and non-overlapping spans
    per_req = {}
    for e in body:
        if e["pid"] == 1:
            per_req.setdefault(e["tid"], []).append(e)
    assert len(per_req) == N_JOBS
    for seq in per_req.values():
        names = [e["name"] for e in seq if not e["name"].endswith(":enter")]
        assert names[0] == "queued"
        assert names[-1] == "finished"
        assert set(names) <= set(REQUEST_PHASES)
        spans = [e for e in seq if e["ph"] == "X"]
        for a, b in zip(spans, spans[1:]):
            assert a["ts"] + a["dur"] <= b["ts"] + 1e-6

    # under virtual time the clock never advances inside a step, so the
    # component breakdown accounts for step wall time *exactly* (both 0)
    assert eng.metrics.steps
    for rec in eng.metrics.steps:
        assert set(rec.component_s) <= set(TRACE_COMPONENTS)
        assert sum(rec.component_s.values()) == 0.0

    # summary surfaces per-component totals
    assert any(k.startswith("component_") for k in summary)


def test_wall_clock_component_times_sum_within_step_wall_time():
    # engine on virtual time (deterministic schedule), tracer on the wall
    # clock: each step's component sum must be positive and bounded by the
    # step's measured wall time (components are disjoint sub-intervals)
    tracer = Tracer()   # wall clock
    eng, clock = make_engine(tracer=tracer)
    walls, t0 = [], [0.0]
    drive_simulated(
        eng, clock, two_tenant_jobs(n=4), max_steps=10_000,
        before_step=lambda e: t0.__setitem__(0, time.perf_counter()),
        after_step=lambda e: walls.append(time.perf_counter() - t0[0]))
    assert len(walls) == len(eng.metrics.steps)
    for rec, wall in zip(eng.metrics.steps, walls):
        comp = sum(rec.component_s.values())
        assert comp > 0.0
        assert comp <= wall + 1e-4


def test_virtual_clock_traces_are_byte_identical_across_runs():
    _, t1, s1 = traced_run(seed=2)
    _, t2, s2 = traced_run(seed=2)
    assert s1 == s2
    j1, j2 = t1.to_chrome_json(), t2.to_chrome_json()
    assert j1 == j2, "virtual-clock trace is not deterministic"
    json.loads(j1)   # well-formed JSON document


def test_request_timeline_renders_phase_history():
    tracer = Tracer(clock=VirtualClock())
    tracer.request_phase(7, "queued")
    tracer.request_phase(7, "prefilling")
    tracer.request_phase(7, "running")
    line = tracer.request_timeline(7)
    assert "queued=" in line and "prefilling=" in line
    assert line.endswith("*"), "open phase should be starred"
    tracer.request_phase(7, "finished")
    assert "*" not in tracer.request_timeline(7)
    assert tracer.request_timeline(999) == "(no spans)"


# ------------------------------------------------------- metrics registry
def test_registry_counter_gauge_histogram_semantics():
    reg = MetricsRegistry()
    c = reg.counter("toks")
    c.inc()
    c.inc(5)
    assert c.value == 6 and isinstance(c.value, int)
    with pytest.raises(ValueError):
        c.inc(-1)

    g = reg.gauge("depth")
    g.set(3)
    g.set(1)
    assert g.value == 1 and g.max == 3

    h = reg.histogram("lat")
    assert math.isnan(h.quantile(50))
    assert math.isnan(h.mean())
    for v in range(1, 101):
        h.observe(float(v))
    # np.percentile linear interpolation, exactly the legacy _pct helper
    assert h.quantile(50) == pytest.approx(50.5)
    assert h.quantile(95) == pytest.approx(95.05)
    assert h.quantile(95) == pytest.approx(
        float(np.percentile(np.arange(1.0, 101.0), 95)))
    assert h.count == 100 and h.sum == pytest.approx(5050.0)

    # get-or-create returns the same instrument; type conflicts are errors
    assert reg.counter("toks") is c
    with pytest.raises(TypeError):
        reg.gauge("toks")

    d = reg.as_dict()
    assert d["toks"] == 6.0
    assert d["depth"] == 1.0 and d["depth_max"] == 3.0
    assert d["lat_count"] == 100.0 and d["lat_p50"] == pytest.approx(50.5)


def test_engine_metrics_empty_window_quantiles_are_nan():
    m = EngineMetrics()
    s = m.summary(1.0)
    for key in ("latency_p50_s", "latency_p95_s", "ttft_p50_s",
                "ttft_p95_s", "itl_max_p50_s", "itl_max_p95_s"):
        assert math.isnan(s[key]), f"{key} should be NaN with no requests"
    assert s["requests_finished"] == 0.0
    # registry export mirrors the same empty-window behaviour
    d = m.registry.as_dict()
    assert d["request_ttft_s_count"] == 0.0
    assert math.isnan(d["request_ttft_s_p95"])


# ------------------------------------------------------ disabled-path cost
def test_null_tracer_is_allocation_free():
    t = NULL_TRACER
    assert isinstance(t, NullTracer)
    assert t.enabled is False
    assert NullTracer.__slots__ == ()   # no per-instance dict either
    # every span call returns the one shared no-op context manager: the
    # disabled path allocates no span or event objects at all
    s = t.span("decode", step=3)
    assert s is t.span("sample") is _NULL_SPAN
    with s:
        pass
    assert t.instant("kv_evict", pages=4) is None
    assert t.counter("queue_depth", 7) is None
    assert t.request_phase(0, "queued") is None
    assert t.step_components() == {}
    assert t.request_timeline(0) == ""
    assert not hasattr(t, "events")
    with pytest.raises(RuntimeError):
        t.export_chrome_trace("/dev/null")


def test_serving_headline_junit_properties(record_property):
    """Virtual-clock two-tenant run with budgeted synchronous installs,
    publishing the serving headline numbers (ttft p95, worst inter-token
    gap p95, install stall steps, prefix hit rate, trace size) as junit
    <properties> — CI re-runs this test in a named step so the numbers
    surface per workflow run alongside the BENCH_serving.json artifact."""
    probe = WeightResidencyManager(
        {"a": (PARAMS_A, CFG), "b": (PARAMS_B, CFG)}, CFG.n_layers)
    bpt = max(max(lw.codes.size for lw in probe.store.layers) // 2, 1)
    clock = VirtualClock()
    tracer = Tracer(clock=clock)
    eng = ServingEngine(
        [EngineModel("a", PARAMS_A, CFG, kv_slots=3, max_seq=MAX_SEQ),
         EngineModel("b", PARAMS_B, CFG, kv_slots=3, max_seq=MAX_SEQ)],
        weight_arena_slots=CFG.n_layers + 1,
        sched=SchedulerConfig(max_prefill_per_step=2),
        clock=clock, tracer=tracer,
        install_ticks_per_step=1,
        install_cost=InstallCostModel(bytes_per_tick=bpt))
    s = drive_simulated(eng, clock, two_tenant_jobs(), max_steps=10_000)
    assert s["requests_finished"] == N_JOBS
    # tick-budgeted synchronous installs pay every tenant switch in full
    assert s["install_stall_steps"] > 0
    record_property("ttft_p95_ms", round(s["ttft_p95_s"] * 1e3, 3))
    record_property("itl_max_p95_ms", round(s["itl_max_p95_s"] * 1e3, 3))
    record_property("install_stall_steps", int(s["install_stall_steps"]))
    record_property("prefix_hit_rate", round(s["prefix_hit_rate"], 4))
    record_property("trace_events", len(tracer.events))


def test_untraced_engine_records_empty_component_breakdowns():
    eng, clock = make_engine()   # no tracer: engine keeps NULL_TRACER
    assert eng.tracer is NULL_TRACER
    drive_simulated(eng, clock, two_tenant_jobs(n=2), max_steps=10_000)
    assert eng.metrics.steps
    for rec in eng.metrics.steps:
        assert rec.component_s == {}
