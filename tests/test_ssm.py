"""SSM blocks: chunkwise-parallel mLSTM vs per-step oracle, decode
consistency for mamba/mLSTM/sLSTM."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.nn.config import ModelConfig
from repro.nn.ssm import (
    init_mamba,
    init_mamba_cache,
    init_mlstm,
    init_mlstm_cache,
    init_slstm,
    init_slstm_cache,
    mamba,
    mlstm,
    slstm,
)

CFG = ModelConfig(name="t", family="ssm", n_layers=1, d_model=32, n_heads=2,
                  n_kv_heads=2, d_ff=0, vocab=64, attn_type="none",
                  ssm_heads=2, ssm_expand=2, ssm_state=4, scan_layers=False)


def test_mlstm_chunked_matches_stepwise():
    """The GLA-style chunkwise form must equal the naive recurrence."""
    params = init_mlstm(jax.random.PRNGKey(0), CFG)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, 32), jnp.float32)
    # chunked (chunk=8 → 3 chunks)
    y_chunk, _ = mlstm(params, x, CFG, chunk=8)
    # stepwise via decode cache, one token at a time
    cache = init_mlstm_cache(CFG, 2)
    ys = []
    for t in range(24):
        y, cache = mlstm(params, x[:, t:t + 1], CFG, cache=cache)
        ys.append(y)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk, np.float32),
                               np.asarray(y_step, np.float32),
                               rtol=2e-3, atol=2e-3)


def test_mamba_decode_matches_scan():
    params = init_mamba(jax.random.PRNGKey(0), CFG)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, 32), jnp.float32)
    y_scan, _ = mamba(params, x, CFG, chunk=4)
    cache = init_mamba_cache(CFG, 2, dtype=jnp.float32)
    ys = []
    for t in range(12):
        y, cache = mamba(params, x[:, t:t + 1], CFG, cache=cache)
        ys.append(y)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_scan, np.float32),
                               np.asarray(y_step, np.float32),
                               rtol=2e-3, atol=2e-3)


def test_slstm_decode_matches_scan():
    params = init_slstm(jax.random.PRNGKey(0), CFG)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 10, 32), jnp.float32)
    y_scan, _ = slstm(params, x, CFG, chunk=5)
    cache = init_slstm_cache(CFG, 2)
    ys = []
    for t in range(10):
        y, cache = slstm(params, x[:, t:t + 1], CFG, cache=cache)
        ys.append(y)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_scan, np.float32),
                               np.asarray(y_step, np.float32),
                               rtol=2e-3, atol=2e-3)


def test_mlstm_gradients_finite():
    params = init_mlstm(jax.random.PRNGKey(0), CFG)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32), jnp.float32)

    def loss(p):
        y, _ = mlstm(p, x, CFG, chunk=8)
        return jnp.mean(jnp.square(y))

    g = jax.grad(loss)(params)
    for leaf in jax.tree.leaves(g):
        assert bool(jnp.all(jnp.isfinite(leaf)))


def test_unroll_chunks_matches_scan_mamba():
    cfg_u = dataclasses.replace(CFG, unroll_chunks=True)
    params = init_mamba(jax.random.PRNGKey(0), CFG)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, 32), jnp.float32)
    y1, _ = mamba(params, x, CFG, chunk=4)
    y2, _ = mamba(params, x, cfg_u, chunk=4)
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y2, np.float32), rtol=1e-5, atol=1e-5)
