"""Adaptive Bank Selection: exact ILP solver vs brute force (§V-A)."""
import itertools

import pytest
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.bank_selection import Bank, make_banks, select_banks


def brute_force(banks, in_b, out_b):
    best = None
    n = len(banks)
    for assign in itertools.product((0, 1, 2), repeat=n):
        ins = sum(banks[i].size_bytes for i in range(n) if assign[i] == 1)
        outs = sum(banks[i].size_bytes for i in range(n) if assign[i] == 2)
        if ins >= in_b and outs >= out_b:
            leak = sum(banks[i].leakage_w for i in range(n) if assign[i])
            if best is None or leak < best:
                best = leak
    return best


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(1, 64), min_size=3, max_size=7),
       st.integers(0, 150), st.integers(0, 150))
def test_exact_matches_brute_force(sizes, in_b, out_b):
    banks = [Bank(s, 0.1 * s + 1.0) for s in sizes]
    sel = select_banks(banks, in_b, out_b)
    ref = brute_force(banks, in_b, out_b)
    if ref is None:
        assert not sel.feasible
    else:
        assert sel.feasible
        assert sel.leakage_w == pytest.approx(ref, rel=1e-9)
        # disjointness + coverage invariants
        assert not (set(sel.input_banks) & set(sel.output_banks))
        assert sum(banks[i].size_bytes for i in sel.input_banks) >= in_b
        assert sum(banks[i].size_bytes for i in sel.output_banks) >= out_b


def test_homogeneous_closed_form():
    banks = make_banks([256] * 15, 1e-3, 1e-4)
    sel = select_banks(banks, 700, 300)
    assert sel.feasible
    assert len(sel.input_banks) == 3 and len(sel.output_banks) == 2


def test_hetero_prefers_small_banks():
    banks = make_banks([1024, 64, 32, 16], 1e-3, 0.0)
    sel = select_banks(banks, 20, 10)
    used = set(sel.input_banks) | set(sel.output_banks)
    assert 0 not in used  # never lights the 1 KB bank for 30 bytes
