"""Attention paths: flash custom-VJP vs naive oracle, masks, caches."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.nn.attention import _mask, _softmax_attend, chunked_attention


def naive(q, k, v, causal, prefix_len=0, window=0):
    """q (B,S,Hkv,G,D), k/v (B,S,Hkv,D) oracle."""
    B, S, Hkv, G, D = q.shape
    pos = jnp.arange(S)
    mask = _mask(pos, pos, causal=causal, window=window, prefix_len=prefix_len)
    return _softmax_attend(q, k, v, mask[None], 0.0)


def rand_qkv(key, B=2, S=300, Hkv=2, G=2, D=16):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, Hkv, G, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("causal,prefix", [(True, 0), (False, 0), (True, 7)])
def test_flash_forward_matches_naive(causal, prefix):
    q, k, v = rand_qkv(jax.random.PRNGKey(0))
    out = chunked_attention(q, k, v, causal=causal, prefix_len=prefix,
                            q_chunk=64, kv_chunk=128)
    ref = naive(q, k, v, causal, prefix)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_flash_gradients_match_naive():
    q, k, v = rand_qkv(jax.random.PRNGKey(1), S=200)

    def loss_flash(q, k, v):
        o = chunked_attention(q, k, v, causal=True, q_chunk=64, kv_chunk=64)
        return jnp.sum(jnp.sin(o))

    def loss_naive(q, k, v):
        return jnp.sum(jnp.sin(naive(q, k, v, True)))

    g1 = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_naive, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-3, atol=5e-4)


def test_banded_equals_masked_full():
    q, k, v = rand_qkv(jax.random.PRNGKey(2), S=256)
    w = 48
    out = chunked_attention(q, k, v, causal=True, window=w, q_chunk=64)
    ref = naive(q, k, v, True, window=w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_unrolled_probe_matches_flash():
    q, k, v = rand_qkv(jax.random.PRNGKey(3), S=160)
    a = chunked_attention(q, k, v, causal=True, q_chunk=64, kv_chunk=64)
    b = chunked_attention(q, k, v, causal=True, q_chunk=64, kv_chunk=64,
                          unroll=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-5)


@pytest.mark.parametrize("arch", [
    "qwen3-32b", "gemma-7b", "hymba-1.5b", "deepseek-v2-lite-16b"])
def test_prefill_decode_consistency(arch):
    """Prefill(S) then one decode step must equal forward over S+1 tokens."""
    from repro.nn.model import decode_step, forward, init_params, prefill
    cfg = get_config(arch, smoke=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    B, S = 2, 24
    key = jax.random.PRNGKey(9)
    toks = jax.random.randint(key, (B, S + 1), 0, cfg.vocab).astype(jnp.int32)
    batch = {"tokens": toks[:, :S]}
    prefix = 0
    if cfg.input_mode == "prefix_vlm":
        batch["patch_embeds"] = jax.random.normal(
            key, (B, cfg.prefix_len, cfg.d_model))
        prefix = cfg.prefix_len
    logits_p, caches = prefill(params, batch, cfg, cache_len=S + 8 + prefix)
    logits_d, _ = decode_step(params, toks[:, S], caches,
                              jnp.int32(S + prefix), cfg)
    # Reference: full forward over S+1 tokens, take last.
    batch2 = dict(batch, tokens=toks)
    ref, _, _ = forward(params, batch2, cfg, last_only=True)
    # bf16 end-to-end: prefill (chunked f32-accum) vs decode (cached) paths
    # differ in summation order; tolerance sized to bf16 noise, not bugs.
    np.testing.assert_allclose(np.asarray(logits_d, np.float32),
                               np.asarray(ref[:, 0], np.float32),
                               rtol=0.15, atol=0.25)


# --------------------------------------------------------- hymba ring anchor
# The hymba-1.5b prefill/decode drift (present since the seed, root-caused
# in PR 3) lived in the sliding-window decode path: prefill's make_cache
# emits an exactly-window-sized ring cache, but decode's ring detection
# required the cache to be STRICTLY larger than the window, so it treated
# the ring as a full-length cache — the write index clamped at the last
# slot and the mask admitted the whole buffer.  The boundary now accepts
# `==` (`0 < layer_window <= cache["k"].shape[1]`); these branch isolations
# stay as regression anchors, all plain-passing in f32.
def _hymba_branch_setup(S):
    cfg = get_config("hymba-1.5b", smoke=True)
    x = jax.random.normal(jax.random.PRNGKey(9), (2, S + 1, cfg.d_model),
                          jnp.float32)
    return cfg, x


def test_hymba_mamba_branch_prefill_decode_exact():
    """The ssm half of the hybrid block is NOT the drift: its recurrent
    cache reproduces the full-sequence scan exactly in f32."""
    from repro.nn.ssm import init_mamba, mamba
    cfg, x = _hymba_branch_setup(S=24)
    params = init_mamba(jax.random.PRNGKey(1), cfg)
    y_full, _ = mamba(params, x, cfg)
    _, cache = mamba(params, x[:, :24], cfg, make_cache=True)
    y_dec, _ = mamba(params, x[:, 24:25], cfg, cache=cache)
    np.testing.assert_allclose(np.asarray(y_dec[:, 0], np.float32),
                               np.asarray(y_full[:, 24], np.float32),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("S", [
    # S=8 stays below the window (the ring covers every position); S=24
    # crosses it — the case the off-by-one boundary used to corrupt
    8, 24,
])
def test_hymba_swa_attention_branch_prefill_decode(S):
    """The sliding-window attention branch must be exact both below and at
    prefill lengths >= the window (the exactly-window-sized ring cache)."""
    from repro.nn.attention import attention, init_attention
    cfg, x = _hymba_branch_setup(S)
    window = cfg.sliding_window   # hymba smoke: 16 (layer 1 is SWA)
    params = init_attention(jax.random.PRNGKey(1), cfg)
    params = jax.tree.map(lambda a: a.astype(jnp.float32), params)
    ref, _ = attention(params, x, cfg, layer_window=window)
    _, cache = attention(params, x[:, :S], cfg, layer_window=window,
                         make_cache=True, cache_len=S + 8)
    dec, _ = attention(params, x[:, S:S + 1], cfg, layer_window=window,
                       cache=cache, cache_pos=jnp.int32(S))
    np.testing.assert_allclose(np.asarray(dec[:, 0], np.float32),
                               np.asarray(ref[:, S], np.float32),
                               rtol=1e-5, atol=1e-5)


def test_hymba_global_attention_branch_prefill_decode_exact():
    """Global (unwindowed) attention layers of the same config are exact —
    the drift is confined to the windowed ring-cache path."""
    from repro.nn.attention import attention, init_attention
    cfg, x = _hymba_branch_setup(S=24)
    params = init_attention(jax.random.PRNGKey(1), cfg)
    params = jax.tree.map(lambda a: a.astype(jnp.float32), params)
    ref, _ = attention(params, x, cfg, layer_window=0)
    _, cache = attention(params, x[:, :24], cfg, layer_window=0,
                         make_cache=True, cache_len=32)
    dec, _ = attention(params, x[:, 24:25], cfg, layer_window=0,
                       cache=cache, cache_pos=jnp.int32(24))
    np.testing.assert_allclose(np.asarray(dec[:, 0], np.float32),
                               np.asarray(ref[:, 24], np.float32),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("arch", ["gemma-7b", "qwen3-32b"])
def test_int8_kv_cache_decode_close_to_bf16(arch):
    """§Perf iteration 9: int8 KV cache (paper's INT8 cells applied to the
    KV crossbar) must track the bf16 cache within quantization noise."""
    import dataclasses
    from repro.nn.model import decode_step, init_params, prefill
    cfg8 = dataclasses.replace(get_config(arch, smoke=True),
                               kv_cache_dtype="int8")
    cfg16 = get_config(arch, smoke=True)
    params = init_params(jax.random.PRNGKey(0), cfg16)
    B, S = 2, 24
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, S + 1), 0,
                              cfg16.vocab).astype(jnp.int32)
    batch = {"tokens": toks[:, :S]}
    outs = {}
    for name, cfg in (("int8", cfg8), ("bf16", cfg16)):
        _, cc = prefill(params, batch, cfg, cache_len=S + 8)
        ld, _ = decode_step(params, toks[:, S], cc, jnp.int32(S), cfg)
        outs[name] = np.asarray(ld, np.float32)
    rel = np.max(np.abs(outs["int8"] - outs["bf16"])) / np.max(
        np.abs(outs["bf16"]))
    assert rel < 0.08, rel
