"""Live telemetry plane: streaming quantiles (P2 + sliding window) must
track `np.percentile` (exactly when the stream fits the window, within
tolerance for the lifetime estimator), the burn-rate SLO tracker must
transition breach -> recover deterministically, and the engine-level
plane (windows + SLO + flight recorder + watchdog) must be pure
observation: token-identical to a defaults-off run on the same schedule,
with `health()` snapshots and flight dumps byte-identical across runs
under `VirtualClock`."""
import json
import os

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.nn.model import init_params
from repro.serving import (EngineModel, FlightRecorder, P2Quantile,
                           SchedulerConfig, ServingEngine, SLOConfig,
                           SLOTracker, SlidingWindow, StreamStat, Tracer,
                           TelemetryConfig, VirtualClock, drive_simulated,
                           prometheus_text, validate_events_jsonl,
                           validate_prometheus_text)
from repro.serving.telemetry import dumps_deterministic
from repro.serving.variants import perturbed_variant

MAX_SEQ = 48
CFG = get_config("gemma-7b", smoke=True)
PARAMS_A = init_params(jax.random.PRNGKey(0), CFG)
PARAMS_B = perturbed_variant(PARAMS_A)
N_PAGES = 24
PAGE = 8

# an ITL target far below the virtual step dt: every decode interval is
# over-limit, so the burn windows saturate and the breach fires early.
# (TTFT can NOT force a breach here: under VirtualClock a request that
# prefills the same step it arrives has ttft exactly 0.0, and the
# over-limit indicator is strict.)
TIGHT_ITL = SLOConfig(itl_p95_s=1e-3)
STEP_DT = 0.01


def two_tenant_jobs(seed=0, n=10):
    rng = np.random.default_rng(seed)
    t, jobs = 0.0, []
    for i in range(n):
        t += float(rng.exponential(1.5))
        plen = int(rng.integers(3, 10))
        jobs.append((t, "a" if i % 2 == 0 else "b",
                     rng.integers(1, CFG.vocab, plen).tolist(),
                     int(rng.integers(4, 8))))
    return jobs


def make_engine(*, clock=None, tracer=None, **knobs):
    clock = clock or VirtualClock()
    kv = dict(kv_slots=3, max_seq=MAX_SEQ, kv_layout="paged",
              page_size=PAGE, n_pages=N_PAGES, prefix_cache=True)
    eng = ServingEngine(
        [EngineModel(n, {"a": PARAMS_A, "b": PARAMS_B}[n], CFG, **kv)
         for n in ("a", "b")],
        weight_arena_slots=CFG.n_layers + 2,
        sched=SchedulerConfig(max_prefill_per_step=2),
        clock=clock, tracer=tracer, **knobs)
    return eng, clock


def generated_by_rid(eng):
    return {r.rid: tuple(r.generated) for r in eng.requests.values()}


# ------------------------------------------------------- quantile maths
def _quantile_invariants(samples, window):
    """Shared property body: windowed quantiles are exact `np.percentile`
    over the tail; the lifetime P2 estimate stays within tolerance."""
    stat = StreamStat(window=window)
    for x in samples:
        stat.observe(float(x))
    tail = np.asarray(samples[-window:], dtype=float)
    snap = stat.snapshot()
    assert snap["n"] == len(samples)
    assert snap["last"] == pytest.approx(float(samples[-1]))
    # the sliding window is exact, whatever the stream length
    assert snap["p50"] == pytest.approx(np.percentile(tail, 50))
    assert snap["p95"] == pytest.approx(np.percentile(tail, 95))
    # P2 is exact below 5 samples (it keeps them all); for longer
    # streams it must stay inside the sample range and near the truth
    full = np.asarray(samples, dtype=float)
    if len(samples) < 5:
        assert snap["stream_p50"] == pytest.approx(np.percentile(full, 50))
        assert snap["stream_p95"] == pytest.approx(np.percentile(full, 95))
    else:
        lo, hi = float(full.min()), float(full.max())
        span = max(hi - lo, 1e-12)
        assert lo <= snap["stream_p50"] <= hi
        assert lo <= snap["stream_p95"] <= hi
        assert abs(snap["stream_p50"] - np.percentile(full, 50)) \
            <= 0.25 * span
    assert stat.p50() == snap["p50"]


def test_quantiles_empty_and_small():
    stat = StreamStat(window=8)
    snap = stat.snapshot()
    assert snap["n"] == 0
    for k in ("last", "p50", "p95", "stream_p50", "stream_p95"):
        assert np.isnan(snap[k]), f"{k} must be NaN on an empty stream"
    # exact small-window behaviour, including n < 5 for P2
    for n in (1, 2, 3, 4):
        _quantile_invariants(list(range(n, 0, -1)), window=8)
    # single repeated value: every estimate collapses to it
    stat = StreamStat(window=4)
    for _ in range(32):
        stat.observe(2.5)
    snap = stat.snapshot()
    assert snap["p50"] == snap["p95"] == snap["stream_p95"] == 2.5

    win = SlidingWindow(window=3)
    assert np.isnan(win.quantile(50.0)) and np.isnan(win.last)
    for x in (5.0, 1.0, 3.0, 9.0):
        win.observe(x)
    assert len(win) == 3 and win.total == 4          # ring evicted the 5.0
    assert win.quantile(50.0) == pytest.approx(3.0)

    with pytest.raises(ValueError):
        SlidingWindow(0)
    with pytest.raises(ValueError):
        P2Quantile(0.0)


def test_p2_converges_on_large_stream():
    """Lifetime P2 p95 lands within ~2% of np.percentile on a 5000-sample
    lognormal stream — the regime the 5-marker estimator is built for."""
    rng = np.random.default_rng(0)
    xs = rng.lognormal(0.0, 0.5, size=5000)
    q = P2Quantile(0.95)
    for x in xs:
        q.observe(float(x))
    truth = float(np.percentile(xs, 95))
    assert q.value == pytest.approx(truth, rel=0.02)


def test_windowed_quantiles_property():
    """Hypothesis sweep of `_quantile_invariants` over random streams and
    window sizes."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=60, deadline=None)
    @given(xs=st.lists(st.floats(-1e6, 1e6, allow_nan=False, width=32),
                       min_size=1, max_size=200),
           window=st.integers(1, 64))
    def prop(xs, window):
        _quantile_invariants(xs, window)

    prop()


def test_windowed_quantiles_manual_trials():
    """Deterministic fallback for environments without hypothesis: the
    same invariants over a seeded random sweep."""
    rng = np.random.default_rng(7)
    for _ in range(40):
        n = int(rng.integers(1, 200))
        window = int(rng.integers(1, 65))
        xs = rng.normal(0.0, float(rng.uniform(0.1, 100.0)), n).tolist()
        _quantile_invariants(xs, window)


# ------------------------------------------------------------ SLO maths
def test_slo_tracker_breach_and_recover():
    cfg = SLOConfig(ttft_p95_s=0.1, itl_p95_s=0.05,
                    short_window=4, long_window=8, min_samples=3)
    trk = SLOTracker(cfg)
    assert not trk.any_breached and trk.evaluate() == []

    # two bad ttft samples: under min_samples, no transition yet
    trk.observe("ttft_p95", 0.5)
    trk.observe("ttft_p95", 0.5)
    assert trk.evaluate() == []
    trk.observe("ttft_p95", 0.5)
    (kind, name, s, lo), = trk.evaluate()
    assert (kind, name) == ("slo_breach", "ttft_p95")
    assert s == 1.0 and lo == 1.0
    assert trk.any_breached
    assert trk.evaluate() == []               # transitions, not levels

    # good samples wash the short window first, then the long one
    for _ in range(8):
        trk.observe("ttft_p95", 0.01)
    (kind, name, s, lo), = trk.evaluate()
    assert (kind, name) == ("slo_recover", "ttft_p95")
    assert not trk.any_breached

    # untracked names are ignored; itl target untouched throughout
    trk.observe("nonsense", 99.0)
    st = trk.status()
    assert set(st) == {"ttft_p95", "itl_p95"}
    assert st["itl_p95"]["samples"] == 0
    assert st["ttft_p95"]["breached"] == 0
    assert SLOConfig().targets() == {}        # all-zero config: no targets


# ----------------------------------------------- engine: pure observation
def _drive(jobs, drive_kwargs=None, **knobs):
    # the tracer must share the virtual clock: trace timestamps and the
    # per-step component spans land in flight-recorder ring entries, so a
    # wall-clocked tracer would break dump byte-determinism
    clock = VirtualClock()
    eng, clock = make_engine(clock=clock, tracer=Tracer(clock=clock),
                             **knobs)
    drive_simulated(eng, clock, jobs, dt=STEP_DT, **(drive_kwargs or {}))
    return eng


def test_telemetry_token_identical_and_deterministic(tmp_path):
    """Everything on (windows + tight ITL SLO + recorder + watchdog) must
    decode the exact tokens of a defaults-off run, and two identical
    on-runs must produce byte-identical health snapshots, flight dumps
    and event logs even from different output directories."""
    jobs = two_tenant_jobs()
    plain = _drive(jobs)

    def run(d):
        os.makedirs(d, exist_ok=True)
        sampled = []
        eng = _drive(
            jobs,
            # sample the router probe mid-flight every 5 driven steps:
            # the sampled sequence must be byte-identical across runs too
            drive_kwargs=dict(health_every=5,
                              on_health=lambda h: sampled.append(h)),
            telemetry=TelemetryConfig(
                window=16, slo=TIGHT_ITL,
                events_path=os.path.join(d, "events.jsonl")),
            recorder=FlightRecorder(32, out_dir=str(d)),
            stall_timeout_s=300.0)
        eng.telemetry.close()
        return eng, sampled

    a, sampled_a = run(tmp_path / "a")
    b, sampled_b = run(tmp_path / "b")
    assert sampled_a, "health_every must sample the probe mid-run"
    assert [dumps_deterministic(h) for h in sampled_a] == \
        [dumps_deterministic(h) for h in sampled_b]

    assert generated_by_rid(a) == generated_by_rid(plain), \
        "telemetry plane changed decoded tokens"
    assert generated_by_rid(a) == generated_by_rid(b)

    # health snapshots: byte-identical canonical JSON
    ha, hb = a.health(), b.health()
    assert dumps_deterministic(ha) == dumps_deterministic(hb)
    assert ha["ok"] is False                  # tight ITL SLO is burning
    assert ha["slo"]["itl_p95"]["breached"] == 1
    assert ha["kv_total_pages"] == 2 * N_PAGES
    assert ha["queue_depth"] == 0 and ha["n_active"] == 0

    # the breach left exactly the same dump(s) in both directories
    assert a.recorder.dumps, "tight ITL SLO must leave a flight dump"
    assert [os.path.basename(p) for p in a.recorder.dumps] == \
        [os.path.basename(p) for p in b.recorder.dumps]
    for pa, pb in zip(a.recorder.dumps, b.recorder.dumps):
        with open(pa, "rb") as f:
            da = f.read()
        with open(pb, "rb") as f:
            db = f.read()
        assert da == db, f"{os.path.basename(pa)} differs across runs"
    dump = json.loads(da)
    assert dump["reason"] == "slo_breach"
    assert dump["entries"], "dump must carry the step ring"
    assert dump["n_entries"] <= 32

    # events JSONL: byte-identical and schema-valid
    ea = (tmp_path / "a" / "events.jsonl").read_bytes()
    assert ea == (tmp_path / "b" / "events.jsonl").read_bytes()
    assert validate_events_jsonl(ea.decode()) == []

    # windowed view saw every finish, globally and per tenant
    snap = a.telemetry.snapshot()
    assert snap["finishes"] == len(jobs)
    assert set(snap["tenants"]) == {"a", "b"}
    assert snap["global"]["itl_max_s"]["n"] == len(jobs)

    # Prometheus exposition from the live registry parses cleanly
    prom = prometheus_text(a.metrics.registry, a.telemetry)
    assert validate_prometheus_text(prom) == []
    assert 'repro_slo_breached{target="itl_p95"} 1' in prom
    assert "repro_engine_tokens_generated_total" in prom


def test_recorder_ring_and_fault_trigger(tmp_path):
    """A seeded fault run: every retirement dumps the ring (up to
    max_dumps), the ring never exceeds its bound, and the run still
    finishes every request."""
    eng = _drive(
        two_tenant_jobs(seed=1, n=8),
        fault_rate=0.02, fault_seed=11,
        recorder=FlightRecorder(4, out_dir=str(tmp_path), max_dumps=2))
    s = eng.metrics.summary(0.0)
    assert s["requests_finished"] == 8
    h = eng.health()
    retired = int(h["slots_retired"] + h["pages_retired"])
    assert retired > 0, "seeded 2% fault run must retire something"
    reasons = [t["reason"] for t in eng.recorder.triggers]
    assert reasons.count("unit_retired") == \
        len([t for t in eng.recorder.triggers]), reasons
    assert len(eng.recorder.dumps) == min(len(reasons), 2)  # max_dumps cap
    assert len(eng.recorder) <= 4
    doc = json.loads(open(eng.recorder.dumps[0]).read())
    assert doc["reason"] == "unit_retired"
    assert doc["attrs"]["retired_total"] >= 1
    # ring entries carry record + health + the step's trace events
    entry = doc["entries"][-1]
    assert {"step", "record", "health", "events"} <= set(entry)


def test_watchdog_stall_dump(tmp_path):
    """The watchdog path: a suspected stall emits the `stall_suspected`
    instant and a flight dump, and the fire is recorded on the engine's
    watchdog."""
    clock = VirtualClock()
    eng, clock = make_engine(
        clock=clock, tracer=Tracer(clock=clock),
        recorder=FlightRecorder(8, out_dir=str(tmp_path)),
        stall_timeout_s=300.0)
    drive_simulated(eng, clock, two_tenant_jobs(n=4), dt=STEP_DT)
    assert eng.watchdog is not None and eng.watchdog.fires == 0
    assert not eng.recorder.dumps            # healthy run: no dumps

    eng._on_stall(7)                         # what the timer thread runs
    assert [os.path.basename(p) for p in eng.recorder.dumps] == \
        ["flight-000-stall_suspected.json"]
    names = [e["name"] for e in eng.tracer.events
             if e.get("ph") == "i"]
    assert "stall_suspected" in names


def test_health_without_telemetry():
    """`health()` is a router probe even with every knob off: ok=True,
    capacity keys present, no `slo`/`windows` sections."""
    eng = _drive(two_tenant_jobs(n=4))
    h = eng.health()
    assert h["ok"] is True
    assert "slo" not in h and "windows" not in h
    assert h["kv_free_pages"] <= h["kv_total_pages"]
    assert h["weight_slots_total"] == CFG.n_layers + 2
    assert h["slots_retired"] == 0 and h["pages_retired"] == 0
    json.dumps(h)                            # snapshot is pure JSON


def test_per_tenant_summary_lines():
    eng = _drive(two_tenant_jobs())
    s = eng.metrics.summary(0.0)
    for name in ("a", "b"):
        assert s[f"tenant.{name}.requests"] == 5
        assert s[f"tenant.{name}.tokens_generated"] > 0
    from repro.serving.metrics import format_summary
    text = format_summary(s)
    assert "tenant a: 5 requests" in text
    assert "tenant b: 5 requests" in text


def test_telemetry_junit_properties(record_property):
    """Headline counters for the CI job summary."""
    jobs = two_tenant_jobs()
    eng = _drive(jobs, telemetry=TelemetryConfig(window=16, slo=TIGHT_ITL))
    snap = eng.telemetry.snapshot()
    record_property("telemetry_finishes", int(snap["finishes"]))
    record_property("telemetry_tenants", len(snap["tenants"]))
    record_property("slo_itl_breached",
                    int(snap["slo"]["itl_p95"]["breached"]))
    record_property("itl_p95_window_ms",
                    round(snap["global"]["itl_max_s"]["p95"] * 1e3, 3))
    assert snap["finishes"] == len(jobs)
