"""Wear & write-energy telemetry: per-install cell flips must conserve
across the WearMap / ResidencyStats / metrics-histogram views, KV page
writes must match the actual device scatter + COW calls one for one, the
Gini summaries must stay in bounds on degenerate planes, the wear JSON
export must be byte-deterministic under a VirtualClock, and the bench
regression gate must flag direction-aware tolerance breaches."""
import importlib.util
import json
import os

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.nn.model import init_params
from repro.serving import (EngineModel, SchedulerConfig, ServingEngine,
                           Tracer, VirtualClock, WearPlane, drive_simulated,
                           gini_coefficient)
from repro.serving.variants import perturbed_variant
from repro.streaming.delta import _cells, flip_counts

MAX_SEQ = 48
CFG = get_config("gemma-7b", smoke=True)
PARAMS_A = init_params(jax.random.PRNGKey(0), CFG)
PARAMS_B = perturbed_variant(PARAMS_A)   # co-hosted fine-tune regime
N_PAGES = 24
PAGE = 8


def two_tenant_jobs(seed=0, n=10):
    rng = np.random.default_rng(seed)
    t, jobs = 0.0, []
    for i in range(n):
        t += float(rng.exponential(1.5))
        plen = int(rng.integers(3, 10))
        jobs.append((t, "a" if i % 2 == 0 else "b",
                     rng.integers(1, CFG.vocab, plen).tolist(),
                     int(rng.integers(4, 8))))
    return jobs


def make_engine(*, reuse=True, paged=False, prefix_cache=False,
                clock=None, tracer=None, names=("a", "b")):
    clock = clock or VirtualClock()
    if paged:
        kv = dict(kv_slots=3, max_seq=MAX_SEQ, kv_layout="paged",
                  page_size=PAGE, n_pages=N_PAGES,
                  prefix_cache=prefix_cache)
    else:
        kv = dict(kv_slots=3, max_seq=MAX_SEQ)
    params = {"a": PARAMS_A, "b": PARAMS_B}
    eng = ServingEngine(
        [EngineModel(n, params[n], CFG, **kv) for n in names],
        weight_arena_slots=CFG.n_layers + 1,   # forces tenant swaps
        reuse=reuse,
        sched=SchedulerConfig(max_prefill_per_step=2),
        clock=clock, tracer=tracer)
    return eng, clock


# ------------------------------------------------------- flip semantics
def test_flip_counts_semantics():
    rng = np.random.default_rng(0)
    old = rng.integers(0, 256, 64).astype(np.uint8)
    new = rng.integers(0, 256, 64).astype(np.uint8)

    # identity install programs nothing under equal-skip, everything cold
    assert flip_counts(old, old) == (0, 0)
    cells, pulses = flip_counts(old, old, skip_equal=False)
    assert cells == old.size * 4 and pulses == old.size * 4

    # cold install (erased region): every nonzero cell flips, pulses = Σ|Δ|
    cn = _cells(new)
    assert flip_counts(None, new) == (int(np.count_nonzero(cn)),
                                      int(cn.sum()))

    # delta install: equal-skip flips bounded by the raw rewrite, and
    # per-cell pulses never exceed the no-skip programmer's
    f_on, p_on = flip_counts(old, new)
    f_off, p_off = flip_counts(old, new, skip_equal=False)
    assert f_on <= f_off == new.size * 4
    assert p_on <= p_off

    # a new longer than old programs its tail from erased
    longer = np.concatenate([new, rng.integers(0, 256, 8).astype(np.uint8)])
    f_tail, p_tail = flip_counts(old, longer)
    f_head, p_head = flip_counts(old, new)
    f_cold, p_cold = flip_counts(None, longer[64:])
    assert (f_tail, p_tail) == (f_head + f_cold, p_head + p_cold)


# ------------------------------------------------------- gini bounds
def test_gini_bounds_and_degenerate():
    assert gini_coefficient([]) == 0.0
    assert gini_coefficient([7]) == 0.0
    assert gini_coefficient([0, 0, 0]) == 0.0
    assert gini_coefficient([5, 5, 5, 5]) == pytest.approx(0.0)
    # one-hot over n locations is the maximal spread: (n-1)/n
    for n in (2, 5, 32):
        one_hot = [0] * (n - 1) + [9]
        assert gini_coefficient(one_hot) == pytest.approx((n - 1) / n)
    rng = np.random.default_rng(3)
    for _ in range(10):
        g = gini_coefficient(rng.integers(0, 100, 50))
        assert 0.0 <= g <= 1.0

    # degenerate single-slot plane: every summary well-defined, gini 0
    plane = WearPlane("solo", 1)
    plane.record(0, flips=10, pulses=25)
    assert plane.gini("writes") == 0.0
    assert plane.summary()["gini_flips"] == 0.0
    assert plane.hottest() == [(0, 1)]
    json.dumps(plane.as_json())

    with pytest.raises(ValueError):
        WearPlane("empty", 0)
    with pytest.raises(KeyError):
        plane.counts("joules")


# ------------------------------------- flip conservation & reuse energy
def test_flip_conservation_and_reuse_energy():
    jobs = two_tenant_jobs()
    arms = {}
    for reuse in (False, True):
        eng, clock = make_engine(reuse=reuse)
        summary = drive_simulated(eng, clock, jobs, max_steps=10_000)
        arms[reuse] = (eng, summary)

    eng, summary = arms[True]
    stats = eng.residency.stats
    plane = eng.wear.plane("weight")
    assert stats.installs > 0

    # every flip/pulse recorded in _install lands in exactly one slot of
    # the wear plane, one histogram sample, and the stats totals
    assert int(plane.flips.sum()) == stats.cell_flips
    assert int(plane.pulses.sum()) == stats.write_pulses
    assert int(plane.writes.sum()) == stats.installs
    by_group = plane.by_group
    assert sum(v[1] for v in by_group.values()) == stats.cell_flips
    assert sum(v[0] for v in by_group.values()) == stats.installs
    hist = eng.metrics.registry.histogram("install_cell_flips")
    assert hist.count == stats.installs
    assert int(hist.sum) == stats.cell_flips

    # summary wiring: energy is exactly pulses × the model's pulse joules
    assert summary["install_cell_flips"] == float(stats.cell_flips)
    assert summary["install_energy_j"] == pytest.approx(
        stats.write_pulses * eng.energy_model.write_pulse_j)
    assert 0.0 <= summary["wear_gini_weight"] <= 1.0
    assert "wear_gini_kv" not in summary   # slot arenas: no KV write plane

    # same virtual-clock schedule across arms (installs are instant and
    # decode runs the full-precision params), so the equal-skip programmer
    # must spend strictly less write energy than the rewrite-everything one
    eng_off, s_off = arms[False]
    assert s_off["steps"] == summary["steps"]
    assert {r.rid: r.generated for r in eng_off.requests.values()} == \
        {r.rid: r.generated for r in eng.requests.values()}
    assert summary["install_energy_j"] < s_off["install_energy_j"]


# ----------------------------------------------- KV page write accounting
def test_kv_page_writes_match_scatter_cow_events():
    eng, clock = make_engine(paged=True, prefix_cache=True, names=("a",))
    arena = eng.arenas["a"]
    calls = {"write": 0, "copy": 0}
    orig_write, orig_copy = arena._write, arena._copy

    def counting_write(*a):
        calls["write"] += 1
        return orig_write(*a)

    def counting_copy(*a):
        calls["copy"] += 1
        return orig_copy(*a)

    arena._write, arena._copy = counting_write, counting_copy

    # two identical 20-token prompts arriving together: the second shares
    # all 3 pages of the first (exact-tuple tail edge), then both decode
    # into the shared partial block at pos 20 — forcing exactly one COW
    rng = np.random.default_rng(5)
    twin = rng.integers(1, CFG.vocab, 20).tolist()
    jobs = [(0.0, "a", twin, 6), (0.0, "a", list(twin), 6)]
    for i in range(4):
        jobs.append((2.0 + i, "a", rng.integers(1, CFG.vocab, 7).tolist(),
                     int(rng.integers(4, 8))))
    summary = drive_simulated(eng, clock, jobs, max_steps=10_000)
    assert summary["requests_finished"] == len(jobs)

    # every accounted page write is one real device scatter or COW copy
    assert arena.kv_page_writes == calls["write"] + calls["copy"]
    assert calls["copy"] == arena.allocator.cow_copies >= 1
    assert arena.kv_page_writes_avoided >= 3   # the twin's shared pages

    plane = eng.wear.plane("kv:a")
    assert plane.first == 1                    # scratch page 0 untracked
    assert int(plane.writes.sum()) == arena.kv_page_writes
    assert summary["kv_page_writes"] == float(arena.kv_page_writes)
    assert summary["kv_page_writes_avoided"] == float(
        arena.kv_page_writes_avoided)
    assert summary["kv_write_energy_j"] == pytest.approx(
        eng.energy_model.kv_write_j(arena.kv_bytes_written))


# --------------------------------------------------- deterministic export
def test_wear_json_deterministic():
    docs = []
    for _ in range(2):
        eng, clock = make_engine(paged=True, prefix_cache=True)
        drive_simulated(eng, clock, two_tenant_jobs(seed=2), max_steps=10_000)
        assert set(eng.wear.planes) == {"weight", "kv:a", "kv:b"}
        docs.append(json.dumps(eng.wear.as_json(), sort_keys=True))
    assert docs[0] == docs[1]


# -------------------------------------------------------- trace counters
def test_trace_counter_tracks():
    clock = VirtualClock()
    tracer = Tracer(clock=clock)
    eng, _ = make_engine(paged=True, tracer=tracer, clock=clock)
    drive_simulated(eng, clock, two_tenant_jobs(seed=4, n=6),
                    max_steps=10_000)
    counters = {e["name"] for e in tracer.chrome_trace_doc()["traceEvents"]
                if e.get("ph") == "C"}
    assert {"install_flips", "wear_gini_weight", "kv_free_pages",
            "install_queue_depth"} <= counters


# ----------------------------------------------------- junit properties
def test_wear_junit_properties(record_property):
    jobs = two_tenant_jobs(seed=6, n=8)
    arms = {}
    for reuse in (False, True):
        eng, clock = make_engine(reuse=reuse, paged=True, prefix_cache=True)
        arms[reuse] = drive_simulated(eng, clock, jobs, max_steps=10_000)
    on, off = arms[True], arms[False]
    assert on["install_energy_j"] < off["install_energy_j"]
    record_property("install_energy_mj_on", on["install_energy_j"] * 1e3)
    record_property("install_energy_mj_off", off["install_energy_j"] * 1e3)
    record_property("install_cell_flips", on["install_cell_flips"])
    record_property("kv_write_energy_mj", on["kv_write_energy_j"] * 1e3)
    record_property("kv_page_writes", on["kv_page_writes"])
    record_property("wear_gini_weight", on["wear_gini_weight"])
    record_property("wear_gini_kv", on["wear_gini_kv"])


# ------------------------------------------------------ regression gate
def _load_gate():
    path = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                        "check_regression.py")
    spec = importlib.util.spec_from_file_location("check_regression", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _doc(**wear):
    return {"parts": {"wear": wear}}


def test_regression_gate_directions(tmp_path):
    gate = _load_gate()
    base = _doc(install_energy_j_on=1.0, kv_page_writes=10.0,
                wear_gini_weight=0.4)
    base["parts"]["overlap"] = {"stall_steps_overlap": 4.0,
                                "hidden_bytes": 100.0}

    def run(fresh):
        bp, fp = tmp_path / "base.json", tmp_path / "fresh.json"
        bp.write_text(json.dumps(base))
        fp.write_text(json.dumps(fresh))
        return gate.main(["--baseline", str(bp), "--fresh", str(fp)])

    # identical and better-on-every-axis both pass
    assert run(base) == 0
    better = _doc(install_energy_j_on=0.5, kv_page_writes=8.0,
                  wear_gini_weight=0.3)
    better["parts"]["overlap"] = {"stall_steps_overlap": 2.0,
                                  "hidden_bytes": 150.0}
    assert run(better) == 0

    # within-tolerance drift passes; past-tolerance fails, each direction
    drift = json.loads(json.dumps(base))
    drift["parts"]["wear"]["install_energy_j_on"] = 1.05   # 10% tol
    assert run(drift) == 0
    worse_lower = json.loads(json.dumps(base))
    worse_lower["parts"]["wear"]["install_energy_j_on"] = 1.2
    assert run(worse_lower) == 1
    worse_higher = json.loads(json.dumps(base))
    worse_higher["parts"]["overlap"]["hidden_bytes"] = 80.0  # higher=better
    assert run(worse_higher) == 1
    worse_exact = json.loads(json.dumps(base))
    worse_exact["parts"]["overlap"]["stall_steps_overlap"] = 5.0  # 0% tol
    assert run(worse_exact) == 1

    # --warn-only reports but exits 0; missing metrics are skipped, a
    # fully disjoint doc is an input error
    bp, fp = tmp_path / "b2.json", tmp_path / "f2.json"
    bp.write_text(json.dumps(base))
    fp.write_text(json.dumps(worse_lower))
    assert gate.main(["--baseline", str(bp), "--fresh", str(fp),
                      "--warn-only"]) == 0
    fp.write_text(json.dumps({"parts": {"layout": {"x": 1.0}}}))
    assert gate.main(["--baseline", str(bp), "--fresh", str(fp)]) == 2

    rows = gate.compare(base["parts"], worse_lower["parts"])
    bad = [r for r in rows if r["regressed"]]
    assert [(r["part"], r["metric"]) for r in bad] == \
        [("wear", "install_energy_j_on")]


def test_regression_gate_on_committed_trajectory():
    gate = _load_gate()
    path = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_serving.json")
    with open(path) as f:
        parts = json.load(f)["parts"]
    rows = gate.compare(parts, parts)
    assert rows, "committed trajectory shares no gated metrics with SPECS"
    assert not any(r["regressed"] for r in rows)
    gated_parts = {r["part"] for r in rows}
    assert "wear" in gated_parts, \
        "committed BENCH_serving.json is missing the wear part"
