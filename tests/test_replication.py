"""Algorithm 1 (Adaptive Weight Replication) invariants."""
from repro.core.replication import LayerCost, plan_writes


def mk(rows, cycles, dma=1000.0, maxrep=64):
    return LayerCost(base_rows=rows, compute_cycles=cycles,
                     max_replication=maxrep, write_dma_cycles=dma)


WL = lambda idx: 768_000.0


def total_rows(items, costs):
    return sum(i.rows for i in items)


def test_partial_write_when_too_small():
    costs = [mk(100, 10_000)]
    items = plan_writes(40, 0, costs, WL)
    assert len(items) == 1 and items[0].fraction == 0.4
    assert items[0].replication == 1 and items[0].rows == 40


def test_single_layer_replicates_into_free_rows():
    costs = [mk(10, 5_000_000), mk(1000, 10_000)]
    items = plan_writes(90, 0, costs, WL)
    assert items[0].layer_idx == 0
    # replicates until compute (5e6/f) drops under WL (768k) → f = 7, not 9:
    # past the WL inflection more replicas only cost writes (paper §V-B).
    assert items[0].replication == 7


def test_rows_never_exceed_budget():
    costs = [mk(7, 900_000), mk(11, 1_200_000), mk(5, 50_000), mk(9, 2_000_000)]
    for free in (10, 23, 40, 100, 300):
        items = plan_writes(free, 0, costs, WL)
        assert total_rows(items, costs) <= free


def test_fc_like_layers_not_replicated():
    """BERT regime: compute ≪ WL → zero replication (paper Fig 14)."""
    costs = [mk(37, 12_288, dma=40_000) for _ in range(10)]
    items = plan_writes(576, 0, costs, WL)
    assert all(i.replication == 1 for i in items)


def test_compute_bound_layers_do_replicate():
    costs = [mk(2, 5_000_000) for _ in range(4)] + [mk(2, 1_000)]
    items = plan_writes(576, 0, costs, WL)
    assert any(i.replication > 1 for i in items)


def test_tail_wave_gated_by_dma_cost():
    # no following writes: replicate while marginal saving > replica DMA
    costs = [mk(10, 1_000, dma=100_000)]
    items = plan_writes(576, 0, costs, lambda i: 0.0)
    assert items[0].replication == 1  # saving 500 < dma 100k

    costs = [mk(10, 10_000_000, dma=1_000)]
    items = plan_writes(576, 0, costs, lambda i: 0.0)
    assert items[0].replication > 1


def test_ordering_consecutive_from_head():
    costs = [mk(50, 100_000) for _ in range(8)]
    items = plan_writes(576, 2, costs, WL)
    idxs = [i.layer_idx for i in items]
    assert idxs == sorted(idxs) and idxs[0] == 2
