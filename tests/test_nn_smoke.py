"""Per-architecture smoke tests (required): reduced config of the same
family, one forward/train step on CPU, asserting shapes and no NaNs."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, supported_shapes
from repro.data.pipeline import DataConfig, make_batch
from repro.nn.model import init_params, lm_loss
from repro.optim.adamw import adamw_init, adamw_update


def _batch(cfg, B=2, S=16, step=0):
    data = DataConfig(seq_len=S, global_batch=B)
    return {k: jnp.asarray(v) for k, v in make_batch(cfg, data, step).items()}


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch):
    cfg = get_config(arch, smoke=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)

    loss, metrics = lm_loss(params, batch, cfg)
    assert loss.shape == ()
    assert np.isfinite(float(loss))
    # loss should be near log(vocab) at init (uniform predictions)
    assert 0.2 * np.log(cfg.vocab) < float(loss) < 3.0 * np.log(cfg.vocab)

    grads = jax.grad(lambda p: lm_loss(p, batch, cfg)[0])(params)
    opt = adamw_init(params)
    new_params, opt, om = adamw_update(params, grads, opt, jnp.float32(1e-3))
    assert np.isfinite(float(om["grad_norm"])) and float(om["grad_norm"]) > 0
    # parameters actually moved
    moved = any(
        bool(jnp.any(a != b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params)))
    assert moved


@pytest.mark.parametrize("arch", ARCHS)
def test_one_step_reduces_loss_direction(arch):
    """Two SGD-ish steps on the same batch should not increase loss."""
    cfg = get_config(arch, smoke=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    opt = adamw_init(params)
    l0 = float(lm_loss(params, batch, cfg)[0])
    for _ in range(2):
        grads = jax.grad(lambda p: lm_loss(p, batch, cfg)[0])(params)
        params, opt, _ = adamw_update(params, grads, opt, jnp.float32(3e-3))
    l1 = float(lm_loss(params, batch, cfg)[0])
    assert l1 < l0 + 0.05, (l0, l1)


def test_supported_shapes_policy():
    assert "decode_32k" not in supported_shapes("hubert-xlarge")
    assert "long_500k" in supported_shapes("xlstm-350m")
    assert "long_500k" in supported_shapes("hymba-1.5b")
    assert "long_500k" not in supported_shapes("qwen3-32b")
    total = sum(len(supported_shapes(a)) for a in ARCHS)
    assert total == 31  # 40 − 8 long-skips − 1 hubert decode
