"""ARAS scheduler + event simulator: structural invariants and paper claims."""
import numpy as np
import pytest

from repro.core.resources import AcceleratorConfig
from repro.core.scheduler import build_schedule, validate_schedule
from repro.models.paper_nets import build_net, synth_layer_codes
from repro.sim.aras import ArasSimConfig, segment_graph, simulate_aras, upper_bound_cycles
from repro.sim.tpu import simulate_tpu


@pytest.fixture(scope="module")
def resnet():
    g = build_net("resnet50")
    codes = synth_layer_codes(g, max_samples=50_000)
    return g, codes


@pytest.fixture(scope="module")
def bert():
    g = build_net("bert_base")
    codes = synth_layer_codes(g, max_samples=50_000)
    return g, codes


def test_segments_fit_pool(resnet):
    g, _ = resnet
    accel = AcceleratorConfig()
    for s in segment_graph(g, accel):
        assert s.base_rows <= accel.total_rows


def test_schedule_is_valid(resnet):
    g, codes = resnet
    sched = build_schedule(g, codes, ArasSimConfig.variant("BRW"))
    errors = validate_schedule(sched)
    assert errors == [], errors


def test_overlap_beats_naive(resnet):
    g, codes = resnet
    naive = simulate_aras(g, codes, ArasSimConfig.variant("naive"))
    base = simulate_aras(g, codes, ArasSimConfig.variant("baseline"))
    assert base.makespan_s < naive.makespan_s


def test_replication_speeds_up_cnn_not_bert(resnet, bert):
    for (g, codes), expect_gain in ((resnet, True), (bert, False)):
        base = simulate_aras(g, codes, ArasSimConfig.variant("baseline"))
        br = simulate_aras(g, codes, ArasSimConfig.variant("BR"))
        if expect_gain:
            assert br.makespan_s < base.makespan_s * 0.75
        else:
            assert br.makespan_s == pytest.approx(base.makespan_s, rel=1e-6)


def test_weight_reuse_cuts_pulses_not_time(resnet):
    g, codes = resnet
    br = simulate_aras(g, codes, ArasSimConfig.variant("BR"))
    brw = simulate_aras(g, codes, ArasSimConfig.variant("BRW"))
    assert brw.total_pulses < br.total_pulses * 0.95
    assert brw.makespan_s == pytest.approx(br.makespan_s, rel=1e-6)


def test_upper_bound_is_a_bound(resnet, bert):
    for g, codes in (resnet, bert):
        ub = upper_bound_cycles(g, AcceleratorConfig()) / 1e9
        for v in ("baseline", "BRW"):
            r = simulate_aras(g, codes, ArasSimConfig.variant(v))
            assert r.makespan_s >= ub * 0.999


def test_determinism(resnet):
    g, codes = resnet
    a = simulate_aras(g, codes, ArasSimConfig.variant("BRW"))
    b = simulate_aras(g, codes, ArasSimConfig.variant("BRW"))
    assert a.makespan_s == b.makespan_s
    assert a.total_pulses == b.total_pulses


def test_energy_breakdown_positive(bert):
    g, codes = bert
    r = simulate_aras(g, codes, ArasSimConfig.variant("BRW"))
    for k, v in r.energy.items():
        assert v >= 0.0, k
    assert r.energy["total"] == pytest.approx(
        sum(v for k, v in r.energy.items() if k != "total"))


def test_paper_claim_bands(resnet, bert):
    """Reproduction bands: ResNet speedup ≈ 2.2× (paper), BERT ≈ 1.0×;
    BRW pulse ratio ≈ 0.83; energy ratio ≈ 0.72 (±0.12 tolerance bands)."""
    g, codes = resnet
    base = simulate_aras(g, codes, ArasSimConfig.variant("baseline"))
    brw = simulate_aras(g, codes, ArasSimConfig.variant("BRW"))
    speedup = base.makespan_s / brw.makespan_s
    assert 1.7 <= speedup <= 2.7
    assert 0.70 <= brw.total_pulses / base.total_pulses <= 0.95
    assert 0.6 <= brw.total_energy_j / base.total_energy_j <= 0.88

    g, codes = bert
    base = simulate_aras(g, codes, ArasSimConfig.variant("baseline"))
    brw = simulate_aras(g, codes, ArasSimConfig.variant("BRW"))
    assert base.makespan_s / brw.makespan_s == pytest.approx(1.0, abs=0.05)


def test_tpu_comparison_direction(resnet):
    g, codes = resnet
    brw = simulate_aras(g, codes, ArasSimConfig.variant("BRW"))
    tpu = simulate_tpu(g)
    assert tpu.makespan_s / brw.makespan_s > 1.0   # paper: ARAS faster
    assert brw.total_energy_j / tpu.total_energy_j < 1.0  # and cheaper
