"""Stuck-at fault injection & wear-aware placement (Hamun policy half):
the fault stream must replay deterministically for a fixed seed, faulted
runs must stay token-equivalent to fault-free ones with every retired unit
permanently out of service (allocator conservation holds with retired
pages excluded), wear-aware placement must strictly flatten the weight
plane's write spread on a token-identical schedule, and with both knobs
off the engine must reproduce the default engine's run byte-for-byte."""
import json

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.nn.model import init_params
from repro.serving import (EngineModel, FaultModel, SchedulerConfig,
                           ServingEngine, Tracer, VirtualClock,
                           drive_simulated)
from repro.serving.variants import perturbed_variant

MAX_SEQ = 48
CFG = get_config("gemma-7b", smoke=True)
PARAMS_A = init_params(jax.random.PRNGKey(0), CFG)
PARAMS_B = perturbed_variant(PARAMS_A)
N_PAGES = 24
PAGE = 8


def two_tenant_jobs(seed=0, n=10):
    rng = np.random.default_rng(seed)
    t, jobs = 0.0, []
    for i in range(n):
        t += float(rng.exponential(1.5))
        plen = int(rng.integers(3, 10))
        jobs.append((t, "a" if i % 2 == 0 else "b",
                     rng.integers(1, CFG.vocab, plen).tolist(),
                     int(rng.integers(4, 8))))
    return jobs


def make_engine(*, paged=True, prefix_cache=True, clock=None, tracer=None,
                names=("a", "b"), spare_slots=2, **knobs):
    clock = clock or VirtualClock()
    if paged:
        kv = dict(kv_slots=3, max_seq=MAX_SEQ, kv_layout="paged",
                  page_size=PAGE, n_pages=N_PAGES,
                  prefix_cache=prefix_cache)
    else:
        kv = dict(kv_slots=3, max_seq=MAX_SEQ)
    params = {"a": PARAMS_A, "b": PARAMS_B}
    eng = ServingEngine(
        [EngineModel(n, params[n], CFG, **kv) for n in names],
        # spare slots beyond one tenant: room to both force swaps and
        # survive a couple of weight-slot retirements
        weight_arena_slots=CFG.n_layers + spare_slots,
        sched=SchedulerConfig(max_prefill_per_step=2),
        clock=clock, tracer=tracer, **knobs)
    return eng, clock


def generated_by_rid(eng):
    return {r.rid: tuple(r.generated) for r in eng.requests.values()}


# --------------------------------------------------------- fault model
def test_fault_model_deterministic_and_seeded():
    a = FaultModel(0.1, seed=7)
    b = FaultModel(0.1, seed=7)
    seq_a = [a.check("kv", u) for u in (1, 2, 3) * 40]
    seq_b = [b.check("kv", u) for u in (1, 2, 3) * 40]
    assert seq_a == seq_b                       # fixed seed: exact replay
    assert a.faults == b.faults
    assert a.checks == 120

    c = FaultModel(0.1, seed=8)
    seq_c = [c.check("kv", u) for u in (1, 2, 3) * 40]
    assert seq_c != seq_a                       # seed moves the stream

    # rate endpoints: 0 never faults, 1 always does; bad rates rejected
    never = FaultModel(0.0)
    assert not any(never.check("kv", u) for u in range(50))
    always = FaultModel(1.0)
    assert all(always.check("weight", u) for u in range(50))
    with pytest.raises(ValueError):
        FaultModel(1.5)
    with pytest.raises(ValueError):
        FaultModel(-0.1)

    # the per-unit write ordinal advances the stream: repeated writes to
    # one unit are independent draws, not one frozen verdict
    m = FaultModel(0.5, seed=3)
    draws = [m.check("kv", 9) for _ in range(64)]
    assert any(draws) and not all(draws)
    assert m.stats() == {"fault_checks": 64,
                         "faults_injected": sum(draws)}


# --------------------------------------------- knobs off = legacy, exactly
def test_knobs_off_is_byte_identical_to_default():
    jobs = two_tenant_jobs(seed=1, n=8)
    docs, tokens = [], []
    for knobs in ({}, {"wear_aware": 0.0, "fault_rate": 0.0}):
        clock = VirtualClock()
        tracer = Tracer(clock=clock)
        eng, _ = make_engine(clock=clock, tracer=tracer, **knobs)
        assert eng.faults is None               # rate 0: no model built
        drive_simulated(eng, clock, jobs, max_steps=10_000)
        docs.append(json.dumps(tracer.chrome_trace_doc(), sort_keys=True))
        tokens.append(generated_by_rid(eng))
    assert docs[0] == docs[1]
    assert tokens[0] == tokens[1]


# ------------------------------------------------- wear-aware placement
def test_wear_aware_flattens_weight_gini_token_identical():
    jobs = two_tenant_jobs(seed=2, n=12)
    arms = {}
    for weight in (0.0, 1.0):
        # n_layers + 1 slots: too small for both tenants, so turns swap
        # installs — and min-delta alone would never touch the spare slot
        eng, clock = make_engine(spare_slots=1, wear_aware=weight)
        summary = drive_simulated(eng, clock, jobs, max_steps=10_000)
        arms[weight] = (eng, summary)
    eng_off, s_off = arms[0.0]
    eng_on, s_on = arms[1.0]
    # identical virtual-clock schedule (installs are instant bookkeeping)
    assert s_on["steps"] == s_off["steps"]
    assert generated_by_rid(eng_on) == generated_by_rid(eng_off)
    # min-delta alone parks installs on the same hot slots and leaves the
    # spares cold; the wear blend rotates writes into them
    assert s_on["wear_gini_weight"] < s_off["wear_gini_weight"]
    writes_on = eng_on.wear.plane("weight").writes
    writes_off = eng_off.wear.plane("weight").writes
    assert int(writes_on.sum()) > 0
    # the blend leaves no slot colder than min-delta's coldest
    assert int(writes_on.min()) >= int(writes_off.min())


def test_wear_aware_page_allocation_is_coldest_first():
    eng, clock = make_engine(names=("a",), wear_aware=1.0)
    alloc = eng.arenas["a"].allocator
    assert alloc.wear_aware
    jobs = [(t, "a", prompt, n) for t, _, prompt, n
            in two_tenant_jobs(seed=3, n=6)]
    drive_simulated(eng, clock, jobs, max_steps=10_000)
    # free structure is a (writes, page) min-heap: popping drains it in
    # nondecreasing wear order
    got = [alloc._take_page() for _ in range(min(alloc.n_free, 8))]
    wear = [int(alloc.wear.writes[p - 1]) for p in got]
    assert wear == sorted(wear)


# --------------------------------------------------- fault-rate sweep
def test_fault_sweep_token_equivalent_with_survivals():
    jobs = two_tenant_jobs(seed=4, n=12)
    baseline = None
    survived_by_rate = {}
    for rate in (0.0, 0.01, 0.02, 0.08):
        eng, clock = make_engine(fault_rate=rate, fault_seed=11)
        summary = drive_simulated(eng, clock, jobs, max_steps=10_000)
        assert summary["requests_finished"] == len(jobs)
        toks = generated_by_rid(eng)
        if baseline is None:
            baseline = toks
        else:
            assert toks == baseline, f"rate {rate} changed tokens"
        survived_by_rate[rate] = summary["faults_survived"]
        assert summary["faults_survived"] == \
            summary["slots_retired"] + summary["pages_retired"]

        # conservation with retired pages excluded: every page is free,
        # referenced, or permanently retired — and never two of those
        for arena in eng.arenas.values():
            a = arena.allocator
            free = ({p for _, p in a._free} if a.wear_aware
                    else set(a._free))
            referenced = {p for p in range(1, a.n_pages + 1)
                          if a.refcount[p] > 0}
            assert len(free) == a.n_free
            assert not free & referenced
            assert not a.retired & (free | referenced)
            assert len(free) + len(referenced) + len(a.retired) == a.n_pages
            in_tables = {p for t in a.tables.values() for p in t}
            assert not in_tables & a.retired
        # retired weight slots hold nothing and count against capacity
        res = eng.residency
        for slot in res.retired:
            assert res.slots[slot] is None
        assert not set(res.resident.values()) & res.retired
    assert survived_by_rate[0.0] == 0
    assert survived_by_rate[0.08] > 0, \
        "sweep never injected a fault — seed/rate too conservative"


def test_fault_replay_is_deterministic_per_seed():
    jobs = two_tenant_jobs(seed=5, n=8)
    runs = {}
    for seed in (21, 21, 22):
        eng, clock = make_engine(fault_rate=0.08, fault_seed=seed)
        summary = drive_simulated(eng, clock, jobs, max_steps=10_000)
        doc = json.dumps(eng.wear.as_json(), sort_keys=True)
        runs.setdefault(seed, []).append(
            (doc, summary["faults_survived"], generated_by_rid(eng)))
    (doc_a, n_a, tok_a), (doc_b, n_b, tok_b) = runs[21]
    assert doc_a == doc_b and n_a == n_b and tok_a == tok_b
    # a different seed faults different units (the wear JSON includes the
    # retired list, so any divergence shows up here)
    (doc_c, _, tok_c), = runs[22]
    assert doc_c != doc_a
    assert tok_c == tok_a                       # ...but tokens never move


# ------------------------------------------- weight-slot fault remapping
def test_weight_slot_fault_retires_and_remaps():
    class ScriptedFaults:
        """Duck-typed FaultModel: slot 0 of the weight plane is stuck."""
        def check(self, plane, unit):
            return plane == "weight" and unit == 0

    jobs = two_tenant_jobs(seed=6, n=8)
    eng, clock = make_engine(spare_slots=2)
    eng.residency.faults = ScriptedFaults()
    summary = drive_simulated(eng, clock, jobs, max_steps=10_000)

    base_eng, base_clock = make_engine(spare_slots=2)
    base = drive_simulated(base_eng, base_clock, jobs, max_steps=10_000)

    res = eng.residency
    assert res.stats.slots_retired == 1         # stuck-at: retired once
    assert res.retired == {0}
    assert res.slots[0] is None
    assert 0 not in set(res.resident.values())
    assert 0 in res.wear.retired
    assert summary["slots_retired"] == 1.0
    assert summary["requests_finished"] == len(jobs)
    assert generated_by_rid(eng) == generated_by_rid(base_eng)
    assert base["slots_retired"] == 0.0


# ----------------------------------------------------- junit properties
def test_fault_junit_properties(record_property):
    jobs = two_tenant_jobs(seed=4, n=12)
    eng0, clock0 = make_engine(fault_rate=0.0)
    base = drive_simulated(eng0, clock0, jobs, max_steps=10_000)
    eng, clock = make_engine(fault_rate=0.08, fault_seed=11)
    s = drive_simulated(eng, clock, jobs, max_steps=10_000)
    assert generated_by_rid(eng) == generated_by_rid(eng0)
    assert s["faults_survived"] > 0
    assert base["faults_survived"] == 0
    record_property("faults_survived", int(s["faults_survived"]))
    record_property("slots_retired", int(s["slots_retired"]))
    record_property("pages_retired", int(s["pages_retired"]))
    record_property("fault_checks", eng.faults.checks)
    record_property("wear_gini_weight", round(s["wear_gini_weight"], 4))
