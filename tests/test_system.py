"""End-to-end behaviour tests for the paper's system.

1. The offline scheduler + simulator reproduce the paper's headline claims
   (tested in detail in test_scheduler_sim.py).
2. The training launcher runs, checkpoints, and resumes deterministically.
3. The serving launcher prefills + decodes (resident and streaming modes).
4. A dry-run smoke cell lowers + compiles on a forced-512-device mesh and
   emits roofline terms (subprocess: device count is locked at jax init).
"""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _run(args, timeout=900):
    env = dict(os.environ, PYTHONPATH="src")
    return subprocess.run([sys.executable, "-m", *args], cwd=ROOT, env=env,
                          capture_output=True, text=True, timeout=timeout)


def test_train_launcher_runs_and_resumes(tmp_path):
    ck = str(tmp_path / "ck")
    r = _run(["repro.launch.train", "--arch", "minicpm-2b", "--smoke",
              "--steps", "4", "--batch", "2", "--seq", "32",
              "--ckpt-dir", ck, "--ckpt-every", "2"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "step     3" in r.stdout
    # resume: runs steps 4..5 only
    r2 = _run(["repro.launch.train", "--arch", "minicpm-2b", "--smoke",
               "--steps", "6", "--batch", "2", "--seq", "32",
               "--ckpt-dir", ck])
    assert r2.returncode == 0, r2.stderr[-2000:]
    assert "resumed from step 4" in r2.stdout
    assert "step     3" not in r2.stdout


def test_serve_launcher_prefill_decode():
    r = _run(["repro.launch.serve", "--arch", "gemma-7b", "--smoke",
              "--batch", "2", "--prompt-len", "16", "--gen", "4"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "prefill" in r.stdout and "decode" in r.stdout


def test_serve_streaming_mode():
    r = _run(["repro.launch.serve", "--arch", "minicpm-2b", "--smoke",
              "--streaming", "--arena-slots", "2", "--batch", "2",
              "--prompt-len", "8"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "streaming forward" in r.stdout


@pytest.mark.slow
def test_dryrun_smoke_cell(tmp_path):
    out = str(tmp_path / "cell.json")
    r = _run(["repro.launch.dryrun", "--arch", "minicpm-2b",
              "--shape", "train_4k", "--smoke", "--out", out], timeout=1800)
    assert r.returncode == 0, r.stderr[-3000:]
    cell = json.load(open(out))
    assert cell["chips"] == 256
    roof = cell["roofline"]
    assert roof["dominant"] in ("compute", "memory", "collective")
    assert roof["flops_per_device"] > 0
    assert cell["memory_analysis"]["temp_size_in_bytes"] is not None
