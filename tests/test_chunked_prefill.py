"""Chunked prefill + prompt-length bucketing harness.

Three pillars, per the acceptance bar:
  * compile bounding — distinct prefill jit traces stay <= the bucket
    ladder size over randomized prompt lengths (and grow ~linearly with
    bucketing off), read off the `launch.steps.prefill_cache_info`
    hit/miss counters;
  * token equivalence — chunked prefill is token-for-token identical to
    the monolithic path for slot and paged layouts, across GQA bf16/int8,
    MLA+MoE, and the hymba SWA∥mamba hybrid (ring conversion), including
    prefix-shared/COW pages and a mid-prefill pool-exhaustion
    preempt/resume;
  * scheduling — with a prefill-token budget, the decode batch never
    shrinks below the no-prefill baseline while a long prompt is
    chunk-prefilling, and the worst inter-token gap p95 strictly drops
    versus monolithic prefill on the two-tenant Poisson workload (virtual
    clock + per-token step cost model).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.steps import (cached_prefill_step, cached_serve_step,
                                prefill_cache_info)
from repro.nn.model import init_params
from repro.serving import (EngineModel, SchedulerConfig, ServingEngine,
                           VirtualClock, bucket_for, bucket_ladder,
                           drive_simulated)
from repro.serving.request import RequestStatus

CFG = get_config("gemma-7b", smoke=True)
PARAMS = init_params(jax.random.PRNGKey(0), CFG)
MAX_SEQ = 48


def one_tenant_engine(cfg=CFG, params=PARAMS, *, max_seq=MAX_SEQ, chunk=0,
                      budget=None, growth=2.0, kv_layout="slot", page_size=4,
                      n_pages=0, kv_slots=3, clock=None,
                      max_prefill_per_step=2, staging_growth=2.0):
    kw = dict(kv_slots=kv_slots, max_seq=max_seq, kv_layout=kv_layout,
              page_size=page_size, n_pages=n_pages)
    extra = {} if clock is None else {"clock": clock}
    return ServingEngine(
        [EngineModel("a", params, cfg, **kw)],
        sched=SchedulerConfig(max_prefill_per_step=max_prefill_per_step,
                              prefill_token_budget=budget),
        prefill_chunk=chunk, bucket_growth=growth,
        staging_growth=staging_growth, **extra)


def sequential_tokens(prompt, n_new, cfg=CFG, params=PARAMS,
                      cache_len=MAX_SEQ):
    """Oracle: batch-1 monolithic prefill + scalar-position decode loop."""
    logits, caches = cached_prefill_step(cfg, cache_len)(
        params, {"tokens": jnp.asarray(prompt, jnp.int32)[None]})
    decode = cached_serve_step(cfg)
    toks = [int(jnp.argmax(logits[0, :cfg.vocab]))]
    for i in range(n_new - 1):
        logits, caches = decode(params, jnp.asarray([toks[-1]], jnp.int32),
                                caches, jnp.int32(len(prompt) + i))
        toks.append(int(jnp.argmax(logits[0, :cfg.vocab])))
    return toks


def run_workload(eng, n=6, seed=0, gen=5, lo=3, hi=20):
    rng = np.random.default_rng(seed)
    reqs = [eng.submit("a", rng.integers(1, CFG.vocab,
                                         int(rng.integers(lo, hi))).tolist(),
                       max_new_tokens=gen) for _ in range(n)]
    s = eng.run()
    assert s["requests_finished"] == n
    return reqs, s


# ------------------------------------------------------- bucket ladder
def _ladder_invariants(lo, hi, growth):
    ladder = bucket_ladder(lo, hi, growth)
    assert ladder[-1] == hi
    assert all(b > a for a, b in zip(ladder, ladder[1:])), "not monotone"
    for n in range(1, hi + 1):
        b = bucket_for(n, ladder)
        assert b >= n, "bucket below length"
        assert b <= max(growth * n, lo), (
            f"waste {b}/{n} exceeds growth {growth}")
    # bucket_for is non-decreasing in n
    buckets = [bucket_for(n, ladder) for n in range(1, hi + 1)]
    assert buckets == sorted(buckets)


def test_bucket_ladder_property():
    """Hypothesis sweep: every (lo, hi, growth) ladder covers all lengths,
    is monotone, and wastes at most a growth factor of padding."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=60, deadline=None)
    @given(lo=st.integers(1, 32), span=st.integers(0, 480),
           growth=st.floats(1.1, 4.0, allow_nan=False))
    def prop(lo, span, growth):
        _ladder_invariants(lo, lo + span, growth)

    prop()


def test_bucket_ladder_manual_trials():
    """Deterministic fallback for environments without hypothesis: the same
    invariants over a seeded random parameter sweep."""
    rng = np.random.default_rng(11)
    for _ in range(40):
        lo = int(rng.integers(1, 33))
        hi = lo + int(rng.integers(0, 200))
        growth = float(rng.uniform(1.1, 4.0))
        _ladder_invariants(lo, hi, growth)
    # degenerate ladders are rejected loudly
    with pytest.raises(ValueError):
        bucket_ladder(8, 64, 1.0)
    with pytest.raises(ValueError):
        bucket_ladder(0, 64, 2.0)


# ---------------------------------------------------- compile bounding
def test_trace_count_bounded_by_bucket_ladder(record_property):
    """~50 randomized prompt lengths: with bucketing OFF distinct chunk
    traces grow ~linearly with distinct tail lengths; with bucketing ON
    they stay <= the ladder size.  (Order matters: the step cache is
    process-wide, so the off arm runs first and the on arm's delta can
    only be smaller than a cold ladder.)"""
    chunk = 32
    rng = np.random.default_rng(5)
    lens = [int(x) for x in rng.integers(1, MAX_SEQ - 8, 50)]
    distinct_tails = len({n % chunk or chunk for n in lens})

    def run_arm(growth):
        before = prefill_cache_info()["chunk_misses"]
        # staging_growth=0: one staging length, so the trace count isolates
        # the tail-bucketing effect (the staging ladder has its own test)
        eng = one_tenant_engine(chunk=chunk, growth=growth, kv_slots=4,
                                staging_growth=0.0)
        for n in lens:
            eng.submit("a", rng.integers(1, CFG.vocab, n).tolist(),
                       max_new_tokens=2)
        s = eng.run()
        assert s["requests_finished"] == len(lens)
        return prefill_cache_info()["chunk_misses"] - before

    off_traces = run_arm(0.0)           # bucketing off: pad to exact tail
    on_traces = run_arm(2.0)
    ladder = bucket_ladder(8, chunk, 2.0)
    assert on_traces <= len(ladder), (on_traces, ladder)
    # off: one trace per distinct tail length (~linear growth)
    assert off_traces >= 0.8 * distinct_tails, (off_traces, distinct_tails)
    assert off_traces > 3 * on_traces
    info = prefill_cache_info()
    for k, v in info.items():
        record_property(f"prefill_cache_{k}", v)
    record_property("traces_bucketing_on", on_traces)
    record_property("traces_bucketing_off", off_traces)


# ---------------------------------------------------- staging ladder
def test_staging_ladder_rungs_and_memory():
    """The staging-length ladder (default on): each in-flight prefill
    stages into the smallest rung covering its prompt, not one
    max-capacity buffer.  Rungs are chunk multiples (slot) and
    lcm(chunk, page) multiples (paged); staging_growth <= 1 restores the
    single max-capacity length."""
    eng = one_tenant_engine(chunk=8, max_seq=96)
    rungs = eng._staging_ladders["a"]
    assert rungs[-1] >= 96 and all(r % 8 == 0 for r in rungs)
    assert rungs == sorted(set(rungs)) and len(rungs) > 1
    for n in (1, 8, 9, 96):
        rung = eng.staging_len_for("a", n)
        assert rung >= n and rung % 8 == 0 and rung in rungs
    assert eng.staging_len_for("a", 1) < eng.staging_len_for("a", 96)
    # a short prompt's live staging cache really is rung-sized
    eng.submit("a", [3, 1, 4], max_new_tokens=2)
    eng._admit_staged({"a"})
    st = eng._prefills[0]
    assert st.staging_len == eng.staging_len_for("a", 3)
    leaf = jax.tree.leaves(st.caches)[0]
    assert st.staging_len in leaf.shape
    eng.run()
    # paged: rungs stay page-aligned even when chunk and page are coprime
    paged = one_tenant_engine(chunk=6, kv_layout="paged", page_size=4,
                              n_pages=24)
    assert all(r % 12 == 0 for r in paged._staging_ladders["a"])
    # flat ladder: exactly one max-capacity rung
    flat = one_tenant_engine(chunk=8, max_seq=96, staging_growth=0.0)
    assert flat._staging_ladders["a"] == [96]


def test_staging_ladder_bounds_traces_at_ladder_x_rungs():
    """Trace accounting with the ladder on: distinct chunk-prefill traces
    stay <= |bucket ladder| x |staging rungs actually used|."""
    chunk = 16
    rng = np.random.default_rng(9)
    lens = [int(x) for x in rng.integers(1, MAX_SEQ - 8, 30)]
    before = prefill_cache_info()["chunk_misses"]
    eng = one_tenant_engine(chunk=chunk, kv_slots=4)
    for n in lens:
        eng.submit("a", rng.integers(1, CFG.vocab, n).tolist(),
                   max_new_tokens=2)
    s = eng.run()
    assert s["requests_finished"] == len(lens)
    traces = prefill_cache_info()["chunk_misses"] - before
    ladder = bucket_ladder(8, chunk, 2.0)
    rungs_used = {eng.staging_len_for("a", n) for n in lens}
    assert traces <= len(ladder) * len(rungs_used), (
        traces, ladder, sorted(rungs_used))


def test_staging_ladder_token_identical_to_flat():
    """Rung-sized staging must not change a single token versus the
    max-capacity staging (masked tail positions contribute exact zeros)."""
    flat, _ = run_workload(one_tenant_engine(chunk=8, budget=8,
                                             staging_growth=0.0), seed=12)
    laddered, _ = run_workload(one_tenant_engine(chunk=8, budget=8), seed=12)
    for f, g in zip(flat, laddered):
        assert f.generated == g.generated, f.rid


def test_engine_summary_surfaces_trace_counters():
    eng = one_tenant_engine(chunk=8)
    eng.submit("a", [3, 1, 4, 1, 5, 9, 2, 6], max_new_tokens=2)
    s = eng.run()
    assert s["prefill_chunks"] >= 1
    assert s["prefill_tokens"] == 8.0
    assert s["prefill_cache_chunk_traces"] >= 1
    assert s["prefill_cache_misses"] <= s["prefill_cache_hits"] + \
        s["prefill_cache_misses"]


# -------------------------------------------------- token equivalence
@pytest.mark.parametrize("chunk,budget", [(4, None), (8, 4), (16, 3)])
def test_slot_chunked_matches_monolithic_and_oracle(chunk, budget):
    mono, _ = run_workload(one_tenant_engine())
    chunked, s = run_workload(one_tenant_engine(chunk=chunk, budget=budget))
    for m, c in zip(mono, chunked):
        assert c.generated == m.generated, (chunk, budget, c.rid)
        assert c.generated == sequential_tokens(list(c.prompt),
                                                c.max_new_tokens)
    assert s["prefill_chunks"] >= len(chunked)


@pytest.mark.parametrize("chunk,budget", [(4, None), (8, 4)])
def test_paged_chunked_matches_monolithic_and_oracle(chunk, budget):
    kw = dict(kv_layout="paged", page_size=4, n_pages=24)
    mono, _ = run_workload(one_tenant_engine(**kw), seed=1)
    chunked, _ = run_workload(one_tenant_engine(chunk=chunk, budget=budget,
                                                **kw), seed=1)
    for m, c in zip(mono, chunked):
        assert c.generated == m.generated, (chunk, budget, c.rid)
        assert c.generated == sequential_tokens(
            list(c.prompt), c.max_new_tokens, cache_len=24 * 4)


def test_hymba_hybrid_chunked_matches_monolithic():
    """The SWA∥mamba hybrid: chunk carry through the recurrent state and
    the full-length→ring conversion at install must reproduce the
    monolithic prefill token-for-token (prefill length crosses the
    sliding window)."""
    cfg = get_config("hymba-1.5b", smoke=True)   # window 16
    params = init_params(jax.random.PRNGKey(0), cfg)

    def arm(chunk, budget=None):
        eng = one_tenant_engine(cfg, params, max_seq=40, chunk=chunk,
                                budget=budget)
        rng = np.random.default_rng(2)
        reqs = [eng.submit("a", rng.integers(1, cfg.vocab,
                                             int(n)).tolist(),
                           max_new_tokens=6)
                for n in (24, 7, 30, 18)]       # 24, 30 cross the window
        eng.run()
        return [list(r.generated) for r in reqs]

    mono = arm(0)
    assert arm(8) == mono
    assert arm(16, budget=8) == mono


def test_int8_kv_chunked_matches_monolithic():
    """int8 tenants stage raw bf16 K/V and quantize once at install —
    chunked must reproduce the monolithic attend-raw-then-quantize path."""
    cfg = dataclasses.replace(CFG, kv_cache_dtype="int8")
    mono, _ = run_workload(one_tenant_engine(cfg, PARAMS), seed=3, n=4)
    chunked, _ = run_workload(one_tenant_engine(cfg, PARAMS, chunk=6),
                              seed=3, n=4)
    for m, c in zip(mono, chunked):
        assert c.generated == m.generated, c.rid


def test_mla_moe_chunked_matches_monolithic():
    """MLA latent caches (chunk branch materializes K/V like the monolithic
    prefill, not the absorbed decode path) + MoE batch routing."""
    cfg = get_config("deepseek-v2-lite-16b", smoke=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    mono, _ = run_workload(one_tenant_engine(cfg, params), seed=4, n=4)
    chunked, _ = run_workload(one_tenant_engine(cfg, params, chunk=8,
                                                budget=8), seed=4, n=4)
    for m, c in zip(mono, chunked):
        assert c.generated == m.generated, c.rid


def test_mlstm_tenant_rejects_chunked_prefill():
    """Chunkwise-parallel mLSTM prefill is not chunking-invariant (float
    regrouping at chunk boundaries changes tokens), so the engine must
    refuse prefill_chunk > 0 for mLSTM tenants at construction instead of
    serving silently divergent tokens — the monolithic path stays open."""
    cfg = get_config("xlstm-350m", smoke=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="mLSTM"):
        one_tenant_engine(cfg, params, chunk=8)
    # prefill_chunk=0 still admits the tenant, and a pure-attention
    # tenant is unaffected by the rejection rule at any chunk size
    one_tenant_engine(cfg, params, chunk=0)
    one_tenant_engine(CFG, PARAMS, chunk=8)


def test_paged_chunked_prefix_sharing_and_cow_exact():
    """An identical prompt arriving mid-decode shares the first request's
    pages at chunk granularity (reservation opens with the shared prefix,
    non-shared blocks grow per chunk) and COWs on divergence — both
    decodes oracle-exact, pool drained on finish."""
    kw = dict(kv_layout="paged", page_size=4, n_pages=16,
              max_prefill_per_step=1)
    prompt = [7, 3, 9, 2, 5, 8, 1, 4, 6, 2]      # 2 full pages + partial
    eng = one_tenant_engine(chunk=4, **kw)
    r1 = eng.submit("a", prompt, max_new_tokens=8)
    eng.step()
    eng.step()
    r2 = eng.submit("a", prompt, max_new_tokens=8)
    eng.run()
    alloc = eng.arenas["a"].allocator
    assert alloc.shared_hits >= 3
    assert alloc.cow_copies >= 1
    ref = sequential_tokens(prompt, 8, cache_len=16 * 4)
    assert r1.generated == ref
    assert r2.generated == ref
    assert alloc.n_free == alloc.n_pages and not alloc.tables


def test_paged_mid_prefill_exhaustion_preempts_and_resumes():
    """A chunk-prefilling request whose page reservation hits pool
    exhaustion is preempted (pages freed, staging kept) and resumes at the
    last completed chunk once the decoding neighbor drains — no prompt
    token is ever re-prefilled, and tokens stay oracle-exact."""
    eng = one_tenant_engine(chunk=4, budget=4, kv_layout="paged",
                            page_size=4, n_pages=6, kv_slots=2,
                            max_prefill_per_step=1)
    rng = np.random.default_rng(6)
    p1 = rng.integers(1, CFG.vocab, 4).tolist()
    p2 = rng.integers(1, CFG.vocab, 16).tolist()
    r1 = eng.submit("a", p1, max_new_tokens=17)  # grows to ceil(21/4)=6 pages
    for _ in range(3):                           # r1 mid-decode, 2 pages held
        eng.step()
    r2 = eng.submit("a", p2, max_new_tokens=4)   # needs 4 blocks + 1 decode
    saw_prefilling = False
    steps = 0
    while eng.has_work() and steps < 200:
        saw_prefilling |= r2.status is RequestStatus.PREFILLING
        eng.step()
        steps += 1
    s = eng.summary()
    assert r1.status is RequestStatus.FINISHED
    assert r2.status is RequestStatus.FINISHED
    assert saw_prefilling
    assert r2.preemptions >= 1, "no mid-prefill preemption was provoked"
    assert r1.preemptions == 0
    # resume reused the staging: every prompt token prefilled exactly once
    assert s["prefill_tokens"] == len(p1) + len(p2)
    assert r1.generated == sequential_tokens(p1, 17, cache_len=6 * 4)
    assert r2.generated == sequential_tokens(p2, 4, cache_len=6 * 4)


def test_slot_explicit_preempt_mid_prefill_resumes():
    """engine.preempt on a PREFILLING request releases the slot but keeps
    chunk progress; readmission resumes rather than restarting."""
    eng = one_tenant_engine(chunk=4, budget=4, kv_slots=1,
                            max_prefill_per_step=1)
    prompt = list(range(1, 17))
    req = eng.submit("a", prompt, max_new_tokens=3)
    eng.step()                                    # one chunk done
    assert req.status is RequestStatus.PREFILLING
    done_before = eng._prefills[req.rid].done
    assert done_before == 4
    eng.preempt(req.rid)
    assert req.status is RequestStatus.PREEMPTED
    assert req.rid in eng._prefills               # staging survives
    eng.run()
    s = eng.summary()
    assert req.status is RequestStatus.FINISHED
    assert s["prefill_tokens"] == len(prompt)     # no chunk re-run
    assert req.generated == sequential_tokens(prompt, 3)


# ------------------------------------------------------- scheduling
def test_decode_batch_never_shrinks_during_chunked_prefill():
    """With a prefill-token budget, a long prompt's chunks interleave with
    the decode batch: every step while it prefills still decodes one token
    per running request (the no-prefill baseline)."""
    eng = one_tenant_engine(chunk=8, budget=8, max_seq=96, kv_slots=3)
    a = eng.submit("a", [5, 6, 7], max_new_tokens=40)
    b = eng.submit("a", [9, 8, 7, 6], max_new_tokens=40)
    eng.step()                     # both admitted and decoding
    assert a.status is RequestStatus.RUNNING
    long = eng.submit("a", list(np.arange(1, 65)), max_new_tokens=2)
    prefill_steps = 0
    while long.status in (RequestStatus.QUEUED, RequestStatus.PREFILLING):
        running = sum(r.status is RequestStatus.RUNNING
                      for r in (a, b))
        eng.step()
        rec = eng.metrics.steps[-1]
        assert rec.n_decoded >= running, (
            "decode batch shrank while the long prompt chunk-prefilled")
        if rec.n_prefill_chunks:
            prefill_steps += 1
    assert prefill_steps >= 64 // 8, "budget did not spread the prefill"
    eng.run()
    for r in (a, b, long):
        assert r.generated == sequential_tokens(list(r.prompt),
                                                r.max_new_tokens,
                                                cache_len=96)


def _itl_arm(jobs, *, chunk, budget):
    clock = VirtualClock()
    cfg = CFG
    eng = ServingEngine(
        [EngineModel("a", PARAMS, cfg, kv_slots=3, max_seq=200),
         EngineModel("b", init_params(jax.random.PRNGKey(1), cfg), cfg,
                     kv_slots=3, max_seq=200)],
        sched=SchedulerConfig(max_prefill_per_step=2,
                              prefill_token_budget=budget),
        clock=clock, prefill_chunk=chunk)
    dt = 1e-3
    s = drive_simulated(
        eng, clock, jobs, dt=dt,
        step_dt=lambda rec: dt * (1 + rec.prefill_tokens))
    s["_generated"] = {r.rid: list(r.generated)
                       for r in eng.requests.values()}
    return s


def test_chunked_prefill_strictly_improves_worst_itl():
    """Two-tenant Poisson workload with one long prompt per tenant, virtual
    clock charging each step for its prefilled tokens: the budgeted chunked
    arm must strictly drop the worst inter-token-gap p95 versus monolithic
    prefill — token-for-token identical."""
    rng = np.random.default_rng(8)
    t, jobs = 0.0, []
    for i in range(10):
        t += float(rng.exponential(2.0)) * 1e-3
        plen = 180 if i in (4, 7) else int(rng.integers(3, 12))
        jobs.append((t, "a" if i % 2 == 0 else "b",
                     rng.integers(1, CFG.vocab, plen).tolist(),
                     int(rng.integers(6, 12))))
    mono = _itl_arm(jobs, chunk=0, budget=None)
    chunked = _itl_arm(jobs, chunk=16, budget=16)
    assert chunked["_generated"] == mono["_generated"]
    assert chunked["itl_max_p95_s"] < mono["itl_max_p95_s"], (
        chunked["itl_max_p95_s"], mono["itl_max_p95_s"])
    # the TTFT split exists for the chunked arm: queue + prefill == ttft
    assert chunked["ttft_queue_p95_s"] >= 0
    assert chunked["ttft_prefill_p95_s"] > 0


def test_ttft_split_survives_decode_preemption():
    """Re-prefilling a preempted (already-decoding) request must not move
    prefill_start_t past the first token: the TTFT split describes the
    road to the FIRST token only, so ttft_prefill stays non-negative."""
    eng = one_tenant_engine(chunk=4, kv_slots=1)
    req = eng.submit("a", list(range(1, 9)), max_new_tokens=8)
    eng.step()                                    # prefilled + first token
    assert req.status is RequestStatus.RUNNING
    eng.step()
    eng.preempt(req.rid)
    eng.run()
    assert req.status is RequestStatus.FINISHED
    assert req.preemptions == 1
    assert req.prefill_start_t <= req.first_token_t
    assert req.ttft_prefill >= 0
    assert req.generated == sequential_tokens(list(req.prompt), 8)


def test_ttft_splits_sum_to_ttft():
    eng = one_tenant_engine(chunk=4, budget=4,
                            clock=None)
    req = eng.submit("a", list(range(1, 13)), max_new_tokens=2)
    eng.run()
    assert req.ttft_queue is not None and req.ttft_prefill is not None
    assert req.ttft == pytest.approx(req.ttft_queue + req.ttft_prefill)


# ------------------------------------------------- allocator staging
def test_allocator_begin_grow_atomic():
    from repro.serving import PageAllocator
    a = PageAllocator(4, 2)
    n_shared = a.begin_table(0, (1, 2, 3, 4, 5))    # 3 blocks, none shared
    assert n_shared == 0 and a.tables[0] == []
    assert a.grow_table(0, 2) and len(a.tables[0]) == 2
    assert a.grow_table(0, 2)                       # idempotent
    a.begin_table(1, (9, 9))
    assert a.grow_table(1, 1)
    # pool now 3/4 used; growing rid 0 to 5 blocks needs 3 more > 1 free
    assert not a.grow_table(0, 5)
    assert len(a.tables[0]) == 2, "failed grow must not partially allocate"
    a.free_table(0)
    a.free_table(1)
    assert a.n_free == a.n_pages
