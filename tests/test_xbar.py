"""xbar substrate: quantization, 2-bit cells, Eq. 6-7 compensation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.xbar.cells import (
    CELLS_PER_WEIGHT,
    cell_similarity,
    pack_cells,
    pulse_count,
    skip_ratio,
    unpack_cells,
)
from repro.xbar.quant import (
    dequantize,
    dot_int8,
    quantize_tensor,
    shift_weights,
)


def test_pack_unpack_roundtrip():
    codes = jnp.arange(256, dtype=jnp.uint8)
    assert (unpack_cells(pack_cells(codes)) == codes).all()


@settings(max_examples=50, deadline=None)
@given(st.integers(0, 255), st.integers(0, 255))
def test_pulse_count_is_cellwise_l1(a, b):
    pa, pb = np.asarray(pack_cells(jnp.uint8(a))), np.asarray(pack_cells(jnp.uint8(b)))
    expected = np.abs(pa.astype(int) - pb.astype(int)).sum()
    assert int(pulse_count(jnp.uint8(a), jnp.uint8(b))) == expected


def test_skip_ratio_identical_is_one():
    codes = jnp.asarray(np.random.default_rng(0).integers(0, 256, 1000, dtype=np.uint8))
    assert float(skip_ratio(codes, codes)) == 1.0
    assert int(pulse_count(codes, codes)) == 0


def test_quantize_roundtrip_error_bound():
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.normal(0, 0.1, 4096).astype(np.float32))
    code, qp = quantize_tensor(w)
    w2 = dequantize(code, qp)
    max_err = float(jnp.max(jnp.abs(w - w2)))
    assert max_err <= float(qp.scale) * 0.5 + 1e-7


def test_eq7_shift_compensation_exact():
    """§V-C: shifting weight codes and subtracting the same Offset from the
    zero point leaves the dot product bit-identical (absent clipping)."""
    rng = np.random.default_rng(2)
    w = jnp.asarray(rng.normal(0, 0.05, (128, 32)).astype(np.float32))
    x = jnp.asarray(rng.normal(0, 1.0, (8, 128)).astype(np.float32))
    w_code, w_qp = quantize_tensor(w)
    x_code, x_qp = quantize_tensor(x)
    y_ref = dot_int8(x_code, w_code, x_qp, w_qp)

    # shift toward a paper center, avoiding clipping by picking 96
    shifted, offset = shift_weights(w_code, jnp.float32(96.0))
    clipped = np.count_nonzero(
        np.asarray(w_code, np.int32) + int(offset) !=
        np.asarray(shifted, np.int32))
    if clipped == 0:
        y_shift = dot_int8(x_code, shifted, x_qp, w_qp.shifted(offset))
        np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_shift),
                                   rtol=1e-6, atol=1e-5)


def test_cell_similarity_eq3_bounds():
    rng = np.random.default_rng(3)
    a = jnp.asarray(rng.integers(0, 256, 4000, dtype=np.uint8))
    b = jnp.asarray(rng.integers(0, 256, 4000, dtype=np.uint8))
    for i in range(CELLS_PER_WEIGHT):
        s = float(cell_similarity(a, b, i))
        assert 0.0 <= s <= 1.0
    # identical distributions of a uniform stream → ≈ 0.25 per cell
    s0 = float(cell_similarity(a, a, 0))
    assert abs(s0 - 0.25) < 0.05
