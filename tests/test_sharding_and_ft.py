"""Sharding spec trees, gradient compression, straggler/watchdog utilities."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.configs import ARCHS, get_config
from repro.ft import StepTimer, Watchdog
from repro.launch.steps import (
    abstract_caches,
    abstract_params,
    cache_shardings,
    param_shardings,
)
from repro.parallel.compression import (
    _dequantize_blockwise,
    _quantize_blockwise,
    compression_ratio_bytes,
)
from repro.parallel.sharding import use_mesh


def _mesh11():
    return Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))


@pytest.mark.parametrize("arch", ARCHS)
def test_param_shardings_match_param_tree(arch):
    cfg = get_config(arch, smoke=True)
    mesh = _mesh11()
    with use_mesh(mesh):
        ap = abstract_params(cfg)
        psh = param_shardings(cfg, mesh)
    # same tree structure → zip succeeds, and every leaf has a sharding
    leaves_p = jax.tree_util.tree_leaves(ap)
    leaves_s = jax.tree_util.tree_leaves(
        psh, is_leaf=lambda x: hasattr(x, "spec"))
    assert len(leaves_p) == len(leaves_s)
    for p, s in zip(leaves_p, leaves_s):
        assert len(s.spec) <= p.ndim, (p.shape, s.spec)


@pytest.mark.parametrize("arch", ["qwen3-32b", "hymba-1.5b", "xlstm-350m",
                                  "deepseek-v2-lite-16b"])
def test_cache_shardings_match_cache_tree(arch):
    cfg = get_config(arch, smoke=True)
    mesh = _mesh11()
    with use_mesh(mesh):
        ac = abstract_caches(cfg, batch=2, cache_len=32)
        csh = cache_shardings(cfg, mesh)
    lp = jax.tree_util.tree_leaves(ac)
    ls = jax.tree_util.tree_leaves(csh, is_leaf=lambda x: hasattr(x, "spec"))
    assert len(lp) == len(ls)


def test_blockwise_quant_roundtrip_error():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 3.0, (5000,)).astype(np.float32))
    q, s = _quantize_blockwise(x)
    out = _dequantize_blockwise(q, s, x.shape, x.size)
    # error bounded by scale/2 per block
    max_scale = float(jnp.max(s))
    assert float(jnp.max(jnp.abs(out - x))) <= max_scale * 0.5 + 1e-6


def test_error_feedback_is_lossless_over_time():
    """Σ_t dequant_t = Σ_t g_t exactly in the limit: the residual is carried,
    so cumulative compressed updates track cumulative gradients."""
    rng = np.random.default_rng(1)
    g = jnp.asarray(rng.normal(0, 1, (4096,)).astype(np.float32))
    err = jnp.zeros_like(g)
    applied = jnp.zeros_like(g)
    for _ in range(30):
        total = g + err
        q, s = _quantize_blockwise(total)
        deq = _dequantize_blockwise(q, s, total.shape, total.size)
        err = total - deq
        applied = applied + deq
    drift = float(jnp.max(jnp.abs(applied / 30.0 - g)))
    assert drift < 0.05


def test_compression_ratio_is_4x_ish():
    g = {"a": jnp.zeros((1 << 20,))}
    raw, comp = compression_ratio_bytes(g)
    assert raw / comp > 3.5


def test_straggler_flagging():
    t = StepTimer(ewma_alpha=1.0, threshold=1.5)
    t.observe({"h0": 1.0, "h1": 1.0, "h2": 1.0, "h3": 5.0})
    rep = t.report(1)
    assert rep.flagged and "h3" in rep.slowest


def test_watchdog_fires_and_cancels():
    fired = []
    wd = Watchdog(0.15, on_timeout=lambda s: fired.append(s))
    with wd.armed(1):
        time.sleep(0.01)
    assert not fired
    with wd.armed(2):
        time.sleep(0.35)
    assert fired == [2]
