"""Kernel-backend equivalence: the Pallas paged-attention decode route
(`kernel_backend="pallas"`, interpret mode on CPU) must produce
token-for-token identical engine output to the XLA gather path, and the
fused on-device sampler must be bitwise-identical to the per-row host
sampler — across bf16 and int8 pools, with preemption and COW in the
schedule, and across temperature/top-k/seed grids including the
padded-vocab-tail edge.  The named CI step re-runs exactly this file."""
import dataclasses as dc

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels.paged_attention.ops import paged_attention
from repro.nn.model import init_params
from repro.serving import EngineModel, ServingEngine, SchedulerConfig
from repro.serving.sampling import request_key, sample_token, sample_tokens

CFG = get_config("gemma-7b", smoke=True)
PARAMS = init_params(jax.random.PRNGKey(0), CFG)
PAGE = 4


# ------------------------------------------------------------ ops contract
def _ops_inputs(H=4, Hkv=2, D=8, P=6, T=3, B=2):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(B, H, D)).astype(np.float32))
    kp = jnp.asarray(rng.normal(size=(P, PAGE, Hkv, D)).astype(np.float32))
    vp = jnp.asarray(rng.normal(size=(P, PAGE, Hkv, D)).astype(np.float32))
    tables = jnp.asarray(rng.integers(0, P, (B, T)), jnp.int32)
    pos = jnp.asarray([3, 5], jnp.int32)
    return q, kp, vp, tables, pos


def test_ops_rejects_non_divisible_heads():
    q, kp, vp, tables, pos = _ops_inputs(H=5, Hkv=2)
    with pytest.raises(ValueError, match="not divisible"):
        paged_attention(q, kp, vp, tables, pos, interpret=True)


def test_ops_rejects_non_int32_tables():
    q, kp, vp, tables, pos = _ops_inputs()
    with pytest.raises(ValueError, match="int32"):
        paged_attention(q, kp, vp, tables.astype(jnp.float32), pos,
                        interpret=True)


def test_ops_explicit_interpret_runs():
    q, kp, vp, tables, pos = _ops_inputs()
    out = paged_attention(q, kp, vp, tables, pos, interpret=True)
    assert out.shape == q.shape
    assert bool(jnp.all(jnp.isfinite(out)))


def test_engine_model_validates_kernel_backend():
    with pytest.raises(ValueError, match="kernel_backend"):
        EngineModel("a", PARAMS, CFG, kernel_backend="cuda")
    with pytest.raises(ValueError, match="paged"):
        EngineModel("a", PARAMS, CFG, kv_layout="slot",
                    kernel_backend="pallas")
    with pytest.raises(ValueError, match="kernel_backend"):
        ServingEngine([EngineModel("a", PARAMS, CFG)],
                      kernel_backend="cuda")


# ----------------------------------------------------- engine equivalence
def _run_engine(backend, fuse, *, int8=False):
    """A schedule that exercises sharing, COW, and preemption: a small
    pool with the prefix cache on, shared prompts (pages shared on
    admission, COWed on first decode write), and enough concurrent load
    that the pool runs dry mid-decode."""
    cfg = dc.replace(CFG, kv_cache_dtype="int8") if int8 else CFG
    eng = ServingEngine(
        [EngineModel("a", PARAMS, cfg, kv_slots=3, max_seq=24,
                     kv_layout="paged", page_size=PAGE, n_pages=10,
                     prefix_cache=True, kernel_backend=backend)],
        sched=SchedulerConfig(max_prefill_per_step=2),
        fuse_sampling=fuse, kernel_interpret=True)
    rng = np.random.default_rng(5)
    shared = rng.integers(1, cfg.vocab, 10).tolist()   # 2.5 pages
    # r1 runs two steps, then an identical prompt arrives mid-decode:
    # r2 shares r1's live pages including the partial tail page and COWs
    # it on its first decode write
    reqs = [eng.submit("a", shared, max_new_tokens=8)]
    eng.step()
    eng.step()
    reqs += [
        eng.submit("a", shared, max_new_tokens=8),
        eng.submit("a", rng.integers(1, cfg.vocab, 12).tolist(),
                   max_new_tokens=12),
        eng.submit("a", shared[:4], max_new_tokens=6,
                   temperature=0.9, top_k=7, seed=11),
    ]
    eng.run()
    arena = eng.arenas["a"]
    stats = {
        "cow": arena.allocator.cow_copies,
        "preempt": sum(r.preemptions for r in reqs),
        "sync_max": max((rec.sample_syncs for rec in eng.metrics.steps
                         if rec.n_decoded), default=0),
    }
    return {r.rid: tuple(r.generated) for r in reqs}, stats


@pytest.mark.parametrize("int8", [False, True], ids=["bf16", "int8"])
def test_pallas_engine_tokens_match_xla(int8):
    base, base_stats = _run_engine("xla", False, int8=int8)
    assert base_stats["cow"] > 0          # the schedule exercises COW
    assert base_stats["preempt"] > 0      # ... and pool-exhaustion preemption
    for backend, fuse in (("xla", True), ("pallas", False),
                          ("pallas", True)):
        got, stats = _run_engine(backend, fuse, int8=int8)
        assert got == base, (backend, fuse)
        assert stats["cow"] == base_stats["cow"]
        assert stats["preempt"] == base_stats["preempt"]


def test_sample_syncs_at_most_one_per_step():
    """Fused or split, sampling costs at most one host sync per decoded
    step — never one per row (the PR 9 hot-path bug)."""
    for fuse in (True, False):
        _, stats = _run_engine("pallas", fuse)
        assert stats["sync_max"] == 1, fuse


# ------------------------------------------------------- sampler identity
def test_fused_sampler_matches_host_grid():
    """`sample_tokens` is row-for-row bitwise identical to per-row
    `sample_token` across temperature/top-k/seed, with the padded vocab
    tail poisoned to +1e9 (it must be masked, not sampled)."""
    vocab, pad, B = CFG.vocab, 64, 6
    rng = np.random.default_rng(1)
    logits = jnp.asarray(rng.normal(size=(B, vocab + pad)).astype(np.float32))
    logits = logits.at[:, vocab:].set(1e9)
    for temp in (0.0, 0.7, 1.3):
        for tk in (0, 1, 5, vocab):
            for seed in (None, 7):
                keys, steps, ref = [], [], []
                for r in range(B):
                    key = request_key(seed, r)
                    keys.append(np.asarray(key, np.uint32))
                    steps.append(r * 3)
                    ref.append(sample_token(
                        logits[r], vocab, temperature=temp, top_k=tk,
                        key=key, step=r * 3))
                got = np.asarray(sample_tokens(
                    logits, vocab,
                    temperatures=jnp.full((B,), temp, jnp.float32),
                    top_ks=jnp.full((B,), tk, jnp.int32),
                    keys=jnp.asarray(np.stack(keys)),
                    steps=jnp.asarray(steps, dtype=jnp.int32)))
                assert list(got) == ref, (temp, tk, seed)
                assert all(t < vocab for t in ref)


def test_sample_tokens_mixed_rows_one_call():
    """One batched call handles a heterogeneous batch: greedy rows,
    sampled rows, and top-k rows in the same device call."""
    vocab = CFG.vocab
    rng = np.random.default_rng(2)
    logits = jnp.asarray(rng.normal(size=(4, vocab)).astype(np.float32))
    temps = jnp.asarray([0.0, 0.8, 1.2, 0.0], jnp.float32)
    tks = jnp.asarray([0, 3, 0, 5], jnp.int32)
    keys = jnp.asarray(np.stack([
        np.asarray(request_key(None, r), np.uint32) for r in range(4)]))
    steps = jnp.asarray([0, 1, 2, 3], jnp.int32)
    got = np.asarray(sample_tokens(logits, vocab, temperatures=temps,
                                   top_ks=tks, keys=keys, steps=steps))
    for r, (t, k) in enumerate(zip([0.0, 0.8, 1.2, 0.0], [0, 3, 0, 5])):
        want = sample_token(logits[r], vocab, temperature=t, top_k=k,
                            key=request_key(None, r), step=int(steps[r]))
        assert got[r] == want, r
