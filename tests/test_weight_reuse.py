"""§V-C Adaptive Partial Weight Reuse properties."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.weight_reuse import (
    CENTERS,
    ERASED_HIST,
    cell_hist,
    encode_network,
    expected_pulses_per_weight,
    expected_skip_per_cell,
    pulse_matrix,
)


def bell_codes(rng, mean, sigma, n=20000):
    return np.clip(rng.normal(mean, sigma, n), 0, 255).astype(np.uint8)


def test_centering_improves_msb_skip_and_pulses():
    rng = np.random.default_rng(0)
    layers = [("a", bell_codes(rng, 110, 20)), ("b", bell_codes(rng, 150, 22)),
              ("c", bell_codes(rng, 135, 18))]
    off_encs, _ = encode_network(layers, enabled=False)
    on_encs, center = encode_network(layers, enabled=True)
    assert center in CENTERS

    def stats(encs):
        skips, pulses = [], []
        for a, b in zip(encs[:-1], encs[1:]):
            skips.append(expected_skip_per_cell(a.hist, b.hist)[2:].sum())
            pulses.append(expected_pulses_per_weight(a.hist, b.hist))
        return np.mean(skips), np.mean(pulses)

    s_off, p_off = stats(off_encs)
    s_on, p_on = stats(on_encs)
    assert s_on > s_off           # MSB cells agree more often
    assert p_on < p_off           # fewer programming pulses


def test_clip_guard_respected():
    rng = np.random.default_rng(1)
    layers = [("a", bell_codes(rng, 128, 15)), ("b", bell_codes(rng, 128, 15))]
    encs, center = encode_network(layers, enabled=True, max_clip_rate=1e-3)
    assert all(e.clip_rate <= 1e-3 for e in encs)


def test_first_layer_never_shifted():
    rng = np.random.default_rng(2)
    layers = [("a", bell_codes(rng, 100, 10)), ("b", bell_codes(rng, 170, 10))]
    encs, _ = encode_network(layers, enabled=True)
    assert encs[0].offset == 0


def test_pulse_matrix_shape_and_erased_row():
    rng = np.random.default_rng(3)
    layers = [("a", bell_codes(rng, 120, 25)), ("b", bell_codes(rng, 140, 25))]
    encs, _ = encode_network(layers, enabled=True)
    m = pulse_matrix(encs)
    assert m.shape == (3, 2)
    # writing over erased (level-0) cells costs the code's own level sum
    h = encs[0].hist
    exp = expected_pulses_per_weight(ERASED_HIST, h)
    assert np.isclose(m[0, 0], exp)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**32 - 1))
def test_expected_pulses_nonnegative_and_bounded(seed):
    rng = np.random.default_rng(seed)
    a = cell_hist(rng.integers(0, 256, 4096).astype(np.uint8))
    b = cell_hist(rng.integers(0, 256, 4096).astype(np.uint8))
    p = expected_pulses_per_weight(a, b)
    assert 0.0 <= p <= 3.0 * 4  # ≤ max |Δ| per cell × 4 cells
    # |Δ| is symmetric → expectation is symmetric in (old, new)
    assert np.isclose(expected_pulses_per_weight(a, b),
                      expected_pulses_per_weight(b, a))
