"""Radix-tree prefix cache: retained KV pages, LRU eviction, chunk-skip.

Three pillars, per the acceptance bar:
  * tree/allocator invariants — hypothesis property tests (with a
    deterministic manual-trials fallback) over random
    admit/decode/finish-with-donate/evict sequences: refcounts never go
    negative, pinned pages are never freed or evicted, lookups return
    block-aligned prefixes of resident pages, and retention conserves
    pages (free + live + cached == pool);
  * token equivalence — warm requests over a cached shared prefix are
    token-for-token identical to cold prefill while recomputing zero
    tokens of the covered chunks (asserted through the prefill_tokens /
    prefix_hit_tokens accounting), on the paged layout and against the
    slot-layout and sequential oracles, including eviction under pool
    pressure and a mid-prefill hit on a resumed request;
  * metrics/CI — the engine summary surfaces hit tokens, hit rate,
    resident cached pages, and LRU evictions; the CI-properties test
    publishes them as junit <properties> for the named workflow step.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.steps import cached_prefill_step, cached_serve_step
from repro.nn.model import init_params
from repro.serving import (EngineModel, PageAllocator, SchedulerConfig,
                           ServingEngine)
from repro.serving.request import RequestStatus

CFG = get_config("gemma-7b", smoke=True)
PARAMS = init_params(jax.random.PRNGKey(0), CFG)
PAGE = 4


# ---------------------------------------------------------- invariants
def _check_invariants(a: PageAllocator):
    """Conservation laws with retention: every page is free xor
    referenced; refcount == table references + (1 if the tree retains it);
    the free list never holds a live page; retained nodes' pages are
    alive; and the cached-page counter matches the tree."""
    counts = np.zeros(a.n_pages + 1, np.int64)
    for table in a.tables.values():
        for page in table:
            counts[page] += 1
    retained = set()
    stack = list(a.tree._root.children.values())
    n_nodes = 0
    while stack:
        node = stack.pop()
        n_nodes += 1
        assert a.tree._by_page.get(node.page) is node, "page index stale"
        if node.retained:
            retained.add(node.page)
        if len(node.edge) < a.page_size:
            assert not node.children, "partial edge with children"
        stack.extend(node.children.values())
    assert n_nodes == len(a.tree._by_page), "unreachable indexed nodes"
    assert len(retained) == a.tree.n_cached
    free = set(a._free)
    assert len(free) == len(a._free), "free list holds duplicates"
    for page in range(1, a.n_pages + 1):
        expect = counts[page] + (1 if page in retained else 0)
        assert a.refcount[page] == expect, (
            f"page {page}: refcount {a.refcount[page]} != {expect}")
        assert a.refcount[page] >= 0, "negative refcount"
        assert (page in free) == (a.refcount[page] == 0)
    assert a.n_free + int((a.refcount[1:] > 0).sum()) == a.n_pages
    # the incremental evictable count (heap-era bookkeeping) must never
    # drift from the O(tree) reference walk, with and without an exclude
    # set (can_admit excludes the prefix it is about to pin)
    assert a.tree.evictable_count() == a.tree.evictable_walk(a._sole), (
        "incremental evictable count drifted from the reference walk")
    if retained:
        excl = frozenset(list(retained)[:2])
        assert (a.tree.evictable_count(excl)
                == a.tree.evictable_walk(a._sole, excl)), (
            "evictable count with exclude drifted from the walk")
    # every retained leaf must own a live heap entry carrying its current
    # stamp — otherwise a candidate could become invisible to evict_lru
    entries = {(p, s) for s, _, p in a.tree._heap}
    for node in (a.tree._by_page[p] for p in retained):
        if not node.children:
            assert (node.page, node.stamp) in entries, (
                f"retained leaf page {node.page} missing from the "
                "candidate heap")


def _check_match_block_aligned(a: PageAllocator, tokens):
    """A lookup's cover is block-aligned: k matched pages cover exactly the
    first k blocks of `tokens`, every matched page is alive, and no page
    repeats within one match."""
    pages = a.match_prefix(tuple(tokens), touch=False)
    assert len(pages) <= a.blocks_for(len(tokens))
    assert len(set(pages)) == len(pages), "match repeats a physical page"
    for page in pages:
        assert a.refcount[page] >= 1, "match returned a dead page"
        assert page not in set(a._free)


def _random_trial(seed: int, *, n_ops: int = 60, retain: bool = True,
                  max_cached=None):
    """One random op sequence over a small pool with a tiny token alphabet
    (so prefixes really collide): admit via alloc_table or
    begin_table/grow_table, register, extend, cow, finish with or without
    donation — invariants checked after every op."""
    rng = np.random.default_rng(seed)
    a = PageAllocator(8, 2, retain=retain, max_cached=max_cached)
    live = {}               # rid -> tokens
    next_rid = 0
    for _ in range(n_ops):
        op = rng.choice(["new", "stage", "finish", "donate", "extend",
                         "cow", "match"])
        if op in ("new", "stage"):
            n = int(rng.integers(1, 9))
            tokens = tuple(int(t) for t in rng.integers(0, 3, n))
            if op == "new":
                got = a.alloc_table(next_rid, tokens)
                if got is not None:
                    a.register(next_rid, tokens)
                    live[next_rid] = tokens
            else:
                a.begin_table(next_rid, tokens)
                if a.grow_table(next_rid, a.blocks_for(n)):
                    a.register(next_rid, tokens)
                    live[next_rid] = tokens
                else:       # reservation lost the race: release
                    a.free_table(next_rid)
            next_rid += 1
        elif op == "match":
            n = int(rng.integers(1, 9))
            _check_match_block_aligned(
                a, tuple(int(t) for t in rng.integers(0, 3, n)))
        elif live:
            rid = list(live)[int(rng.integers(len(live)))]
            if op == "finish":
                a.free_table(rid)
                live.pop(rid)
            elif op == "donate":
                tokens = live.pop(rid)
                # grow the sequence like decode would, then donate the
                # prefix the table actually covers
                extra = tuple(int(t) for t in rng.integers(
                    0, 3, len(a.tables[rid]) * a.page_size - len(tokens)))
                a.free_table(rid, donate_tokens=tokens + extra)
            elif op == "extend":
                a.extend(rid)
            elif op == "cow":
                a.cow(rid, int(rng.integers(len(a.tables[rid]))))
        _check_invariants(a)
    for rid in list(live):
        a.free_table(rid)
        live.pop(rid)
    _check_invariants(a)
    # after releasing every table, only tree-retained pages stay used
    assert a.n_used == a.tree.n_cached
    if max_cached is not None:
        assert a.tree.n_cached <= max_cached
    # and the cache is fully evictable: draining it empties the pool
    assert a.ensure_free(a.n_pages)
    assert a.n_free == a.n_pages and a.tree.n_cached == 0
    _check_invariants(a)


def test_allocator_retention_manual_trials():
    """Deterministic fallback for environments without hypothesis."""
    for seed in range(25):
        _random_trial(seed)
    for seed in range(10):
        _random_trial(100 + seed, max_cached=3)
    for seed in range(5):
        _random_trial(200 + seed, retain=False)


def test_allocator_retention_property_random_ops():
    """Hypothesis sweep over the same op machine."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=80, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1),
           cap=st.one_of(st.none(), st.integers(0, 6)))
    def prop(seed, cap):
        _random_trial(seed, max_cached=cap)

    prop()


# ------------------------------------------------------------ tree unit
def test_donated_pages_survive_and_rematch():
    a = PageAllocator(8, 2, retain=True)
    tokens = (5, 6, 7, 8, 9)                 # 2 full pages + 1 partial
    table, _ = a.alloc_table(0, tokens)
    a.register(0, tokens)
    a.free_table(0, donate_tokens=tokens)
    assert a.tree.n_cached == 3 and a.n_used == 3
    # full-block match against a longer prompt: partial tail page of the
    # donation does not match a full block (block-aligned semantics)
    assert a.match_prefix((5, 6, 7, 8, 1, 2)) == table[:2]
    # exact match reaches the partial page too
    assert a.match_prefix(tokens) == table
    _check_invariants(a)


def test_lru_eviction_order_and_pinning():
    a = PageAllocator(4, 2, retain=True)
    a.alloc_table(0, (1, 1))
    a.register(0, (1, 1))
    a.free_table(0, donate_tokens=(1, 1))
    a.alloc_table(1, (2, 2))
    a.register(1, (2, 2))
    a.free_table(1, donate_tokens=(2, 2))
    assert a.tree.n_cached == 2
    # touch (1, 1): (2, 2) becomes the LRU victim
    assert a.match_prefix((1, 1))
    t2, s2 = a.alloc_table(2, (1, 1))       # pins the (1, 1) page
    assert s2 == 1
    # demand 3 fresh pages: 2 free + 1 evictable — the pinned (1, 1)
    # page must survive, the (2, 2) page must go
    t3, _ = a.alloc_table(3, (7, 8, 9, 0, 7, 7))
    assert t3 is not None and len(t3) == 3
    assert a.tree.evictions == 1
    assert a.match_prefix((1, 1)) == t2      # still resident, still shared
    assert a.match_prefix((2, 2)) == []      # evicted
    _check_invariants(a)


def test_donation_onto_live_nodes_transfers_refs():
    """Donating a sequence whose prefix blocks are still live transfers
    the caller's refcounts into the tree: the pages outlive the remaining
    live holder, and leaf-first eviction can fully drain the chain."""
    a = PageAllocator(8, 2, retain=True)
    a.alloc_table(0, (3, 3))
    a.register(0, (3, 3))
    t1, s1 = a.alloc_table(1, (3, 3, 4, 4))   # shares rid 0's page
    assert s1 == 1
    a.register(1, (3, 3, 4, 4))
    a.free_table(1, donate_tokens=(3, 3, 4, 4))
    assert a.tree.n_cached == 2               # both blocks retained
    a.free_table(0)                           # live holder exits
    assert a.tree.n_cached == 2 and a.n_used == 2
    _check_invariants(a)
    assert a.ensure_free(a.n_pages)           # leaf first, then parent
    assert a.n_free == a.n_pages and a.tree.n_cached == 0
    _check_invariants(a)


def test_cascade_removal_releases_unreachable_retained_pages():
    """A live (non-retained) node dying must cascade through its subtree:
    retained descendants attached below it (via a donation that collided
    on the parent block) become unreachable, so their tree refcounts are
    released — otherwise those pages leak forever."""
    a = PageAllocator(8, 2, retain=True)
    # rid 0 and rid 1 prefill the same prompt concurrently (neither
    # registered yet), so rid 1 holds its OWN page for block (3, 3)
    a.alloc_table(0, (3, 3))
    t1, s1 = a.alloc_table(1, (3, 3, 4, 4))
    assert s1 == 0, "no sharing before registration"
    a.register(0, (3, 3))                     # rid 0 wins the index
    a.register(1, (3, 3, 4, 4))               # collides on block 0: its
    #                                           (4,4) node attaches BELOW
    #                                           rid 0's live node
    a.free_table(1, donate_tokens=(3, 3, 4, 4))
    # rid 1's (3,3) page collided (freed); its (4,4) page is retained as
    # a child of rid 0's live, non-retained node
    assert a.tree.n_cached == 1
    _check_invariants(a)
    # rid 0 exits without donating: its page dies, and the retained child
    # below it is unreachable — the cascade must free it too
    a.free_table(0)
    assert a.tree.n_cached == 0
    assert a.n_free == a.n_pages
    _check_invariants(a)


def test_match_is_incremental_o_blocks():
    """The admission-path match walks one dict probe per block — resident
    chains hundreds of blocks deep stay cheap.  Structural proxy: probe
    count equals matched blocks + 1, independent of prompt length."""
    a = PageAllocator(64, 2, retain=True)
    tokens = tuple(int(x) for x in np.arange(128) % 5)
    a.begin_table(0, tokens)
    a.grow_table(0, a.blocks_for(len(tokens)))
    a.register(0, tokens)
    a.free_table(0, donate_tokens=tokens)

    probes = 0
    orig_get = dict.get

    class CountingDict(dict):
        def get(self, *args):
            nonlocal probes
            probes += 1
            return orig_get(self, *args)

    # swap every children dict for a counting one
    stack = [a.tree._root]
    while stack:
        node = stack.pop()
        node.children = CountingDict(node.children)
        stack.extend(node.children.values())
    pages = a.match_prefix(tokens, touch=False)
    assert len(pages) == 64
    assert probes == 64, f"{probes} probes for 64 blocks (not incremental)"


def test_eviction_is_heap_ordered_not_a_tree_scan():
    """The quadratic path is gone: draining N retained leaves costs O(1)
    predicate probes per eviction off the stamp-ordered candidate heap —
    not an O(tree) leaf scan each — never falls back to the reference
    walk, and still evicts in exact LRU (donation stamp) order.  The
    admission-side evictable count likewise answers from the incremental
    counter without touching the walk."""
    a = PageAllocator(64, 2, retain=True)
    n_leaves = 32
    for i in range(n_leaves):
        tokens = (i, i)                     # distinct single-block chains
        got = a.alloc_table(i, tokens)
        assert got is not None
        a.register(i, tokens)
        a.free_table(i, donate_tokens=tokens)
    assert a.tree.n_cached == n_leaves
    donated_pages = [a.tree.match((i, i), touch=False)[0]
                     for i in range(n_leaves)]

    def forbid(*args, **kwargs):            # production must not walk
        raise AssertionError("O(tree) reference scan used on the "
                             "production eviction path")

    a.tree._evictable_leaf = forbid
    a.tree.evictable_walk = forbid

    # admission count: pure counter read, no walk
    assert a.evictable_pages() == n_leaves
    assert a.evictable_pages(frozenset(donated_pages[:3])) == n_leaves - 3

    sole_calls = 0

    def counting_sole(page):
        nonlocal sole_calls
        sole_calls += 1
        return a._sole(page)

    evicted = []

    def record_free(page):
        evicted.append(donated_pages.index(page))
        a.free_page(page)

    while a.tree.evict_lru(counting_sole, record_free):
        pass
    assert a.tree.n_cached == 0
    # one structurally-valid candidate pop (= one predicate probe) per
    # eviction; the old scan paid n_leaves probes per eviction (~530 here)
    assert sole_calls <= n_leaves + 4, (
        f"{sole_calls} predicate probes draining {n_leaves} leaves — "
        "eviction is scanning, not popping the heap")
    # LRU order: donation order is stamp order
    assert evicted == list(range(n_leaves))


# ------------------------------------------------------- engine level
def sequential_tokens(prompt, n_new, cache_len):
    logits, caches = cached_prefill_step(CFG, cache_len)(
        PARAMS, {"tokens": jnp.asarray(prompt, jnp.int32)[None]})
    decode = cached_serve_step(CFG)
    toks = [int(jnp.argmax(logits[0, :CFG.vocab]))]
    for i in range(n_new - 1):
        logits, caches = decode(PARAMS, jnp.asarray([toks[-1]], jnp.int32),
                                caches, jnp.int32(len(prompt) + i))
        toks.append(int(jnp.argmax(logits[0, :CFG.vocab])))
    return toks


def cache_engine(*, cache=True, chunk=4, budget=8, n_pages=24, rows=3,
                 cache_pages=0, max_prefill=2):
    return ServingEngine(
        [EngineModel("a", PARAMS, CFG, kv_slots=rows, max_seq=16,
                     kv_layout="paged", page_size=PAGE, n_pages=n_pages,
                     prefix_cache=cache, prefix_cache_pages=cache_pages)],
        sched=SchedulerConfig(max_prefill_per_step=max_prefill,
                              prefill_token_budget=budget),
        prefill_chunk=chunk)


def test_prefix_cache_requires_paged_layout():
    with pytest.raises(ValueError):
        EngineModel("a", PARAMS, CFG, kv_layout="slot", prefix_cache=True)


def test_warm_request_skips_covered_chunks_token_for_token():
    """The headline: a warm request over a cached shared prefix produces
    token-for-token identical output to cold prefill while re-prefilling
    zero tokens of the covered chunks — on the paged engine, against the
    cache-off paged engine, the slot-layout engine, and the sequential
    oracle."""
    rng = np.random.default_rng(3)
    prompt = rng.integers(1, CFG.vocab, 16).tolist()
    oracle = sequential_tokens(prompt, 6, cache_len=24 * PAGE)

    cold = cache_engine(cache=False)
    c1 = cold.submit("a", prompt, max_new_tokens=6)
    cold.run()

    slot_eng = ServingEngine(
        [EngineModel("a", PARAMS, CFG, kv_slots=2, max_seq=96)],
        prefill_chunk=4)
    s1 = slot_eng.submit("a", prompt, max_new_tokens=6)
    slot_eng.run()
    s2 = slot_eng.submit("a", prompt, max_new_tokens=6)  # slot: no cache,
    slot_eng.run()                                       # plain recompute

    warm = cache_engine(cache=True)
    w1 = warm.submit("a", prompt, max_new_tokens=6)
    warm.run()
    w2 = warm.submit("a", prompt, max_new_tokens=6)
    s = warm.run()

    for r in (c1, s1, s2, w1, w2):
        assert r.generated == oracle, r.rid
    # covered = 4 full pages = 16 tokens, capped at len-1 → 15 skipped,
    # exactly 1 computed (the final token's chunk produces real logits)
    assert s["prefill_tokens"] == 16 + 1
    assert s["prefix_hit_tokens"] == 15
    assert s["prefix_hit_rate"] == pytest.approx(15 / 32)
    assert s["kv_prefix_cached_pages"] > 0
    assert warm._prefills == {}


def test_multi_turn_history_reuse():
    """The multi-turn regime the cache exists for: turn k+1's prompt is
    turn k's prompt + generated + new user tokens, so the donated pages
    (prompt AND generated) cover a growing prefix and each turn computes
    only its tail."""
    eng = cache_engine(n_pages=48, budget=None)
    rng = np.random.default_rng(4)
    hist = rng.integers(1, CFG.vocab, 8).tolist()
    total_prompt = 0
    for turn in range(3):
        total_prompt += len(hist)
        req = eng.submit("a", hist, max_new_tokens=4)
        eng.run()
        assert req.generated == sequential_tokens(hist, 4,
                                                  cache_len=48 * PAGE)
        hist = hist + req.generated + rng.integers(1, CFG.vocab, 5).tolist()
    s = eng.summary()
    # conservation: every submitted prompt token was either computed in a
    # chunk or served from the cache — and a real share came from cache
    assert s["prefill_tokens"] + s["prefix_hit_tokens"] == total_prompt
    assert s["prefix_hit_tokens"] >= 8
    assert s["prefill_tokens"] < total_prompt


def test_eviction_under_pressure_stays_exact():
    """Retained pages fill the pool; admission demanding more pages than
    the free list holds LRU-evicts cached pages on demand instead of
    failing or preempting — and the evicted-then-recomputed request is
    still oracle-exact."""
    eng = cache_engine(n_pages=8, rows=2, budget=None)
    rng = np.random.default_rng(5)
    p1 = rng.integers(1, CFG.vocab, 12).tolist()
    r1 = eng.submit("a", p1, max_new_tokens=4)
    eng.run()
    alloc = eng.arenas["a"].allocator
    cached_before = alloc.tree.n_cached
    assert cached_before >= 3                 # pool is 8; most of it cached
    # a non-matching request needing more than the free pages forces LRU
    # eviction of the retained prefix
    p2 = rng.integers(1, CFG.vocab, 16).tolist()
    r2 = eng.submit("a", p2, max_new_tokens=8)
    s = eng.run()
    assert alloc.tree.evictions >= 1
    assert s["kv_prefix_evictions"] >= 1
    assert r1.generated == sequential_tokens(p1, 4, cache_len=8 * PAGE)
    assert r2.generated == sequential_tokens(p2, 8, cache_len=8 * PAGE)
    assert s["preemptions"] == 0, "eviction should pre-empt preemption"
    # p1's prefix was (partially) evicted: a p1 rerun may re-prefill, but
    # stays exact
    r3 = eng.submit("a", p1, max_new_tokens=4)
    eng.run()
    assert r3.generated == r1.generated


def test_mid_prefill_hit_and_preempt_resume():
    """A warm request whose prefill is split over chunks: admission skips
    the covered chunks, a mid-prefill preemption keeps both the skip and
    the computed progress, and the resume re-runs neither."""
    shared = list(range(1, 17))               # 16 tokens = 4 pages
    eng = cache_engine(n_pages=32, rows=2, budget=4, max_prefill=1)
    # donor finishes first: donates shared + its generated pages
    donor = eng.submit("a", shared, max_new_tokens=4)
    eng.run()
    assert donor.status is RequestStatus.FINISHED
    hits_after_donor = eng.metrics.prefix_hit_tokens
    rng = np.random.default_rng(6)
    tail = rng.integers(1, CFG.vocab, 8).tolist()
    long_req = eng.submit("a", shared + tail, max_new_tokens=3)
    eng.step()                                # hit-skip + first real chunk
    st = eng._prefills[long_req.rid]
    assert st.skipped == 16                   # admission hit: 4 chunks
    assert st.done == 20                      # + one computed chunk
    eng.preempt(long_req.rid)
    assert long_req.status is RequestStatus.PREEMPTED
    assert eng._prefills[long_req.rid].done == 20    # staging survives
    s = eng.run()
    assert long_req.status is RequestStatus.FINISHED
    assert eng._prefills == {}
    # donor computed 16; long computed only its uncovered 8
    assert s["prefill_tokens"] == 16 + 8
    assert s["prefix_hit_tokens"] == 16
    assert long_req.generated == sequential_tokens(shared + tail, 3,
                                                   cache_len=32 * PAGE)
    assert eng.metrics.prefix_hit_tokens > hits_after_donor


def test_resume_jump_when_coverage_grows_mid_prefill():
    """The hit boundary can MOVE while a request sits preempted: pages
    donated in the meantime extend the cover past its completed chunks,
    and readmission jumps `done` forward (reloading the staging carry-in
    from the pool) instead of recomputing.  The cold first stint is
    simulated by disabling the tenant's skip eligibility — the state a
    restarted or cache-cold engine stint leaves behind."""
    eng = cache_engine(n_pages=32, rows=2, budget=4, max_prefill=1)
    arena = eng.arenas["a"]
    rng = np.random.default_rng(10)
    pref = rng.integers(1, CFG.vocab, 24).tolist()
    donor = eng.submit("a", pref, max_new_tokens=5)
    eng.run()                                 # donates pref + 4 gen tokens
    assert donor.status is RequestStatus.FINISHED
    tail = rng.integers(1, CFG.vocab, 8).tolist()
    long_req = eng.submit("a", pref + tail, max_new_tokens=2)
    arena.skip_ok = False                     # cold stint: no hit applied
    eng.step()
    st = eng._prefills[long_req.rid]
    assert st.skipped == 0 and st.done == 4   # one cold chunk
    eng.preempt(long_req.rid)
    arena.skip_ok = True
    s = eng.run()
    # readmission re-matched: covered 24, floored to chunk 24 > done 4 →
    # jump of 20; only the 8 uncovered tokens plus the already-computed
    # cold chunk ever ran
    assert long_req.status is RequestStatus.FINISHED
    assert s["prefill_tokens"] == 24 + 4 + 8
    assert s["prefix_hit_tokens"] == 20
    assert long_req.generated == sequential_tokens(pref + tail, 2,
                                                   cache_len=32 * PAGE)


def test_full_prompt_cached_still_emits_first_token():
    """An exactly-cached prompt must still run its final chunk — the
    first token comes from real logits, not the cache."""
    eng = cache_engine(n_pages=24, budget=None, chunk=4)
    prompt = list(range(2, 10))               # 8 tokens = 2 pages, 2 chunks
    r1 = eng.submit("a", prompt, max_new_tokens=3)
    eng.run()
    r2 = eng.submit("a", prompt, max_new_tokens=3)
    s = eng.run()
    # covered = 8 (exact partial/full match), capped at 7 → 1 computed
    assert s["prefill_tokens"] == 8 + 1
    assert r2.generated == r1.generated
    assert r2.generated == sequential_tokens(prompt, 3, cache_len=24 * PAGE)


def test_int8_tenant_skips_covered_chunks_token_for_token():
    """int8 tenants now *skip* covered prefix-cache chunks: the
    dequantize-aware `_cached_page_read` reloads cached pages into the
    bf16 staging (codes × scales, same values decode attends after
    install), so a warm int8 request skips its covered chunks and still
    produces the same tokens as the cold run."""
    import dataclasses as dc
    cfg8 = dc.replace(CFG, kv_cache_dtype="int8")
    params = PARAMS

    def eng8(cache):
        return ServingEngine(
            [EngineModel("a", params, cfg8, kv_slots=2, max_seq=16,
                         kv_layout="paged", page_size=PAGE, n_pages=24,
                         prefix_cache=cache)],
            sched=SchedulerConfig(max_prefill_per_step=1),
            prefill_chunk=4)

    rng = np.random.default_rng(7)
    prompt = rng.integers(1, cfg8.vocab, 12).tolist()
    cold = eng8(False)
    c1 = cold.submit("a", prompt, max_new_tokens=5)
    cold.run()
    warm = eng8(True)
    w1 = warm.submit("a", prompt, max_new_tokens=5)
    warm.run()
    w2 = warm.submit("a", prompt, max_new_tokens=5)
    s = warm.run()
    assert warm.arenas["a"].skip_ok
    # w2's prompt is fully covered: skip to the len-1 cap (11 tokens
    # served from cache), only the final chunk computes
    assert s["prefix_hit_tokens"] == 11
    assert s["prefill_tokens"] < 24           # w2 did not re-prefill
    assert w1.generated == w2.generated == c1.generated


def test_cache_cap_bounds_resident_pages():
    eng = cache_engine(n_pages=32, cache_pages=2, budget=None)
    rng = np.random.default_rng(8)
    for _ in range(3):
        eng.submit("a", rng.integers(1, CFG.vocab, 12).tolist(),
                   max_new_tokens=3)
    eng.run()
    alloc = eng.arenas["a"].allocator
    assert alloc.tree.max_cached == 2
    assert alloc.tree.n_cached <= 2
    assert alloc.tree.evictions >= 1


def test_summary_and_junit_properties(record_property):
    """Metrics surface + the CI counters: a warm two-request workload
    publishes hit tokens, hit rate, resident pages, and evictions as
    junit properties (the named CI step re-runs exactly this test)."""
    eng = cache_engine(n_pages=12, budget=None)
    rng = np.random.default_rng(9)
    prompt = rng.integers(1, CFG.vocab, 12).tolist()
    r1 = eng.submit("a", prompt, max_new_tokens=4)
    eng.run()
    r2 = eng.submit("a", prompt, max_new_tokens=4)
    eng.run()
    other = eng.submit("a", rng.integers(1, CFG.vocab, 14).tolist(),
                       max_new_tokens=4)
    s = eng.run()
    assert r1.generated == r2.generated
    assert other.status is RequestStatus.FINISHED
    for key in ("prefix_hit_tokens", "prefix_hit_rate",
                "kv_prefix_cached_pages", "kv_prefix_evictions",
                "prefix_cached_pages_mean", "prefix_cached_pages_max"):
        assert key in s, key
    assert s["prefix_hit_tokens"] >= 8
    assert 0.0 < s["prefix_hit_rate"] < 1.0
    record_property("prefix_hit_tokens", int(s["prefix_hit_tokens"]))
    record_property("prefix_hit_rate", round(s["prefix_hit_rate"], 4))
    record_property("prefix_cached_pages_max",
                    int(s["prefix_cached_pages_max"]))
    record_property("prefix_evictions", int(s["kv_prefix_evictions"]))
