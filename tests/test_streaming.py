"""ARAS streaming executor: plan validity, delta accounting, e2e closeness."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.nn.model import forward, init_params
from repro.streaming.delta import QuantizedStore, delta_bytes
from repro.streaming.executor import StreamingExecutor
from repro.streaming.plan import StreamLayer, build_stream_plan


def test_plan_respects_arena_and_order():
    layers = [StreamLayer(f"L{i}", bytes_int8=1000 + 100 * i,
                          flops_per_token=2e6, tokens=4096) for i in range(8)]
    plan = build_stream_plan(layers, hbm_weight_budget_bytes=4000,
                             slot_bytes=2000)
    # compute i must start after its install completes
    installs = {e.layer: e for e in plan.events if e.kind == "install"}
    for e in plan.events:
        if e.kind == "compute":
            assert e.t_start >= installs[e.layer].t_end - 1e-12
    # slots in use never exceed the arena
    events = sorted(plan.events, key=lambda e: e.t_start)
    in_use, peak = 0, 0
    held = {}
    for e in events:
        if e.kind == "install":
            in_use += e.slots
            held[e.layer] = e.slots
            peak = max(peak, in_use)
        else:
            in_use -= held[e.layer]
    assert peak <= plan.n_slots
    assert plan.overlap_speedup >= 1.0


def test_plan_overlap_beats_serial_when_compute_bound():
    # compute ≈ 152 µs/layer ≈ install 150 µs/layer → overlap hides ~half
    layers = [StreamLayer(f"L{i}", bytes_int8=10_000_000,
                          flops_per_token=2e7, tokens=1_500)
              for i in range(12)]
    plan = build_stream_plan(layers, hbm_weight_budget_bytes=60_000_000,
                             slot_bytes=10_000_000, replication=False)
    assert plan.overlap_speedup > 1.3


def test_delta_bytes_skip_accounting():
    rng = np.random.default_rng(0)
    a = rng.integers(0, 256, 10_000, dtype=np.uint8)
    b_same = a.copy()
    bytes_same, skip_same = delta_bytes(a, b_same)
    assert skip_same == 1.0
    assert bytes_same < a.size // 100  # pure run-length tokens
    b_rand = rng.integers(0, 256, 10_000, dtype=np.uint8)
    bytes_rand, skip_rand = delta_bytes(a, b_rand)
    assert 0.15 < skip_rand < 0.35     # uniform 2-bit cells: ~25% equal


def test_store_centering_reduces_wire_bytes():
    # Per-tensor affine quantization normalizes symmetric ranges, so code
    # means only diverge when outliers stretch the range asymmetrically —
    # exactly the regime of real checkpoints (paper Fig 11).
    rng = np.random.default_rng(1)

    def mk(i):
        w = rng.normal(0.0, 0.5, (64, 64)).astype(np.float32)
        stretch = 6.0 if i % 2 == 0 else -6.0
        w.flat[:: 257] = stretch * (1.0 + 0.2 * rng.random())
        return [w]

    layers = [(f"L{i}", mk(i)) for i in range(6)]
    off = QuantizedStore(layers, reuse=False)
    on = QuantizedStore(layers, reuse=True)
    cost_off = sum(off.install_cost(i, i + 1)[0] for i in range(5))
    cost_on = sum(on.install_cost(i, i + 1)[0] for i in range(5))
    assert on.center is not None
    assert cost_on < cost_off


def test_executor_matches_full_model():
    cfg = get_config("minicpm-2b", smoke=True)
    cfg = dataclasses.replace(cfg, n_layers=4, scan_layers=False)
    params = init_params(jax.random.PRNGKey(0), cfg)
    ex = StreamingExecutor(params, cfg, arena_slots=2)
    batch = {"tokens": jnp.ones((2, 12), jnp.int32)}
    logits, m = ex.forward(batch)
    ref, _, _ = forward(params, batch, cfg)
    err = float(jnp.max(jnp.abs(logits.astype(jnp.float32)
                                - ref.astype(jnp.float32))))
    assert err < 0.2, err  # INT8 quantization noise only
    assert m["installs"] if "installs" in m else True
    assert m["wire_bytes"] > 0 and m["raw_bytes"] > 0


def test_executor_arena_smaller_than_model():
    """2 slots, 4 layers → layers must be overwritten (the paper's regime)."""
    cfg = get_config("gemma-7b", smoke=True)
    cfg = dataclasses.replace(cfg, n_layers=4, scan_layers=False)
    params = init_params(jax.random.PRNGKey(1), cfg)
    ex = StreamingExecutor(params, cfg, arena_slots=2)
    batch = {"tokens": jnp.ones((1, 8), jnp.int32)}
    _, m = ex.forward(batch)
    assert ex.stats.installs >= 4  # every layer installed at least once
