"""MoE: reference path invariants + shard_map equivalence (multi-device via
subprocess with forced host devices)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.nn.config import ModelConfig
from repro.nn.moe import init_moe, moe_reference, _route, _aux_loss

CFG = ModelConfig(name="t", family="moe", n_layers=1, d_model=32, n_heads=2,
                  n_kv_heads=2, d_ff=64, vocab=64, n_experts=8, moe_topk=2,
                  d_ff_expert=16)


def test_reference_output_finite_and_gated():
    params = init_moe(jax.random.PRNGKey(0), CFG)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 6, 32), jnp.float32)
    y, aux = moe_reference(params, x, CFG)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))
    assert float(aux) >= 1.0 - 1e-3  # load-balance loss lower bound is ~1


def test_router_topk_normalized():
    x = jax.random.normal(jax.random.PRNGKey(0), (16, 32), jnp.float32)
    r = jax.random.normal(jax.random.PRNGKey(1), (32, 8), jnp.float32)
    probs, vals, idx = _route(x, r, 2)
    np.testing.assert_allclose(np.asarray(vals.sum(-1)), 1.0, rtol=1e-5)
    assert int(idx.max()) < 8


def test_aux_loss_balanced_is_one():
    probs = jnp.full((64, 8), 1.0 / 8)
    idx = jnp.tile(jnp.arange(8), 8)[:, None]
    aux = _aux_loss(probs, idx, 8)
    assert float(aux) == pytest.approx(1.0, rel=1e-5)


_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import sys; sys.path.insert(0, "src")
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import Mesh
    from repro.nn.config import ModelConfig
    from repro.nn.moe import init_moe, moe, moe_reference
    from repro.parallel.sharding import use_mesh

    cfg = ModelConfig(name="t", family="moe", n_layers=1, d_model=32,
                      n_heads=2, n_kv_heads=2, d_ff=64, vocab=64,
                      n_experts=8, moe_topk=2, d_ff_expert=16,
                      capacity_factor=8.0)  # high cf → no drops → exact match
    params = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32), jnp.float32)
    y_ref, aux_ref = moe_reference(params, x, cfg)
    mesh = Mesh(np.array(jax.devices()).reshape(2, 2), ("data", "model"))
    with use_mesh(mesh):
        y, aux = jax.jit(lambda p, v: moe(p, v, cfg))(params, x)
    err = float(jnp.max(jnp.abs(y - y_ref)))
    assert err < 2e-3, f"a2a path mismatch {err}"
    with use_mesh(mesh):
        y2, _ = jax.jit(lambda p, v: moe(p, v, cfg, decode=True))(
            params, x[:, :1])
    y2_ref, _ = moe_reference(params, x[:, :1], cfg)
    err2 = float(jnp.max(jnp.abs(y2 - y2_ref)))
    assert err2 < 2e-3, f"replicated path mismatch {err2}"
    print("MOE_OK", err, err2)
""")


@pytest.mark.slow
def test_shard_map_paths_match_reference_subprocess():
    r = subprocess.run([sys.executable, "-c", _SUBPROC],
                       cwd=os.path.join(os.path.dirname(__file__), ".."),
                       capture_output=True, text=True, timeout=600)
    assert "MOE_OK" in r.stdout, r.stdout + r.stderr
